# Beyond-paper integration: the paper's cache-based MQO applied to LLM
# serving (shared-prefix admission under an HBM budget).
from .costs import ServingCostModel
from .engine import ServingEngine, ServingReport
from .request import GenerationRequest, TokenBlock, plan_requests
