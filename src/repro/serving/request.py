"""Generation requests and token-block prefix plans.

The beyond-paper instantiation of the paper's machinery: a request's
prompt is quantized into blocks of ``block_size`` tokens; the chain of
full blocks forms a unary plan whose Merkle fingerprint (core
Definition 2) identifies shared prefixes across a batch — the serving
analog of similar subexpressions.  Token blocks use STRICT identity
(attrs = the tokens themselves): prefixes share work only when
identical, so covering expressions are identities and extraction plans
are pure "resume from cached state".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

DEFAULT_BLOCK_SIZE = 64


@dataclass(frozen=True)
class TokenBlock:
    """One block of the prefix chain.  children = (previous block,)."""

    tokens: Tuple[int, ...]
    prev: Optional["TokenBlock"] = None
    depth: int = 0                      # blocks before this one

    # --- PlanNode protocol -------------------------------------------------
    @property
    def children(self):
        return (self.prev,) if self.prev is not None else ()

    @property
    def label(self) -> str:
        return "blk"

    loose = False
    cache_friendly = True
    commutative = True          # unary/leaf: irrelevant, set for protocol

    @property
    def strict_attrs(self):
        return self.tokens

    @property
    def n_tokens(self) -> int:
        return (self.depth + 1) * len(self.tokens)

    def merge(self, others):
        return self             # strict identity -> members are identical

    def with_children(self, children):
        if not children:
            return TokenBlock(self.tokens, None, 0)
        (prev,) = children
        return TokenBlock(self.tokens, prev, prev.depth + 1)

    def full_tokens(self) -> np.ndarray:
        parts: List[Tuple[int, ...]] = []
        node: Optional[TokenBlock] = self
        while node is not None:
            parts.append(node.tokens)
            node = node.prev
        return np.asarray([t for blk in reversed(parts) for t in blk],
                          np.int32)


@dataclass
class GenerationRequest:
    request_id: int
    prompt: np.ndarray                  # (T,) int32
    max_new_tokens: int = 16
    # filled by the planner:
    chain: Optional[TokenBlock] = None  # last FULL block of the prompt
    tail: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))


def build_chain(prompt: np.ndarray, block_size: int
                ) -> Tuple[Optional[TokenBlock], np.ndarray]:
    """Quantize a prompt into its full-block chain + unshared tail."""
    n_full = len(prompt) // block_size
    node: Optional[TokenBlock] = None
    for i in range(n_full):
        blk = tuple(int(t) for t in prompt[i * block_size:
                                           (i + 1) * block_size])
        node = TokenBlock(blk, node, i)
    tail = np.asarray(prompt[n_full * block_size:], np.int32)
    return node, tail


def plan_requests(requests: Sequence[GenerationRequest],
                  block_size: int = DEFAULT_BLOCK_SIZE
                  ) -> List[GenerationRequest]:
    for r in requests:
        r.chain, r.tail = build_chain(r.prompt, block_size)
    return list(requests)


def identify_shared_prefixes(requests: Sequence[GenerationRequest],
                             k: int = 2):
    """Serving adaptation of Algorithm 1.

    Plans are unary chains, so the paper's stop-at-the-highest-friendly
    -node heuristic would only ever record whole prompts; the chain
    analog enumerates EVERY full-block prefix into the fingerprint
    table (a chain of depth n has exactly n sub-plans — no search-space
    explosion to prune).  Threshold k keeps prefixes shared by >= k
    requests, exactly as in the paper.
    """
    from ..core.fingerprint import fingerprint
    from ..core.identify import Occurrence, SimilarSubexpression

    table = {}
    memo = {}
    for qi, r in enumerate(requests):
        node = r.chain
        while node is not None:
            psi = fingerprint(node, memo)
            se = table.get(psi)
            if se is None:
                se = table[psi] = SimilarSubexpression(psi=psi)
            se.occurrences.append(Occurrence(qi, node))
            node = node.prev

    out = [se for se in table.values()
           if se.m >= k and len(se.query_indices) >= 2]
    out.sort(key=lambda s: (-s.occurrences[0].node.n_tokens, s.psi))
    return out
