"""Serving cost model: FLOPs-based CE pricing, HBM-bytes weights.

The knapsack weight of a cached prefix is the per-arch state footprint:

  * GQA layers       2 · H_kv · head_dim · len · dtype  per layer
  * local (window)   same, clipped at the window length
  * MLA              (kv_lora + rope) · len  — ~9x lighter than GQA
  * Mamba / RG-LRU   O(1): conv window + recurrent state, len-free

The value follows Eq. 1–3 with C_E = prefill cost of the prefix
(2 · N_active · len linear term + the attention quadratic term),
C_W / C_R = HBM write/read of the state bytes.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..models.config import ArchConfig
from .request import TokenBlock

V5E_FLOPS = 197e12          # bf16 peak per chip
V5E_HBM_BW = 819e9          # bytes/s


@dataclass
class ServingCostModel:
    cfg: ArchConfig
    dtype_bytes: int = 2
    chips: int = 1

    # ---- per-arch state footprint ------------------------------------------
    def state_bytes(self, n_tokens: int) -> int:
        cfg = self.cfg
        total = 0
        for kind in cfg.layer_kinds():
            if kind == "attn":
                total += (2 * cfg.n_kv_heads * cfg.head_dim * n_tokens
                          * self.dtype_bytes)
            elif kind == "local":
                eff = min(n_tokens, cfg.window or n_tokens)
                total += (2 * cfg.n_kv_heads * cfg.head_dim * eff
                          * self.dtype_bytes)
            elif kind == "mla":
                total += ((cfg.kv_lora_rank + cfg.qk_rope_dim) * n_tokens
                          * self.dtype_bytes)
            elif kind == "mamba":
                total += (cfg.d_inner * (cfg.ssm_state + cfg.d_conv)
                          * self.dtype_bytes)
            elif kind == "rglru":
                w = cfg.lru_width_actual
                total += w * (1 + cfg.d_conv) * self.dtype_bytes
        return total

    def prefill_flops(self, n_tokens: int) -> float:
        _, active = self.cfg.param_count()
        linear = 2.0 * active * n_tokens
        attn = 0.0
        for kind in self.cfg.layer_kinds():
            if kind in ("attn", "mla"):
                dim = (self.cfg.qk_head_dim + (
                    self.cfg.v_head_dim if self.cfg.kv_lora_rank
                    else self.cfg.head_dim)) * self.cfg.n_heads
                attn += 2.0 * n_tokens * n_tokens * dim / 2.0
            elif kind == "local":
                w = self.cfg.window or n_tokens
                dim = 2 * self.cfg.head_dim * self.cfg.n_heads
                attn += 2.0 * n_tokens * min(n_tokens, w) * dim / 2.0
        return linear + attn

    # ---- CostModel protocol (seconds on `chips` v5e chips) -----------------
    def execution_cost(self, tree: TokenBlock) -> float:
        return self.prefill_flops(tree.n_tokens) / (self.chips * V5E_FLOPS)

    def output_rows(self, tree: TokenBlock) -> int:
        return tree.n_tokens

    def output_bytes(self, tree: TokenBlock) -> int:
        return self.state_bytes(tree.n_tokens)

    def write_cost(self, tree: TokenBlock) -> float:
        return self.output_bytes(tree) / (self.chips * V5E_HBM_BW)

    def read_cost(self, tree: TokenBlock) -> float:
        return self.output_bytes(tree) / (self.chips * V5E_HBM_BW)
