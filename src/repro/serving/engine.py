"""Batched serving engine with cache-based multi-request optimization.

The paper's four phases over a batch of generation requests:

  1. identify shared full-block prefixes (Merkle chain fingerprints);
  2. covering expressions are the shared prefixes themselves (strict
     identity -> merge is the identity, extraction = resume);
  3. MCKP admission into the HBM state pool under a byte budget, with
     Algorithm-2 groups (nested prefixes are mutually exclusive
     options under their longest selected ancestor);
  4. rewrite: each request prefills only its suffix from the longest
     admitted prefix state; admitted prefixes chain onto each other.

Guarantee (tested): generations are bit-identical with MQO on or off —
prefix state reuse is exact, the optimization only removes recompute.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.cache import CacheManager
from ..core.memory import MemoryManager
from ..core.candidates import generate_knapsack_items
from ..core.costmodel import price_ces
from ..core.covering import build_covering_expressions
from ..core.mckp import solve_mckp
from ..core.telemetry import NOOP_SPAN
from ..models.config import ArchConfig
from ..models.decoder import init_cache
from ..models.model import decode_step
from .costs import ServingCostModel
from .request import (GenerationRequest, TokenBlock,
                      identify_shared_prefixes, plan_requests)


@partial(jax.jit, static_argnames=("cfg",))
def _prefill_scan(params, cache, tokens: jnp.ndarray, start_len, cfg):
    """Sequential cache-filling prefill (scan of decode steps).

    tokens: (B, T).  Returns (cache, last_logits (B, V)).
    NOTE: the parallel (flash) prefill is used for dry-run lowering;
    this scan variant is the cache-materializing path of the serving
    engine — fusing the two is tracked in EXPERIMENTS.md §Perf.
    """
    def step(carry, tok_t):
        cache, i = carry
        logits, cache = decode_step(params, cache, tok_t[:, None], i, cfg)
        return (cache, i + 1), logits

    (cache, _), logits = jax.lax.scan(
        step, (cache, jnp.asarray(start_len, jnp.int32)), tokens.T)
    return cache, logits[-1]


@partial(jax.jit, static_argnames=("cfg", "n_new"))
def _generate_scan(params, cache, first_tok, start_len, cfg, n_new: int):
    def step(carry, _):
        cache, tok, i = carry
        logits, cache = decode_step(params, cache, tok, i, cfg)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return (cache, nxt, i + 1), nxt[:, 0]

    (cache, _, _), toks = jax.lax.scan(
        step, (cache, first_tok, jnp.asarray(start_len, jnp.int32)),
        None, length=n_new)
    return toks.T, cache        # (B, n_new)


@dataclass
class ServingReport:
    n_requests: int = 0
    n_ses: int = 0
    n_selected: int = 0
    pool_budget: int = 0
    pool_used: int = 0
    tokens_prefilled: int = 0
    tokens_prefilled_baseline: int = 0
    prefill_flops_saved: float = 0.0
    optimize_seconds: float = 0.0
    wall_seconds: float = 0.0

    @property
    def prefill_token_ratio(self) -> float:
        base = max(self.tokens_prefilled_baseline, 1)
        return self.tokens_prefilled / base


def _state_to_host(payload):
    """Spill a prefix state (cache pytree, n_tokens) HBM -> host DRAM."""
    cache, n_tok = payload
    return (jax.tree_util.tree_map(lambda a: np.asarray(a), cache), n_tok)


def _state_to_device(payload):
    cache, n_tok = payload
    return (jax.tree_util.tree_map(jnp.asarray, cache), n_tok)


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, *,
                 pool_budget_bytes: int, block_size: int = 64,
                 max_len: int = 512, k: int = 2,
                 policy: str = "lru",
                 retain_states: bool = True,
                 telemetry=None):
        self.cfg = cfg
        self.params = params
        self.block_size = block_size
        self.max_len = max_len
        self.k = k
        self.cost_model = ServingCostModel(cfg)
        self.pool_budget = int(pool_budget_bytes)
        # optional relational.observe.Telemetry (PR 9): phase spans +
        # counters for the serving-side MQO; None costs one attribute
        # check per batch
        self.telemetry = telemetry
        # prefix states are admitted through the unified memory
        # hierarchy: HBM budget enforced by the manager, eviction under
        # pressure, spill tier = host DRAM offload of the KV/SSM state.
        # Retained across batches (prefix fingerprints are Merkle chains
        # over token CONTENT, so cross-batch reuse is exact) unless
        # retain_states=False.
        self.retain_states = retain_states
        # host tier bounded at 4x HBM budget so a long-lived engine with
        # retention cannot grow host DRAM without limit (same rationale
        # as relational.Session)
        self.memory = MemoryManager(self.pool_budget,
                                    host_budget=4 * self.pool_budget,
                                    policy=policy)
        self.pool = CacheManager(
            self.pool_budget, spill_fn=_state_to_host,
            unspill_fn=_state_to_device, manager=self.memory,
            pool="prefix")
        if telemetry is not None:
            self.memory.telemetry = telemetry

    def _span(self, name: str, **attrs):
        tel = self.telemetry
        if tel is not None and tel.tracer.enabled:
            return tel.tracer.span(name, **attrs)
        return NOOP_SPAN

    def _fresh_cache(self, batch: int = 1):
        return init_cache(self.cfg, batch, self.max_len,
                          jnp.dtype(self.cfg.dtype))

    # ------------------------------------------------------------------
    def run_batch(self, requests: Sequence[GenerationRequest], *,
                  mqo: bool = True) -> Tuple[List[np.ndarray],
                                             ServingReport]:
        report = ServingReport(n_requests=len(requests),
                               pool_budget=self.pool_budget)
        t_wall = time.perf_counter()
        requests = plan_requests(list(requests), self.block_size)
        report.tokens_prefilled_baseline = sum(len(r.prompt)
                                               for r in requests)

        if mqo:
            if not self.retain_states:
                self.pool.clear()
            pool = self.pool
        else:
            # the no-MQO baseline stays cold: an empty throwaway pool,
            # so retained states never leak into baseline measurements
            pool = CacheManager(self.pool_budget)
        if mqo:
            t0 = time.perf_counter()
            with self._span("serving.identify",
                            n_requests=len(requests)):
                ses = identify_shared_prefixes(requests, k=self.k)
            report.n_ses = len(ses)
            ces = build_covering_expressions(ses)
            price_ces(ces, self.cost_model)
            items = generate_knapsack_items(ces)
            with self._span("serving.solve", n_items=len(items),
                            budget=self.pool_budget):
                sol = solve_mckp(items, self.pool_budget)
            report.optimize_seconds = time.perf_counter() - t0
            report.n_selected = len(sol.ces)

            # materialize admitted prefixes, chaining longer onto shorter
            with self._span("serving.materialize",
                            n_selected=len(sol.ces)):
                for ce in sorted(sol.ces, key=lambda c: c.tree.n_tokens):
                    chain: TokenBlock = ce.tree
                    if pool.touch(ce.psi):
                        # cross-batch hit: the state is already
                        # materialized (prefix fingerprints are
                        # content-exact), skip the prefill entirely —
                        # the full CE value is saved.  touch() refreshes
                        # LRU recency (so the entry is not this batch's
                        # next eviction victim) WITHOUT paying an
                        # unspill: consumers unspill/promote on demand
                        # in _resume_point.
                        report.prefill_flops_saved += ce.value * (
                            self.cost_model.chips * 1.0)
                        continue
                    anc_psi, anc_len = self._longest_cached_ancestor(
                        chain, pool)
                    if anc_psi is not None:
                        cache, _ = pool.get(anc_psi)
                    else:
                        cache, anc_len = self._fresh_cache(), 0
                    delta = chain.full_tokens()[anc_len:]
                    cache, _ = _prefill_scan(
                        self.params, cache, jnp.asarray(delta[None]),
                        anc_len, self.cfg)
                    report.tokens_prefilled += len(delta)
                    pool.put(ce.psi, (cache, chain.n_tokens),
                             nbytes=self.cost_model.state_bytes(
                                 chain.n_tokens),
                             est_bytes=ce.weight,
                             benefit=max(float(ce.value), 0.0))
                    report.prefill_flops_saved += ce.value * (
                        self.cost_model.chips * 1.0)

        # rewrite + execute every request
        outputs: List[np.ndarray] = []
        for r in requests:
            cache, start = self._resume_point(r, pool)
            suffix = np.concatenate(
                [r.chain.full_tokens()[start:] if r.chain is not None
                 else np.zeros(0, np.int32), r.tail])
            if len(suffix) > 1:
                cache, _ = _prefill_scan(
                    self.params, cache,
                    jnp.asarray(suffix[:-1][None]), start, self.cfg)
                report.tokens_prefilled += len(suffix) - 1
            first = jnp.asarray(suffix[-1:][None])
            toks, _ = _generate_scan(
                self.params, cache, first, len(r.prompt) - 1, self.cfg,
                r.max_new_tokens)
            outputs.append(np.asarray(toks[0]))

        report.pool_used = pool.used_bytes
        report.wall_seconds = time.perf_counter() - t_wall
        if self.telemetry is not None:
            reg = self.telemetry.registry
            reg.inc("serving.batches")
            reg.inc("serving.requests", len(requests))
            reg.inc("serving.tokens_prefilled", report.tokens_prefilled)
            reg.inc("serving.tokens_prefilled_baseline",
                    report.tokens_prefilled_baseline)
        return outputs, report

    # ------------------------------------------------------------------
    def _longest_cached_ancestor(self, chain: TokenBlock,
                                 pool: CacheManager):
        from ..core.fingerprint import fingerprint

        node = chain.prev
        while node is not None:
            psi = fingerprint(node)
            if pool.contains(psi):
                return psi, node.n_tokens
            node = node.prev
        return None, 0

    def _resume_point(self, r: GenerationRequest, pool: CacheManager):
        from ..core.fingerprint import fingerprint

        node = r.chain
        while node is not None:
            psi = fingerprint(node)
            if pool.contains(psi):
                cache, n_tok = pool.get(psi)
                return cache, n_tok
            node = node.prev
        return self._fresh_cache(), 0
