# LM substrate: pattern-based decoder stacks covering all assigned
# architecture families (dense/MoE/MLA/SSM/hybrid/VLM/audio).
from .config import ArchConfig, smoke_variant
from .model import (SHAPES, ShapeCell, decode_step, forward, get_shape,
                    init_params, input_specs, loss_fn, model_specs)
