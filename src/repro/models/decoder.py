"""Decoder stack: pattern-based block assembly, scanned over repeats.

A model is ``first_k_dense`` unrolled prefix layers + ``full_repeats``
scanned copies of the layer ``pattern`` + unrolled remainder layers.
Scanning keeps the HLO compact (one pattern body regardless of depth),
which matters for 512-device dry-run compile times; remat wraps the
scan body when cfg.remat == "block".

Three entry points per stack: ``forward`` (training), ``prefill``
(fills decode caches from a token block, used by the serving engine's
covering prefill plans), and ``decode_step`` (single token).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from . import ffn as F
from . import rglru as R
from . import ssm as S
from .common import ParamSpec, rmsnorm, rmsnorm_spec
from .config import ArchConfig


# ---------------------------------------------------------------------------
# per-block specs
# ---------------------------------------------------------------------------
def block_specs(cfg: ArchConfig, kind: str, ffn_kind: str) -> Dict:
    d = cfg.d_model
    specs: Dict[str, Any] = {"norm1": rmsnorm_spec(d)}
    if kind in ("attn", "local"):
        specs["mix"] = A.gqa_specs(cfg)
    elif kind == "mla":
        specs["mix"] = A.mla_specs(cfg)
    elif kind == "mamba":
        specs["mix"] = S.mamba_specs(cfg)
        return specs                       # mamba block has no MLP
    elif kind == "rglru":
        specs["mix"] = R.rglru_specs(cfg)
    else:
        raise ValueError(kind)
    specs["norm2"] = rmsnorm_spec(d)
    specs["ffn"] = F.ffn_specs(cfg, ffn_kind)
    return specs


def _stack_specs(specs, repeats: int):
    def stack(s: ParamSpec) -> ParamSpec:
        return ParamSpec((repeats,) + s.shape, ("layers",) + s.logical_axes,
                         s.init, s.scale, s.dtype)

    return jax.tree.map(stack, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def decoder_specs(cfg: ArchConfig) -> Dict:
    specs: Dict[str, Any] = {}
    if cfg.first_k_dense:
        specs["prefix"] = [block_specs(cfg, cfg.pattern[0], "dense")
                           for _ in range(cfg.first_k_dense)]
    if cfg.full_repeats:
        body = {str(p): block_specs(cfg, kind, cfg.ffn_kind)
                for p, kind in enumerate(cfg.pattern)}
        specs["scan"] = _stack_specs(body, cfg.full_repeats)
    if cfg.remainder_layers:
        specs["rem"] = [
            block_specs(cfg, cfg.pattern[i % len(cfg.pattern)],
                        cfg.ffn_kind)
            for i in range(cfg.remainder_layers)]
    return specs


# ---------------------------------------------------------------------------
# training / prefill-style forward
# ---------------------------------------------------------------------------
def _window(cfg: ArchConfig, kind: str) -> Optional[int]:
    return cfg.window if kind == "local" else None


def block_forward(p, x: jnp.ndarray, cfg: ArchConfig, kind: str,
                  ffn_kind: str, positions: jnp.ndarray, dtype
                  ) -> jnp.ndarray:
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind in ("attn", "local"):
        h = A.gqa_forward(p["mix"], h, cfg, window=_window(cfg, kind),
                          positions=positions, dtype=dtype)
    elif kind == "mla":
        h = A.mla_forward(p["mix"], h, cfg, positions=positions,
                          dtype=dtype)
    elif kind == "mamba":
        return x + S.mamba_forward(p["mix"], h, cfg, dtype)
    elif kind == "rglru":
        h = R.rglru_forward(p["mix"], h, cfg, dtype)
    x = x + h
    h = rmsnorm(p["norm2"], x, cfg.norm_eps)
    x = x + F.ffn_forward(p["ffn"], h, cfg, ffn_kind, dtype)
    return x


def decoder_forward(params, x: jnp.ndarray, cfg: ArchConfig,
                    positions: jnp.ndarray, dtype) -> jnp.ndarray:
    for p in params.get("prefix", []):
        x = block_forward(p, x, cfg, cfg.pattern[0], "dense", positions,
                          dtype)

    if cfg.full_repeats:
        def body(x, layer):
            for p_i, kind in enumerate(cfg.pattern):
                x = block_forward(layer[str(p_i)], x, cfg, kind,
                                  cfg.ffn_kind, positions, dtype)
            return x, None

        if cfg.remat in ("block", "full"):
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["scan"])

    for i, p in enumerate(params.get("rem", [])):
        kind = cfg.pattern[i % len(cfg.pattern)]
        x = block_forward(p, x, cfg, kind, cfg.ffn_kind, positions, dtype)
    return x


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------
def _kind_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                dtype):
    if kind in ("attn", "local"):
        # local layers only ever need a window-sized cache
        L = max_len if kind == "attn" else min(max_len,
                                               cfg.window or max_len)
        return A.gqa_init_cache(cfg, batch, L, dtype)
    if kind == "mla":
        return A.mla_init_cache(cfg, batch, max_len, dtype)
    if kind == "mamba":
        return S.mamba_init_cache(cfg, batch, dtype)
    if kind == "rglru":
        return R.rglru_init_cache(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None
               ) -> Dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    cache: Dict[str, Any] = {}
    if cfg.first_k_dense:
        cache["prefix"] = [
            _kind_cache(cfg, cfg.pattern[0], batch, max_len, dtype)
            for _ in range(cfg.first_k_dense)]
    if cfg.full_repeats:
        body = {str(p): _kind_cache(cfg, kind, batch, max_len, dtype)
                for p, kind in enumerate(cfg.pattern)}
        cache["scan"] = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a[None], (cfg.full_repeats,) + a.shape).copy(), body)
    if cfg.remainder_layers:
        cache["rem"] = [
            _kind_cache(cfg, cfg.pattern[i % len(cfg.pattern)], batch,
                        max_len, dtype)
            for i in range(cfg.remainder_layers)]
    return cache


def _block_decode(p, x, cache, cur_len, cfg: ArchConfig, kind: str,
                  ffn_kind: str, dtype):
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind in ("attn", "local"):
        # local cache is a rolling window: write position clamps to the
        # last slot once full (older entries roll off), while RoPE keeps
        # using the absolute position so relative phases stay correct.
        if kind == "local" and cfg.window is not None:
            wlen = cache["k"].shape[2]
            write_idx = jnp.minimum(cur_len, wlen - 1)

            def roll(a):
                return jnp.where(cur_len >= wlen,
                                 jnp.roll(a, -1, axis=2), a)

            cache = jax.tree.map(roll, cache)
            h, new_cache = A.gqa_decode(p["mix"], h, cache, write_idx,
                                        cfg, window=None, dtype=dtype,
                                        rope_pos=cur_len)
        else:
            h, new_cache = A.gqa_decode(p["mix"], h, cache, cur_len, cfg,
                                        window=None, dtype=dtype)
    elif kind == "mla":
        h, new_cache = A.mla_decode(p["mix"], h, cache, cur_len, cfg,
                                    dtype=dtype)
    elif kind == "mamba":
        h, new_cache = S.mamba_decode(p["mix"], h, cache, cfg, dtype)
        return x + h, new_cache
    elif kind == "rglru":
        h, new_cache = R.rglru_decode(p["mix"], h, cache, cfg, dtype)
    x = x + h
    h = rmsnorm(p["norm2"], x, cfg.norm_eps)
    x = x + F.ffn_forward(p["ffn"], h, cfg, ffn_kind, dtype)
    return x, new_cache


def decoder_decode_step(params, cache, x: jnp.ndarray, cur_len,
                        cfg: ArchConfig, dtype) -> Tuple[jnp.ndarray, Dict]:
    new_cache: Dict[str, Any] = {}
    if cfg.first_k_dense:
        nc = []
        for p, c in zip(params["prefix"], cache["prefix"]):
            x, c2 = _block_decode(p, x, c, cur_len, cfg, cfg.pattern[0],
                                  "dense", dtype)
            nc.append(c2)
        new_cache["prefix"] = nc

    if cfg.full_repeats:
        def body(x, xs):
            layer, lcache = xs
            ncs = {}
            for p_i, kind in enumerate(cfg.pattern):
                x, nc_ = _block_decode(layer[str(p_i)], x, lcache[str(p_i)],
                                       cur_len, cfg, kind, cfg.ffn_kind,
                                       dtype)
                ncs[str(p_i)] = nc_
            return x, ncs

        x, sc = jax.lax.scan(body, x, (params["scan"], cache["scan"]))
        new_cache["scan"] = sc

    if cfg.remainder_layers:
        nc = []
        for i, (p, c) in enumerate(zip(params["rem"], cache["rem"])):
            kind = cfg.pattern[i % len(cfg.pattern)]
            x, c2 = _block_decode(p, x, c, cur_len, cfg, kind,
                                  cfg.ffn_kind, dtype)
            nc.append(c2)
        new_cache["rem"] = nc
    return x, new_cache
