"""FFN blocks: dense SwiGLU and Mixture-of-Experts.

MoE uses sort-based token dispatch (argsort by expert id, capacity-
bounded scatter into per-expert slots) + batched expert matmuls — the
einsum shape (E, C, D) x (E, D, F) keeps FLOPs proportional to ACTIVE
parameters (top-k), and the expert dimension shards over the "model"
mesh axis (expert parallelism; tokens cross via the scatter/gather
collectives).  Shared experts (DeepSeek) are a fused dense SwiGLU of
width n_shared * d_ff_expert.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ParamSpec
from .config import ArchConfig


def dense_specs(cfg: ArchConfig, d_ff: int | None = None
                ) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return {
        "w1": ParamSpec((d, f), ("embed", "ffn"), "lecun"),
        "w3": ParamSpec((d, f), ("embed", "ffn"), "lecun"),
        "w2": ParamSpec((f, d), ("ffn", "embed"), "lecun"),
    }


def dense_forward(p, x: jnp.ndarray, dtype) -> jnp.ndarray:
    h = jax.nn.silu(x @ p["w1"].astype(dtype)) * (x @ p["w3"].astype(dtype))
    return h @ p["w2"].astype(dtype)


def moe_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, e, fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    specs: Dict[str, ParamSpec] = {
        "router": ParamSpec((d, e), ("embed", None), "lecun"),
        "w1": ParamSpec((e, d, fe), ("experts", "embed", "ffn"), "lecun"),
        "w3": ParamSpec((e, d, fe), ("experts", "embed", "ffn"), "lecun"),
        "w2": ParamSpec((e, fe, d), ("experts", "ffn", "embed"), "lecun"),
    }
    if cfg.n_shared_experts:
        shared = dict(dense_specs(cfg, cfg.n_shared_experts
                                  * cfg.d_ff_expert))
        specs["shared"] = shared
    return specs


def moe_forward(p, x: jnp.ndarray, cfg: ArchConfig, dtype) -> jnp.ndarray:
    from .common import constrain

    b, t, d = x.shape
    s = b * t
    e, k = cfg.n_experts, cfg.top_k
    xf = constrain(x.reshape(s, d), ("tokens", None))

    gates = jax.nn.softmax(
        (xf @ p["router"].astype(dtype)).astype(jnp.float32), axis=-1)
    top_vals, top_idx = jax.lax.top_k(gates, k)           # (S, k)
    top_vals = top_vals / jnp.maximum(
        top_vals.sum(-1, keepdims=True), 1e-9)            # renormalize

    # per-expert slots; clamped to S (one expert can never receive more
    # than every token).  capacity_factor >= n_experts/top_k => dropless.
    capacity = min(s, int((s * k / e) * cfg.capacity_factor) + 1)

    flat_e = top_idx.reshape(s * k)
    flat_tok = jnp.repeat(jnp.arange(s), k)
    flat_w = top_vals.reshape(s * k)

    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]
    sorted_w = flat_w[order]

    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    rank = jnp.arange(s * k) - seg_start[sorted_e]
    keep = rank < capacity                                # overflow drops
    slot = jnp.where(keep, sorted_e * capacity + rank, e * capacity)

    # token->slot scatter: tokens stay data-sharded, expert slots are
    # expert-parallel over "model" — the partitioner turns the crossing
    # into the EP all-to-all instead of replicating the buffers
    src = constrain(xf[sorted_tok] * keep[:, None].astype(dtype),
                    ("tokens", None))
    buf = jnp.zeros((e * capacity + 1, d), dtype)
    buf = buf.at[slot].set(src)
    expert_in = constrain(buf[:-1].reshape(e, capacity, d),
                          ("experts", None, None))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in,
                               p["w1"].astype(dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, p["w3"].astype(dtype))
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(dtype))
    out_e = constrain(out_e, ("experts", None, None))

    gathered = out_e.reshape(e * capacity, d)[jnp.minimum(
        slot, e * capacity - 1)]
    gathered = constrain(gathered, ("tokens", None))
    gathered = gathered * (keep & True)[:, None].astype(dtype)
    contrib = gathered * sorted_w[:, None].astype(dtype)
    out = jnp.zeros((s, d), dtype).at[sorted_tok].add(contrib)
    out = constrain(out, ("tokens", None))

    if cfg.n_shared_experts:
        out = out + dense_forward(p["shared"], xf, dtype)
    return out.reshape(b, t, d)


def moe_forward_ep(p, x: jnp.ndarray, cfg: ArchConfig, dtype,
                   mesh, token_axes, model_axis: str) -> jnp.ndarray:
    """Expert-parallel MoE via shard_map (the §Perf iteration-3 path).

    Tokens stay batch-sharded (replicated across the model axis);
    experts are model-sharded.  Routing/top-k run at jit level; the
    dispatch scatter, expert matmuls, and combine gather run INSIDE a
    shard_map body — purely shard-LOCAL, so the partitioner can neither
    replicate the buffers nor lower the scatter to masked-dense ops.
    The only cross-shard collective is one psum of the (S_local, d)
    partial outputs over the model axis.
    """
    try:
        from jax import shard_map
    except ImportError:  # older jax
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    b, t, d = x.shape
    s = b * t
    e, k = cfg.n_experts, cfg.top_k
    m = dict(zip(mesh.axis_names, mesh.devices.shape))[model_axis]
    assert e % m == 0, (e, m)
    e_loc = e // m

    xf = x.reshape(s, d)
    gates = jax.nn.softmax(
        (xf @ p["router"].astype(dtype)).astype(jnp.float32), axis=-1)
    top_vals, top_idx = jax.lax.top_k(gates, k)
    top_vals = (top_vals / jnp.maximum(
        top_vals.sum(-1, keepdims=True), 1e-9)).astype(dtype)

    n_data = 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for a in token_axes:
        n_data *= sizes[a]
    s_loc = s // n_data
    cap = min(s_loc, int((s_loc * k / e) * cfg.capacity_factor) + 1)

    tok_spec = P(token_axes if s % n_data == 0 and s > 1 else None)

    def body(xf_l, idx_l, vals_l, w1_l, w3_l, w2_l):
        j = jax.lax.axis_index(model_axis)
        lo = j * e_loc
        s_l = xf_l.shape[0]
        flat_e = idx_l.reshape(s_l * k)
        flat_tok = jnp.repeat(jnp.arange(s_l), k)
        flat_w = vals_l.reshape(s_l * k)
        mine = (flat_e >= lo) & (flat_e < lo + e_loc)
        local_e = jnp.where(mine, flat_e - lo, e_loc)   # foreign -> E_loc
        order = jnp.argsort(local_e, stable=True)
        se_, st_, sw_ = local_e[order], flat_tok[order], flat_w[order]
        seg = jnp.searchsorted(se_, jnp.arange(e_loc + 1), side="left")
        rank = jnp.arange(s_l * k) - seg[jnp.minimum(se_, e_loc)]
        keep = (se_ < e_loc) & (rank < cap)
        slot = jnp.where(keep, se_ * cap + rank, e_loc * cap)
        buf = jnp.zeros((e_loc * cap + 1, xf_l.shape[1]), xf_l.dtype)
        buf = buf.at[slot].set(xf_l[st_] * keep[:, None].astype(xf_l.dtype))
        ein = buf[:-1].reshape(e_loc, cap, xf_l.shape[1])
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ein, w1_l))
        h = h * jnp.einsum("ecd,edf->ecf", ein, w3_l)
        oe = jnp.einsum("ecf,efd->ecd", h, w2_l)
        g = oe.reshape(e_loc * cap, -1)[jnp.minimum(slot,
                                                    e_loc * cap - 1)]
        g = g * (keep.astype(g.dtype) * sw_)[:, None]
        out_l = jnp.zeros_like(xf_l).at[st_].add(g)
        return jax.lax.psum(out_l, model_axis)

    out = shard_map(
        body, mesh=mesh,
        in_specs=(tok_spec, tok_spec, tok_spec,
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None)),
        out_specs=tok_spec,
        check_vma=False,
    )(xf, top_idx, top_vals, p["w1"].astype(dtype),
      p["w3"].astype(dtype), p["w2"].astype(dtype))

    if cfg.n_shared_experts:
        out = out + dense_forward(p["shared"], xf, dtype)
    return out.reshape(b, t, d)


def ffn_specs(cfg: ArchConfig, kind: str) -> Dict[str, ParamSpec]:
    return moe_specs(cfg) if kind == "moe" else dense_specs(cfg)


def ffn_forward(p, x: jnp.ndarray, cfg: ArchConfig, kind: str, dtype
                ) -> jnp.ndarray:
    if kind == "moe":
        from .common import _ACT_CTX

        ctx = _ACT_CTX.get()
        if ctx is not None and ctx["axes"].get("moe_ep"):
            token_axes, model_axis = ctx["axes"]["moe_ep"]
            return moe_forward_ep(p, x, cfg, dtype, ctx["mesh"],
                                  token_axes, model_axis)
        return moe_forward(p, x, cfg, dtype)
    return dense_forward(p, x, dtype)
