"""Attention blocks: GQA (full / sliding-window) and MLA (DeepSeek-V2).

Train/prefill paths use either the XLA reference (default — also what
the multi-pod dry-run lowers) or the Pallas flash kernel; decode paths
maintain KV caches.  MLA decodes in the *absorbed* form: the cache
stores only the compressed latent (kv_lora + rope dims per token) and
the up-projections are folded into the query/output sides — the reason
the serving-layer MQO assigns deepseek prefixes a ~9x smaller knapsack
weight than GQA archs.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.decode_attention.ref import decode_ref
from ..kernels.flash_attention.ref import mha_ref
from .common import ParamSpec, apply_rope, rmsnorm, rmsnorm_spec
from .config import ArchConfig


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------
def gqa_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, hd = cfg.d_model, cfg.head_dim
    return {
        "wq": ParamSpec((d, cfg.n_heads * hd), ("embed", "heads"), "lecun"),
        "wk": ParamSpec((d, cfg.n_kv_heads * hd), ("embed", "heads"),
                        "lecun"),
        "wv": ParamSpec((d, cfg.n_kv_heads * hd), ("embed", "heads"),
                        "lecun"),
        "wo": ParamSpec((cfg.n_heads * hd, d), ("heads", "embed"), "lecun"),
    }


def _split_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    b, t, _ = x.shape
    return x.reshape(b, t, n_heads, -1).transpose(0, 2, 1, 3)


def gqa_forward(p, x: jnp.ndarray, cfg: ArchConfig, *,
                window: Optional[int], positions: jnp.ndarray,
                dtype) -> jnp.ndarray:
    q = _split_heads(x @ p["wq"].astype(dtype), cfg.n_heads)
    k = _split_heads(x @ p["wk"].astype(dtype), cfg.n_kv_heads)
    v = _split_heads(x @ p["wv"].astype(dtype), cfg.n_kv_heads)
    q = apply_rope(q, positions[None, None, :], cfg.rope_theta)
    k = apply_rope(k, positions[None, None, :], cfg.rope_theta)
    if cfg.attn_impl == "pallas":
        from ..kernels.flash_attention.ops import attention

        out = attention(q, k, v, True, window, None, "pallas")
    else:
        out = mha_ref(q, k, v, causal=True, window=window)
    b, h, t, hd = out.shape
    out = out.transpose(0, 2, 1, 3).reshape(b, t, h * hd)
    return out @ p["wo"].astype(dtype)


def gqa_init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype
                   ) -> Dict[str, jnp.ndarray]:
    shape = (batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_decode(p, x: jnp.ndarray, cache: Dict, write_idx: jnp.ndarray,
               cfg: ArchConfig, *, window: Optional[int], dtype,
               rope_pos: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, 1, d); write_idx: () int32 cache slot for the new token;
    rope_pos: absolute position (defaults to write_idx — they differ for
    rolling sliding-window caches)."""
    b = x.shape[0]
    if rope_pos is None:
        rope_pos = write_idx
    q = _split_heads(x @ p["wq"].astype(dtype), cfg.n_heads)[:, :, 0]
    k = _split_heads(x @ p["wk"].astype(dtype), cfg.n_kv_heads)
    v = _split_heads(x @ p["wv"].astype(dtype), cfg.n_kv_heads)
    pos = jnp.full((1, 1, 1), 0, jnp.int32) + rope_pos
    q = apply_rope(q[:, :, None, :], pos, cfg.rope_theta)[:, :, 0]
    k = apply_rope(k, pos, cfg.rope_theta)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, write_idx,
                                                axis=2)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, write_idx,
                                                axis=2)
    kv_len = jnp.full((b,), write_idx + 1, jnp.int32)
    if cfg.attn_impl == "pallas":
        from ..kernels.decode_attention.ops import decode

        out = decode(q, new_k, new_v, kv_len, window=window)
    else:
        out = decode_ref(q, new_k, new_v, kv_len, window=window)
    out = out.reshape(b, 1, -1)
    return out @ p["wo"].astype(dtype), {"k": new_k, "v": new_v}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------
def mla_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, h = cfg.d_model, cfg.n_heads
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    specs: Dict[str, ParamSpec] = {
        "kv_down": ParamSpec((d, r_kv + rope), ("embed", None), "lecun"),
        "kv_norm": rmsnorm_spec(r_kv),
        "k_up": ParamSpec((r_kv, h * nope), (None, "heads"), "lecun"),
        "v_up": ParamSpec((r_kv, h * vd), (None, "heads"), "lecun"),
        "wo": ParamSpec((h * vd, d), ("heads", "embed"), "lecun"),
    }
    if r_q:
        specs["q_down"] = ParamSpec((d, r_q), ("embed", None), "lecun")
        specs["q_norm"] = rmsnorm_spec(r_q)
        specs["q_up"] = ParamSpec((r_q, h * (nope + rope)),
                                  (None, "heads"), "lecun")
    else:
        specs["q_up"] = ParamSpec((d, h * (nope + rope)),
                                  ("embed", "heads"), "lecun")
    return specs


def _mla_q(p, x, cfg: ArchConfig, dtype):
    if cfg.q_lora_rank:
        cq = rmsnorm(p["q_norm"], x @ p["q_down"].astype(dtype),
                     cfg.norm_eps)
        q = cq @ p["q_up"].astype(dtype)
    else:
        q = x @ p["q_up"].astype(dtype)
    b, t, _ = q.shape
    q = q.reshape(b, t, cfg.n_heads, cfg.qk_nope_dim + cfg.qk_rope_dim)
    return q.transpose(0, 2, 1, 3)      # (B, H, T, nope+rope)


def mla_forward(p, x: jnp.ndarray, cfg: ArchConfig, *,
                positions: jnp.ndarray, dtype) -> jnp.ndarray:
    b, t, d = x.shape
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = _mla_q(p, x, cfg, dtype)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions[None, None, :], cfg.rope_theta)

    ckv_full = x @ p["kv_down"].astype(dtype)          # (B, T, r+rope)
    ckv = rmsnorm(p["kv_norm"], ckv_full[..., : cfg.kv_lora_rank],
                  cfg.norm_eps)
    k_rope = apply_rope(ckv_full[..., cfg.kv_lora_rank:][:, None],
                        positions[None, None, :], cfg.rope_theta)
    k_nope = (ckv @ p["k_up"].astype(dtype)).reshape(
        b, t, cfg.n_heads, nope).transpose(0, 2, 1, 3)
    v = (ckv @ p["v_up"].astype(dtype)).reshape(
        b, t, cfg.n_heads, vd).transpose(0, 2, 1, 3)

    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, cfg.n_heads, t, rope))],
        axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    sm_scale = 1.0 / ((nope + rope) ** 0.5)
    out = mha_ref(q_full, k, v, causal=True, sm_scale=sm_scale)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, cfg.n_heads * vd)
    return out @ p["wo"].astype(dtype)


def mla_init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }


def mla_decode(p, x: jnp.ndarray, cache: Dict, cur_len, cfg: ArchConfig,
               *, dtype) -> Tuple[jnp.ndarray, Dict]:
    """Absorbed MLA decode over the compressed latent cache."""
    b = x.shape[0]
    h = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank

    q = _mla_q(p, x, cfg, dtype)[:, :, 0]               # (B, H, nope+rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    pos = jnp.zeros((1, 1, 1), jnp.int32) + cur_len
    q_rope = apply_rope(q_rope[:, :, None, :], pos,
                        cfg.rope_theta)[:, :, 0]

    ckv_full = x @ p["kv_down"].astype(dtype)           # (B, 1, r+rope)
    ckv_new = rmsnorm(p["kv_norm"], ckv_full[..., :r], cfg.norm_eps)
    k_rope_new = apply_rope(ckv_full[..., r:][:, None], pos,
                            cfg.rope_theta)[:, 0]
    new_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv_new, cur_len, axis=1)
    new_krope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new, cur_len, axis=1)

    # absorb k_up into q: (B, H, nope) x (r, H, nope) -> (B, H, r)
    k_up = p["k_up"].astype(dtype).reshape(r, h, nope)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope, k_up)
    s = jnp.einsum("bhr,bsr->bhs", q_lat, new_ckv)
    s += jnp.einsum("bhr,bsr->bhs", q_rope, new_krope)
    s = s.astype(jnp.float32) / ((nope + rope) ** 0.5)
    mask = jnp.arange(new_ckv.shape[1])[None, None] <= cur_len
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(dtype)
    ctx = jnp.einsum("bhs,bsr->bhr", w, new_ckv)        # (B, H, r)
    v_up = p["v_up"].astype(dtype).reshape(r, h, vd)
    out = jnp.einsum("bhr,rhv->bhv", ctx, v_up)
    out = out.reshape(b, 1, h * vd)
    return out @ p["wo"].astype(dtype), {"ckv": new_ckv,
                                         "k_rope": new_krope}
