"""Mamba-1 selective SSM block (falcon-mamba-7b).

Time recurrence runs as a lax.scan over the sequence (compact HLO for
the dry-run; the chunked parallel-scan kernel is a recorded follow-up
in EXPERIMENTS.md §Perf).  Decode keeps an O(1)-size state per layer:
(conv window, SSM state) — which is also why the serving-layer MQO
gives SSM prefixes a near-zero knapsack weight.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ParamSpec
from .config import ArchConfig


def mamba_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, di, st = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dr = cfg.dt_rank_actual
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "ffn"), "lecun"),
        "conv_w": ParamSpec((di, cfg.d_conv), ("ffn", None), "lecun"),
        "conv_b": ParamSpec((di,), ("ffn",), "zeros"),
        "x_proj": ParamSpec((di, dr + 2 * st), ("ffn", None), "lecun"),
        "dt_proj": ParamSpec((dr, di), (None, "ffn"), "lecun"),
        "dt_bias": ParamSpec((di,), ("ffn",), "zeros"),
        "A_log": ParamSpec((di, st), ("ffn", None), "ones"),
        "D": ParamSpec((di,), ("ffn",), "ones"),
        "out_proj": ParamSpec((di, d), ("ffn", "embed"), "lecun"),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                 ) -> jnp.ndarray:
    """Depthwise causal conv over time.  x: (B, T, di); w: (di, K)."""
    di, kk = w.shape
    xt = x.transpose(0, 2, 1)                          # (B, di, T)
    xt = jnp.pad(xt, ((0, 0), (0, 0), (kk - 1, 0)))
    out = jax.lax.conv_general_dilated(
        xt, w[:, None, :],                             # (di, 1, K)
        window_strides=(1,), padding="VALID",
        feature_group_count=di,
        dimension_numbers=("NCH", "OIH", "NCH"))
    return (out + b[None, :, None]).transpose(0, 2, 1)


def _ssm_scan(dt, Bm, Cm, x_in, A, D):
    """dt, x_in: (B, T, di); Bm, Cm: (B, T, st); A: (di, st)."""
    da = jnp.exp(dt[..., None] * A)                    # (B, T, di, st)
    db_x = (dt * x_in)[..., None] * Bm[:, :, None, :]  # (B, T, di, st)

    def step(h, inp):
        da_t, dbx_t, c_t = inp
        h = da_t * h + dbx_t
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    b, t, di, st = da.shape
    h0 = jnp.zeros((b, di, st), da.dtype)
    xs = (da.transpose(1, 0, 2, 3), db_x.transpose(1, 0, 2, 3),
          Cm.transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2)                          # (B, T, di)
    return y + x_in * D


def mamba_forward(p, x: jnp.ndarray, cfg: ArchConfig, dtype
                  ) -> jnp.ndarray:
    xz = x @ p["in_proj"].astype(dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = jax.nn.silu(_causal_conv(x_in, p["conv_w"].astype(dtype),
                                    p["conv_b"].astype(dtype)))
    proj = x_in @ p["x_proj"].astype(dtype)
    dr, st = cfg.dt_rank_actual, cfg.ssm_state
    dt, Bm, Cm = jnp.split(proj, [dr, dr + st], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(dtype)
                         + p["dt_bias"].astype(dtype))
    A = -jnp.exp(p["A_log"].astype(jnp.float32)).astype(dtype)
    y = _ssm_scan(dt, Bm, Cm, x_in, A, p["D"].astype(dtype))
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(dtype)


def mamba_init_cache(cfg: ArchConfig, batch: int, dtype) -> Dict:
    di = cfg.d_inner
    return {
        "conv": jnp.zeros((batch, di, cfg.d_conv), dtype),
        "ssm": jnp.zeros((batch, di, cfg.ssm_state), dtype),
    }


def mamba_decode(p, x: jnp.ndarray, cache: Dict, cfg: ArchConfig, dtype
                 ) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, 1, d) -> (B, 1, d); O(1) state update."""
    b = x.shape[0]
    xz = x[:, 0] @ p["in_proj"].astype(dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)                # (B, di)

    conv = jnp.concatenate([cache["conv"][:, :, 1:], x_in[:, :, None]],
                           axis=2)                     # (B, di, K)
    x_c = jnp.einsum("bdk,dk->bd", conv, p["conv_w"].astype(dtype))
    x_c = jax.nn.silu(x_c + p["conv_b"].astype(dtype))

    proj = x_c @ p["x_proj"].astype(dtype)
    dr, st = cfg.dt_rank_actual, cfg.ssm_state
    dt, Bm, Cm = jnp.split(proj, [dr, dr + st], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(dtype)
                         + p["dt_bias"].astype(dtype))   # (B, di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32)).astype(dtype)
    da = jnp.exp(dt[..., None] * A)                      # (B, di, st)
    h = da * cache["ssm"] + (dt * x_c)[..., None] * Bm[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, Cm) + x_c * p["D"].astype(dtype)
    y = y * jax.nn.silu(z)
    out = (y @ p["out_proj"].astype(dtype))[:, None]
    return out, {"conv": conv, "ssm": h}
