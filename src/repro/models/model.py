"""Language model wrapper: embeddings, loss, train/serve step builders.

``input_specs`` provides ShapeDtypeStruct stand-ins for every input of
each (config × shape) cell — weak-type-correct, shardable, and never
allocated — which is what the multi-pod dry-run lowers against.
Modality frontends (VLM patches / audio frames) are STUBS per the
assignment: precomputed (B, n_prefix, d_model) embeddings arrive as an
input.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import (ParamSpec, cross_entropy, materialize_params,
                     rmsnorm, rmsnorm_spec)
from .config import ArchConfig
from .decoder import (decoder_decode_step, decoder_forward, decoder_specs,
                      init_cache)


def model_specs(cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    specs: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_size, d), ("vocab", "embed"),
                           "normal"),
        "final_norm": rmsnorm_spec(d),
        "layers": decoder_specs(cfg),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((d, cfg.vocab_size),
                                     ("embed", "vocab"), "lecun")
    return specs


def init_params(cfg: ArchConfig, seed: int = 0):
    return materialize_params(model_specs(cfg), seed)


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------
def forward(params, tokens: jnp.ndarray, cfg: ArchConfig,
            prefix_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """tokens: (B, T_tok) int32 -> logits (B, T, V)."""
    from .common import constrain

    dtype = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(dtype)[tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(dtype), x], axis=1)
    x = constrain(x, ("batch", None, None))
    t = x.shape[1]
    positions = jnp.arange(t, dtype=jnp.int32)
    x = decoder_forward(params["layers"], x, cfg, positions, dtype)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].astype(dtype).T
    else:
        logits = x @ params["unembed"].astype(dtype)
    return constrain(logits, ("batch", None, "vocab"))


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig
            ) -> jnp.ndarray:
    logits = forward(params, batch["tokens"], cfg,
                     prefix_embeds=batch.get("prefix_embeds"))
    labels, mask = batch["labels"], batch.get("mask")
    return cross_entropy(logits[:, : labels.shape[1]], labels, mask)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def decode_step(params, cache, token: jnp.ndarray, cur_len, cfg: ArchConfig
                ) -> Tuple[jnp.ndarray, Any]:
    """token: (B, 1) int32; returns (logits (B, V), new cache)."""
    dtype = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(dtype)[token]
    x, new_cache = decoder_decode_step(params["layers"], cache, x,
                                       cur_len, cfg, dtype)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].astype(dtype).T
    else:
        logits = x @ params["unembed"].astype(dtype)
    return logits[:, 0], new_cache


# ---------------------------------------------------------------------------
# dry-run input specs per assignment shape
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeCell:
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    step: str                 # "train" | "prefill" | "decode"


SHAPES = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def input_specs(cfg: ArchConfig, shape: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for each input of the step function."""
    b, t = shape.global_batch, shape.seq_len
    if shape.step == "train":
        n_tok = t - cfg.n_prefix_tokens
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, n_tok), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, t), jnp.int32),
            "mask": jax.ShapeDtypeStruct((b, t), jnp.float32),
        }
        if cfg.n_prefix_tokens:
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_prefix_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
        return specs
    if shape.step == "prefill":
        n_tok = t - cfg.n_prefix_tokens
        specs = {"tokens": jax.ShapeDtypeStruct((b, n_tok), jnp.int32)}
        if cfg.n_prefix_tokens:
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_prefix_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
        return specs
    # decode: one new token against a KV/state cache of length t
    return {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
