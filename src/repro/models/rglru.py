"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Real-Gated Linear Recurrent Unit: per-channel learned decay gated by
the input, h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t), inside
a gated two-branch block with a short causal conv.  Decode state is
O(1) per layer (conv window + h).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ParamSpec
from .config import ArchConfig
from .ssm import _causal_conv

_C = 8.0  # Griffin's recurrence sharpness constant


def rglru_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, w = cfg.d_model, cfg.lru_width_actual
    return {
        "in_x": ParamSpec((d, w), ("embed", "ffn"), "lecun"),
        "in_gate": ParamSpec((d, w), ("embed", "ffn"), "lecun"),
        "conv_w": ParamSpec((w, cfg.d_conv), ("ffn", None), "lecun"),
        "conv_b": ParamSpec((w,), ("ffn",), "zeros"),
        "w_input_gate": ParamSpec((w, w), ("ffn", None), "lecun"),
        "w_rec_gate": ParamSpec((w, w), ("ffn", None), "lecun"),
        "lam": ParamSpec((w,), ("ffn",), "ones"),
        "out": ParamSpec((w, d), ("ffn", "embed"), "lecun"),
    }


def _gates(p, xc, dtype):
    i_t = jax.nn.sigmoid(xc @ p["w_input_gate"].astype(dtype))
    r_t = jax.nn.sigmoid(xc @ p["w_rec_gate"].astype(dtype))
    log_a = (-_C * jax.nn.softplus(p["lam"].astype(jnp.float32))
             * r_t.astype(jnp.float32))
    a_t = jnp.exp(log_a).astype(dtype)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)
                    ).astype(dtype)
    return i_t, a_t, beta


def rglru_forward(p, x: jnp.ndarray, cfg: ArchConfig, dtype
                  ) -> jnp.ndarray:
    xb = x @ p["in_x"].astype(dtype)                   # (B, T, w)
    gate = jax.nn.gelu(x @ p["in_gate"].astype(dtype))
    xc = _causal_conv(xb, p["conv_w"].astype(dtype),
                      p["conv_b"].astype(dtype))
    i_t, a_t, beta = _gates(p, xc, dtype)
    gx = beta * (i_t * xc)

    def step(h, inp):
        a, b_ = inp
        h = a * h + b_
        return h, h

    b, t, w = xc.shape
    h0 = jnp.zeros((b, w), dtype)
    _, hs = jax.lax.scan(step, h0,
                         (a_t.transpose(1, 0, 2), gx.transpose(1, 0, 2)))
    h_seq = hs.transpose(1, 0, 2)
    return (h_seq * gate) @ p["out"].astype(dtype)


def rglru_init_cache(cfg: ArchConfig, batch: int, dtype) -> Dict:
    w = cfg.lru_width_actual
    return {
        "conv": jnp.zeros((batch, w, cfg.d_conv), dtype),
        "h": jnp.zeros((batch, w), dtype),
    }


def rglru_decode(p, x: jnp.ndarray, cache: Dict, cfg: ArchConfig, dtype
                 ) -> Tuple[jnp.ndarray, Dict]:
    xb = (x[:, 0] @ p["in_x"].astype(dtype))           # (B, w)
    gate = jax.nn.gelu(x[:, 0] @ p["in_gate"].astype(dtype))
    conv = jnp.concatenate([cache["conv"][:, :, 1:], xb[:, :, None]],
                           axis=2)
    xc = jnp.einsum("bdk,dk->bd", conv, p["conv_w"].astype(dtype))
    xc = xc + p["conv_b"].astype(dtype)
    i_t, a_t, beta = _gates(p, xc, dtype)
    h = a_t * cache["h"] + beta * (i_t * xc)
    out = ((h * gate) @ p["out"].astype(dtype))[:, None]
    return out, {"conv": conv, "h": h}
