"""Architecture configuration for the LM substrate.

One frozen dataclass covers all 10 assigned families (dense / MoE /
MLA / SSM / hybrid / VLM / audio).  Layers are described by a repeating
``pattern`` of block kinds; the decoder scans over full pattern repeats
and unrolls the remainder, so heterogeneous stacks (gemma3 5:1
local:global, recurrentgemma 2:1 RG-LRU:attn) still lower to compact
HLO.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

BLOCK_KINDS = ("attn", "local", "mla", "mamba", "rglru")


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    pattern: Tuple[str, ...] = ("attn",)
    window: Optional[int] = None     # sliding window for "local" blocks
    ffn_kind: str = "dense"          # dense|moe
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    first_k_dense: int = 0           # leading layers with dense FFN
    # --- MLA (deepseek) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # --- SSM (mamba1) ---
    ssm_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                 # 0 -> ceil(d_model / 16)
    # --- RG-LRU (griffin) ---
    lru_width: int = 0               # 0 -> d_model
    # --- modality frontend stub ---
    frontend: Optional[str] = None   # None|vision|audio
    n_prefix_tokens: int = 0         # precomputed frontend embeddings
    # --- numerics / misc ---
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # --- distribution knobs (overridden by launch/sharding.py rules) ---
    fsdp_params: bool = False        # ZeRO-3 over the data axis
    remat: str = "block"             # none|block|full
    scan_layers: bool = True
    attn_impl: str = "xla"           # xla|pallas

    # ---- derived -----------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank_actual(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def lru_width_actual(self) -> int:
        return self.lru_width or self.d_model

    @property
    def full_repeats(self) -> int:
        return self.scanned_layers // len(self.pattern)

    @property
    def scanned_layers(self) -> int:
        body = self.n_layers - self.first_k_dense
        return body - (body % len(self.pattern))

    @property
    def remainder_layers(self) -> int:
        return (self.n_layers - self.first_k_dense) % len(self.pattern)

    @property
    def qk_head_dim(self) -> int:
        """Per-head q/k dim (MLA: nope + rope)."""
        if self.kv_lora_rank:
            return self.qk_nope_dim + self.qk_rope_dim
        return self.head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Block kind of every layer, in order."""
        kinds = []
        for i in range(self.n_layers - self.first_k_dense):
            kinds.append(self.pattern[i % len(self.pattern)])
        prefix = tuple(self.pattern[0] for _ in range(self.first_k_dense))
        return prefix + tuple(kinds)

    def ffn_kind_for_layer(self, layer: int) -> str:
        if self.ffn_kind == "moe" and layer >= self.first_k_dense:
            return "moe"
        return "dense"

    # ---- parameter counting (for roofline MODEL_FLOPS) ---------------------
    def param_count(self) -> Tuple[int, int]:
        """(total_params, active_params) excluding negligible norms."""
        d = self.d_model
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = active = emb
        for kind in self.layer_kinds():
            if kind in ("attn", "local"):
                if self.kv_lora_rank:  # MLA
                    q_in = (self.q_lora_rank or d)
                    p = (d * self.q_lora_rank if self.q_lora_rank else 0)
                    p += q_in * self.n_heads * (self.qk_nope_dim
                                                + self.qk_rope_dim)
                    p += d * (self.kv_lora_rank + self.qk_rope_dim)
                    p += self.kv_lora_rank * self.n_heads * (
                        self.qk_nope_dim + self.v_head_dim)
                    p += self.n_heads * self.v_head_dim * d
                else:
                    p = d * self.n_heads * self.head_dim          # Wq
                    p += 2 * d * self.n_kv_heads * self.head_dim  # Wk, Wv
                    p += self.n_heads * self.head_dim * d         # Wo
                total += p
                active += p
            elif kind == "mamba":
                di = self.d_inner
                p = d * 2 * di + di * self.d_conv
                p += di * (self.dt_rank_actual + 2 * self.ssm_state)
                p += self.dt_rank_actual * di + di * self.ssm_state + di
                p += di * d
                total += p
                active += p
            elif kind == "rglru":
                w = self.lru_width_actual
                p = 2 * d * w + w * self.d_conv + 3 * w * w + w + w * d
                total += p
                active += p
            # FFN for transformer-ish blocks
            if kind in ("attn", "local"):
                pass
        # FFNs (attn/local blocks have one each; mamba/rglru do not)
        for li, kind in enumerate(self.layer_kinds()):
            if kind in ("mamba",):
                continue
            if kind == "rglru":
                # griffin: every block has an MLP
                ffn_t = ffn_a = 3 * d * self.d_ff
            elif self.ffn_kind_for_layer(li) == "moe":
                e_p = 3 * d * self.d_ff_expert
                ffn_t = self.n_experts * e_p + self.n_shared_experts * e_p
                ffn_a = (self.top_k + self.n_shared_experts) * e_p
            else:
                ffn_t = ffn_a = 3 * d * self.d_ff
            total += ffn_t
            active += ffn_a
        return total, active


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    pat = len(cfg.pattern)
    n_layers = cfg.first_k_dense + max(pat, 2 if pat == 1 else pat) + 1
    return replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, n_layers),
        d_model=128,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=32,
        d_ff=256,
        d_ff_expert=64 if cfg.n_experts else 0,
        n_experts=min(cfg.n_experts, 8),
        top_k=min(cfg.top_k, 2),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        vocab_size=512,
        kv_lora_rank=32 if cfg.kv_lora_rank else 0,
        q_lora_rank=48 if cfg.q_lora_rank else 0,
        qk_nope_dim=32 if cfg.kv_lora_rank else cfg.qk_nope_dim,
        qk_rope_dim=16 if cfg.kv_lora_rank else cfg.qk_rope_dim,
        v_head_dim=32 if cfg.kv_lora_rank else cfg.v_head_dim,
        window=min(cfg.window, 64) if cfg.window else None,
        lru_width=64 if cfg.family == "hybrid" else 0,
        expand=cfg.expand,
        n_prefix_tokens=8 if cfg.n_prefix_tokens else 0,
        dtype="float32",
        scan_layers=True,
    )
