"""Shared LM building blocks: params-as-pytrees, RMSNorm, RoPE, CE loss.

Parameters are plain dicts of arrays.  Every leaf is declared through
``ParamSpec`` (shape, logical axes, init) so the same definition serves
three uses: CPU smoke materialization, abstract dry-run lowering
(ShapeDtypeStruct only), and mesh sharding (logical axes -> mesh axes
via launch/sharding.py rules).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]   # e.g. ("embed", "ffn")
    init: str = "normal"                      # normal|zeros|ones|lecun
    scale: float = 1.0
    dtype: str = "float32"

    def materialize(self, key) -> jnp.ndarray:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        fan_in = self.shape[0] if len(self.shape) >= 1 else 1
        if self.init == "lecun":
            std = (1.0 / max(fan_in, 1)) ** 0.5
        else:
            std = 0.02
        return (jax.random.normal(key, self.shape, jnp.float32)
                * std * self.scale).astype(self.dtype)

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


ParamTree = Dict
SpecTree = Dict


def materialize_params(specs: SpecTree, seed: int = 0) -> ParamTree:
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(jax.random.PRNGKey(seed), max(len(leaves), 1))
    out = [spec.materialize(k) for spec, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def abstract_params(specs: SpecTree) -> ParamTree:
    return jax.tree.map(lambda s: s.abstract(), specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# normalization / rope / embedding
# ---------------------------------------------------------------------------
def rmsnorm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), (None,), init="ones")


def rmsnorm(w: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6
            ) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
            ).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (..., T, D) with D even; positions: (..., T) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., T, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean CE over (B, T, V) logits and (B, T) int labels, f32 math.

    The gold logit is extracted with a one-hot contraction rather than
    take_along_axis: a gather across a vocab-sharded dimension forces
    the partitioner to all-gather the logits, while the contraction
    partitions into per-shard partial sums + a scalar-sized all-reduce.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    onehot = (labels[..., None]
              == jnp.arange(lf.shape[-1], dtype=labels.dtype))
    gold = jnp.einsum("btv,btv->bt", lf,
                      onehot.astype(jnp.float32))
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def shard_activation(x: jnp.ndarray, spec, enabled: bool) -> jnp.ndarray:
    """with_sharding_constraint guarded for meshless (smoke) execution."""
    if not enabled:
        return x
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(
        x, P(*spec) if not isinstance(spec, P) else spec)


# ---------------------------------------------------------------------------
# activation-sharding context (perf: constrains the SPMD partitioner)
# ---------------------------------------------------------------------------
import contextvars
from contextlib import contextmanager

_ACT_CTX = contextvars.ContextVar("repro_act_sharding", default=None)


@contextmanager
def activation_sharding(mesh, **logical_axes):
    """Trace-time context: ``constrain(x, ("tokens", None))`` inserts
    with_sharding_constraint(NamedSharding(mesh, P(axes["tokens"], None)))
    — a no-op outside the context, so smoke tests and single-device
    paths are untouched.  Set by the dry-run / launchers.

    logical_axes example: tokens=("pod","data"), experts="model",
    model="model".
    """
    token = _ACT_CTX.set({"mesh": mesh, "axes": logical_axes})
    try:
        yield
    finally:
        _ACT_CTX.reset(token)


def constrain(x: jnp.ndarray, dims: Tuple[Optional[str], ...]
              ) -> jnp.ndarray:
    """Constrain each dim to the mesh axes bound to its logical name."""
    ctx = _ACT_CTX.get()
    if ctx is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = ctx["mesh"]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = []
    used = set()
    for dim, name in zip(x.shape, dims):
        ax = ctx["axes"].get(name) if name else None
        if ax is None:
            spec.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        axes = tuple(a for a in axes if a in sizes and a not in used)
        prod = 1
        for a in axes:
            prod *= sizes[a]
        if axes and dim % prod == 0:
            spec.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
