"""Assigned architecture config: phi4-mini-3.8b (see registry.py)."""
from .registry import get_config

CONFIG = get_config("phi4-mini-3.8b")
SMOKE = get_config("phi4-mini-3.8b-smoke")
