"""Assigned architecture config: gemma3-12b (see registry.py)."""
from .registry import get_config

CONFIG = get_config("gemma3-12b")
SMOKE = get_config("gemma3-12b-smoke")
