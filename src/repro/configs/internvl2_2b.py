"""Assigned architecture config: internvl2-2b (see registry.py)."""
from .registry import get_config

CONFIG = get_config("internvl2-2b")
SMOKE = get_config("internvl2-2b-smoke")
