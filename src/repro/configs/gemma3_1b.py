"""Assigned architecture config: gemma3-1b (see registry.py)."""
from .registry import get_config

CONFIG = get_config("gemma3-1b")
SMOKE = get_config("gemma3-1b-smoke")
