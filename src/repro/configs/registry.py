"""Assigned architecture registry (10 archs, exact assignment configs).

Each entry is the FULL config from the public source noted in the
assignment; ``smoke_variant`` derives the reduced CPU-test config.
``--arch <id>`` in the launchers resolves through ``get_config``.
"""
from __future__ import annotations

from typing import Dict

from ..models.config import ArchConfig, smoke_variant

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return smoke_variant(get_config(name[: -len("-smoke")]))
    return _REGISTRY[name]


def list_configs():
    return sorted(_REGISTRY)


# --- llama4-scout-17b-a16e [moe]: 48L d5120 40H (kv8) MoE 16e top-1 ------
register(ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202_048,
    ffn_kind="moe", n_experts=16, top_k=1, d_ff_expert=8192,
    n_shared_experts=1,
    pattern=("attn",), rope_theta=500_000.0, fsdp_params=True,
))

# --- deepseek-v2-236b [moe]: 60L d5120 128H MLA kv_lora 512, 160e top-6 --
register(ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=12_288, vocab_size=102_400,
    pattern=("mla",),
    kv_lora_rank=512, q_lora_rank=1536, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    ffn_kind="moe", n_experts=160, top_k=6, d_ff_expert=1536,
    n_shared_experts=2, first_k_dense=1,
    fsdp_params=True,
))

# --- gemma3-1b [dense]: 26L d1152 4H (kv1) d_ff 6912, 5:1 local:global ---
register(ArchConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262_144,
    pattern=("local", "local", "local", "local", "local", "attn"),
    window=512, tie_embeddings=True, rope_theta=1_000_000.0,
))

# --- granite-8b [dense]: 36L d4096 32H (kv8) d_ff 14336 -------------------
register(ArchConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14_336, vocab_size=49_152,
    pattern=("attn",), rope_theta=10_000_000.0,
))

# --- phi4-mini-3.8b [dense]: 32L d3072 24H (kv8) d_ff 8192 ----------------
register(ArchConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=200_064,
    pattern=("attn",),
))

# --- gemma3-12b [dense]: 48L d3840 16H (kv8), 5:1 local:global ------------
register(ArchConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15_360, vocab_size=262_144,
    pattern=("local", "local", "local", "local", "local", "attn"),
    window=1024, tie_embeddings=True, rope_theta=1_000_000.0,
))

# --- falcon-mamba-7b [ssm]: 64L d4096 attn-free, ssm_state 16 -------------
register(ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=65_024,
    pattern=("mamba",), ssm_state=16, d_conv=4, expand=2,
))

# --- internvl2-2b [vlm]: InternLM2 backbone 24L d2048 16H (kv8) -----------
register(ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=92_553,
    pattern=("attn",),
    frontend="vision", n_prefix_tokens=256,   # precomputed ViT patches
))

# --- musicgen-large [audio]: 48L d2048 32H (kv32 = MHA) over EnCodec ------
register(ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048,
    pattern=("attn",),
    frontend="audio", n_prefix_tokens=128,    # conditioning frames
))

# --- recurrentgemma-9b [hybrid]: 38L d4096 16H (kv1), RG-LRU:attn 2:1 -----
register(ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12_288, vocab_size=256_000,
    pattern=("rglru", "rglru", "local"), window=2048,
    lru_width=4096, tie_embeddings=True,
))

ALL_ARCHS = tuple(list_configs())
