"""Assigned architecture config: deepseek-v2-236b (see registry.py)."""
from .registry import get_config

CONFIG = get_config("deepseek-v2-236b")
SMOKE = get_config("deepseek-v2-236b-smoke")
