from .registry import ALL_ARCHS, get_config, list_configs, register
