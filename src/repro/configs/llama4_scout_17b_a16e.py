"""Assigned architecture config: llama4-scout-17b-a16e (see registry.py)."""
from .registry import get_config

CONFIG = get_config("llama4-scout-17b-a16e")
SMOKE = get_config("llama4-scout-17b-a16e-smoke")
