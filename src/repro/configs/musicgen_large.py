"""Assigned architecture config: musicgen-large (see registry.py)."""
from .registry import get_config

CONFIG = get_config("musicgen-large")
SMOKE = get_config("musicgen-large-smoke")
