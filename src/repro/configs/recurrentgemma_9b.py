"""Assigned architecture config: recurrentgemma-9b (see registry.py)."""
from .registry import get_config

CONFIG = get_config("recurrentgemma-9b")
SMOKE = get_config("recurrentgemma-9b-smoke")
