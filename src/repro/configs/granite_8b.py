"""Assigned architecture config: granite-8b (see registry.py)."""
from .registry import get_config

CONFIG = get_config("granite-8b")
SMOKE = get_config("granite-8b-smoke")
