"""Assigned architecture config: falcon-mamba-7b (see registry.py)."""
from .registry import get_config

CONFIG = get_config("falcon-mamba-7b")
SMOKE = get_config("falcon-mamba-7b-smoke")
