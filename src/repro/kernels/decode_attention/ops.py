"""Public jit'd wrapper for flash-decode (no VJP needed — inference)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import decode_attention
from .ref import decode_ref


def decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
           kv_len: jnp.ndarray, *, sm_scale: Optional[float] = None,
           window: Optional[int] = None,
           impl: str = "pallas") -> jnp.ndarray:
    if impl == "pallas":
        return decode_attention(
            q, k, v, kv_len, sm_scale=sm_scale, window=window,
            interpret=jax.default_backend() != "tpu")
    return decode_ref(q, k, v, kv_len, sm_scale=sm_scale, window=window)
