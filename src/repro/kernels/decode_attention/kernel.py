"""Flash-decode: single-token GQA attention over a (paged) KV cache.

Decode is memory-bound (one query token vs an S-long cache), so the
kernel streams K/V blocks HBM → VMEM once and keeps all ``group`` query
heads of a kv-head resident, amortizing each K/V byte across the GQA
group — the TPU-native adaptation of flash-decode.  Grid
(B, Hkv, S/Bk) with the cache dim sequential; online-softmax scratch
(m, l, acc) sized (group, ·); live-length masking via a scalar
prefetch-style (1,) block carrying kv_len[b].
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_body(block_k: int, n_kv_blocks: int, group: int, scale: float,
                 window: Optional[int],
                 len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                 acc_ref):
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[0]
    k0 = jk * block_k

    run = k0 < kv_len
    if window is not None:
        run = jnp.logical_and(run, k0 + block_k > kv_len - window)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)              # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (Bk, D)
        v = v_ref[0, 0].astype(jnp.float32)           # (Bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, Bk)
        cols = k0 + jax.lax.broadcasted_iota(jnp.int32,
                                             (q.shape[0], block_k), 1)
        mask = cols < kv_len
        if window is not None:
            mask &= cols >= kv_len - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1,
                                                  keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(jk == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("sm_scale", "window", "block_k", "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     kv_len: jnp.ndarray, *,
                     sm_scale: Optional[float] = None,
                     window: Optional[int] = None, block_k: int = 128,
                     interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, D); k, v: (B, Hkv, S, D); kv_len: (B,) live lengths."""
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    assert hq % hkv == 0 and s % block_k == 0
    group = hq // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    n_kv_blocks = s // block_k
    grid = (b, hkv, n_kv_blocks)

    kernel = functools.partial(_decode_body, block_k, n_kv_blocks, group,
                               scale, window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h, j: (b_,)),            # kv_len
            pl.BlockSpec((1, group, d), lambda b_, h, j: (b_, h, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, j: (b_, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, j: (b_, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, group, d), lambda b_, h, j: (b_, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), q, k, v)
