"""Pure-jnp oracle for flash-decode: re-exports the decode reference."""
from ..flash_attention.ref import decode_ref  # noqa: F401
