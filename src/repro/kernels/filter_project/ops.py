"""Jit'd public wrappers for the fused filter-scan kernel.

``compile_predicate`` lowers a relational Expr into the kernel's static
postfix program, so the relational engine can execute covering-
expression predicates through the Pallas path (``use_pallas=True`` in
the engine; interpret mode on CPU, compiled on TPU).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from ...relational import expr as E
from .kernel import DEFAULT_BLOCK, filter_scan, parse_i32
from .ref import PredProgram, filter_scan_ref

_OPMAP = {"<": "lt", "<=": "le", ">": "gt", ">=": "ge", "==": "eq",
          "!=": "ne"}


def compile_predicate(pred: E.Expr, col_names: Sequence[str]
                      ) -> PredProgram:
    """Relational Expr -> static postfix program over numeric columns.

    Supports col-const and col-col compares over i32/f32 columns (the
    program IR promotes mixed dtypes to f32 — see ref.PredProgram).
    String predicates raise ValueError; referencing a column outside
    ``col_names`` (e.g. a string column in a col-col compare) raises
    KeyError — callers pass the *numeric* column set so both cases fall
    back to the XLA path.
    """
    idx = {n: i for i, n in enumerate(col_names)}
    prog: List[tuple] = []

    def walk(e: E.Expr):
        if isinstance(e, E.Cmp):
            e = E.oriented(e)
            if isinstance(e.col, E.Lit):
                raise ValueError("constant compare unsupported in kernel")
            if isinstance(e.rhs, E.Col):
                prog.append((_OPMAP[e.op] + "c", idx[e.col.name],
                             idx[e.rhs.name]))
                return
            v = e.rhs.value
            if isinstance(v, (bytes, str)):
                raise ValueError("string predicates unsupported in kernel")
            prog.append((_OPMAP[e.op], idx[e.col.name], v))
        elif isinstance(e, E.And):
            walk(e.parts[0])
            for p in e.parts[1:]:
                walk(p)
                prog.append(("and",))
        elif isinstance(e, E.Or):
            walk(e.parts[0])
            for p in e.parts[1:]:
                walk(p)
                prog.append(("or",))
        elif isinstance(e, E.Not):
            walk(e.part)
            prog.append(("not",))
        else:
            raise ValueError(type(e))

    walk(pred)
    return tuple(prog)


def kernel_supports(pred: E.Expr,
                    numeric_cols: Sequence[str] | None = None) -> bool:
    """Can this predicate run through the fused kernel?

    Pass ``numeric_cols`` (the schema's i32/f32 column names) whenever
    a schema is at hand: without it, a col-col compare over *string*
    columns is indistinguishable from a numeric one (names carry no
    dtype) and would be reported as supported.
    """
    cols = (list(numeric_cols) if numeric_cols is not None
            else list(E.columns_of(pred)))
    try:
        compile_predicate(pred, cols)
        return True
    except (ValueError, KeyError):
        return False


def filter_mask(columns: Tuple[jnp.ndarray, ...], program: PredProgram,
                nrows: int, *, block: int = DEFAULT_BLOCK,
                use_pallas: bool = True, interpret: bool | None = None):
    """mask+counts via the kernel (padding columns to a block multiple)."""
    n = columns[0].shape[0]
    padded_n = ((n + block - 1) // block) * block
    if padded_n != n:
        columns = tuple(
            jnp.pad(c, ((0, padded_n - n),) + ((0, 0),) * (c.ndim - 1))
            for c in columns)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_pallas:
        mask, counts = filter_scan(columns, program, nrows, block=block,
                                   interpret=interpret)
    else:
        mask, counts = filter_scan_ref(columns, program, nrows, block)
    return mask[:n], counts
