"""Jit'd public wrappers for the fused filter-scan kernel.

``compile_predicate`` lowers a relational Expr into the kernel's static
postfix program, so the relational engine can execute covering-
expression predicates through the Pallas path (``use_pallas=True`` in
the engine; interpret mode on CPU, compiled on TPU).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

import functools

import numpy as np

from ...relational import expr as E
from .kernel import DEFAULT_BLOCK, filter_scan, filter_scan_batch, \
    parse_i32
from .ref import PredProgram, filter_scan_batch_ref, filter_scan_ref

_OPMAP = {"<": "lt", "<=": "le", ">": "gt", ">=": "ge", "==": "eq",
          "!=": "ne"}


def compile_predicate(pred: E.Expr, col_names: Sequence[str]
                      ) -> PredProgram:
    """Relational Expr -> static postfix program over numeric columns.

    Supports col-const and col-col compares over i32/f32 columns (the
    program IR promotes mixed dtypes to f32 — see ref.PredProgram).
    String predicates raise ValueError; referencing a column outside
    ``col_names`` (e.g. a string column in a col-col compare) raises
    KeyError — callers pass the *numeric* column set so both cases fall
    back to the XLA path.
    """
    idx = {n: i for i, n in enumerate(col_names)}
    prog: List[tuple] = []

    def walk(e: E.Expr):
        if isinstance(e, E.Cmp):
            e = E.oriented(e)
            if isinstance(e.col, E.Lit):
                raise ValueError("constant compare unsupported in kernel")
            if isinstance(e.rhs, E.Col):
                prog.append((_OPMAP[e.op] + "c", idx[e.col.name],
                             idx[e.rhs.name]))
                return
            v = e.rhs.value
            if isinstance(v, (bytes, str)):
                raise ValueError("string predicates unsupported in kernel")
            prog.append((_OPMAP[e.op], idx[e.col.name], v))
        elif isinstance(e, E.In):
            if any(isinstance(v, (bytes, str)) for v in e.values):
                raise ValueError("string membership unsupported in kernel")
            prog.append(("in", idx[e.col.name], tuple(e.values)))
        elif isinstance(e, E.And):
            walk(e.parts[0])
            for p in e.parts[1:]:
                walk(p)
                prog.append(("and",))
        elif isinstance(e, E.Or):
            walk(e.parts[0])
            for p in e.parts[1:]:
                walk(p)
                prog.append(("or",))
        elif isinstance(e, E.Not):
            walk(e.part)
            prog.append(("not",))
        else:
            raise ValueError(type(e))

    walk(pred)
    return tuple(prog)


def compile_predicate_slots(pred: E.Expr, col_names: Sequence[str],
                            kinds: Dict[str, str]
                            ) -> Tuple[PredProgram, tuple, tuple]:
    """Relational Expr -> SLOTTED postfix program + hoisted literals.

    The program is the predicate's *shape*: i32/f32 compare constants
    are replaced by ``("$i", j)`` / ``("$f", j)`` slot references and
    returned separately as ``(ivals, fvals)``, so every literal variant
    of one template compiles to the SAME static program (one trace, one
    plan-shape cache key) and a window of variants can evaluate as one
    batch.  Fractional-on-int folding runs here, against the column
    ``kinds`` ({name: "i32"|"i64"|"f32"}), so the slotted result is
    bit-identical to the literal program's trace-time fold.  ``In``
    values and i64 constants stay embedded (no 64-bit slot lane);
    unsupported predicates raise ValueError/KeyError like
    :func:`compile_predicate`.
    """
    idx = {n: i for i, n in enumerate(col_names)}
    prog: List[tuple] = []
    ivals: List[int] = []
    fvals: List[float] = []

    def walk(e: E.Expr):
        if isinstance(e, E.TrueExpr):
            prog.append(("const", True))
        elif isinstance(e, E.Cmp):
            e = E.oriented(e)
            if isinstance(e.col, E.Lit):
                raise ValueError("constant compare unsupported in kernel")
            if isinstance(e.rhs, E.Col):
                prog.append((_OPMAP[e.op] + "c", idx[e.col.name],
                             idx[e.rhs.name]))
                return
            v = e.rhs.value
            if isinstance(v, (bytes, str)):
                raise ValueError("string predicates unsupported in kernel")
            kind = kinds[e.col.name]
            ci = idx[e.col.name]
            opn = _OPMAP[e.op]
            if kind in ("i32", "i64"):
                if isinstance(v, float) and not v.is_integer():
                    folded = E.fold_int_cmp(
                        e.op, v, bits=64 if kind == "i64" else 32)
                    if folded[0] == "all":
                        prog.append(("const", folded[1]))
                        return
                    _, opsym, v = folded
                    opn = _OPMAP[opsym]
                v = int(v)
                if kind == "i64":
                    # i64 consts stay literal in the (static) program
                    prog.append((opn, ci, v))
                    return
                if not -(2 ** 31) <= v <= 2 ** 31 - 1:
                    raise ValueError("const exceeds int32 slot range")
                prog.append((opn, ci, ("$i", len(ivals))))
                ivals.append(v)
            else:
                prog.append((opn, ci, ("$f", len(fvals))))
                fvals.append(float(v))
        elif isinstance(e, E.In):
            if any(isinstance(v, (bytes, str)) for v in e.values):
                raise ValueError("string membership unsupported in kernel")
            kinds[e.col.name]   # KeyError for non-numeric columns
            prog.append(("in", idx[e.col.name], tuple(e.values)))
        elif isinstance(e, E.And):
            walk(e.parts[0])
            for p in e.parts[1:]:
                walk(p)
                prog.append(("and",))
        elif isinstance(e, E.Or):
            walk(e.parts[0])
            for p in e.parts[1:]:
                walk(p)
                prog.append(("or",))
        elif isinstance(e, E.Not):
            walk(e.part)
            prog.append(("not",))
        else:
            raise ValueError(type(e))

    walk(pred)
    return tuple(prog), tuple(ivals), tuple(fvals)


def pack_consts(ival_rows: Sequence[tuple], fval_rows: Sequence[tuple]
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Stack per-query hoisted literals into the kernel's ``(n_q, k)``
    operand arrays (k >= 1 so an unused const class still has a lane)."""
    n_q = len(ival_rows)
    ki = max(max((len(r) for r in ival_rows), default=0), 1)
    kf = max(max((len(r) for r in fval_rows), default=0), 1)
    ic = np.zeros((n_q, ki), np.int32)
    fc = np.zeros((n_q, kf), np.float32)
    for q, row in enumerate(ival_rows):
        ic[q, : len(row)] = row
    for q, row in enumerate(fval_rows):
        fc[q, : len(row)] = row
    return ic, fc


def kernel_supports(pred: E.Expr,
                    numeric_cols: Sequence[str] | None = None) -> bool:
    """Can this predicate run through the fused kernel?

    Pass ``numeric_cols`` (the schema's i32/f32 column names) whenever
    a schema is at hand: without it, a col-col compare over *string*
    columns is indistinguishable from a numeric one (names carry no
    dtype) and would be reported as supported.
    """
    cols = (list(numeric_cols) if numeric_cols is not None
            else list(E.columns_of(pred)))
    try:
        compile_predicate(pred, cols)
        return True
    except (ValueError, KeyError):
        return False


def filter_mask(columns: Tuple[jnp.ndarray, ...], program: PredProgram,
                nrows: int, *, block: int = DEFAULT_BLOCK,
                use_pallas: bool = True, interpret: bool | None = None):
    """mask+counts via the kernel (padding columns to a block multiple)."""
    n = columns[0].shape[0]
    padded_n = ((n + block - 1) // block) * block
    if padded_n != n:
        columns = tuple(
            jnp.pad(c, ((0, padded_n - n),) + ((0, 0),) * (c.ndim - 1))
            for c in columns)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_pallas:
        mask, counts = filter_scan(columns, program, nrows, block=block,
                                   interpret=interpret)
    else:
        mask, counts = filter_scan_ref(columns, program, nrows, block)
    return mask[:n], counts


_batch_ref = functools.partial(
    jax.jit, static_argnames=("program", "block"))(filter_scan_batch_ref)


def filter_mask_batch(columns: Tuple[jnp.ndarray, ...],
                      program: PredProgram, nrows,
                      iconsts, fconsts, *, block: int = DEFAULT_BLOCK,
                      use_pallas: bool = True,
                      interpret: bool | None = None):
    """n-query masks+counts in ONE dispatch over shared columns.

    ``use_pallas=False`` routes through the jitted XLA oracle — the
    fallback batch path when a program falls off the Pallas route."""
    n = columns[0].shape[0]
    padded_n = ((n + block - 1) // block) * block
    if padded_n != n:
        columns = tuple(
            jnp.pad(c, ((0, padded_n - n),) + ((0, 0),) * (c.ndim - 1))
            for c in columns)
    iconsts = jnp.asarray(iconsts, jnp.int32)
    fconsts = jnp.asarray(fconsts, jnp.float32)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_pallas:
        mask, counts = filter_scan_batch(columns, program, nrows,
                                         iconsts, fconsts, block=block,
                                         interpret=interpret)
    else:
        mask, counts = _batch_ref(columns, program, nrows, iconsts,
                                  fconsts, block=block)
    return mask[:, :n], counts
