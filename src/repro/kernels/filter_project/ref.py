"""Pure-jnp oracle for the fused filter/parse scan kernel."""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

# Predicate program IR (static): postfix ops over a stack.
#   ("lt"|"le"|"gt"|"ge"|"eq"|"ne", col_idx, const)    -> push col OP const
#   ("ltc"|"lec"|"gtc"|"gec"|"eqc"|"nec", ia, ib)      -> push col_a OP col_b
#   ("in", col_idx, values)                            -> push membership
#   ("const", bool)                                    -> push constant mask
#   ("and",) / ("or",)                                 -> pop 2, push
#   ("not",)                                           -> pop 1, push
# A float const with a fractional part against an integer column folds
# into an exact integer compare at trace time (f32 promotion would be
# inexact beyond 2^24); col-col compares over mixed dtypes promote both
# sides to f32 (matching jnp's promotion in the XLA path — inexact
# beyond 2^24, like every f32 compare in the engine).
#
# SLOTTED programs (the plan-shape form): the const position of a
# compare may instead be ``("$i", j)`` / ``("$f", j)`` — a reference
# into the runtime ``iconsts`` / ``fconsts`` operand arrays.  A slotted
# program carries no literal values, so every literal variant of one
# predicate template shares a single static program (and a single
# trace).  Operand arrays are ``(k,)`` for one query or ``(n_q, k)``
# for a window batch, in which case the evaluated mask broadcasts to
# ``(n_q, block)`` — n queries in one pass over the same columns.
PredProgram = Tuple[tuple, ...]

_CMP = {
    "lt": lambda a, b: a < b, "le": lambda a, b: a <= b,
    "gt": lambda a, b: a > b, "ge": lambda a, b: a >= b,
    "eq": lambda a, b: a == b, "ne": lambda a, b: a != b,
}

# col-col variants -> base compare op
_CMP_CC = {k + "c": k for k in _CMP}

# kernel opcode <-> relational op symbol (for constant folding)
_CMP_OPSYM = {"lt": "<", "le": "<=", "gt": ">", "ge": ">=",
              "eq": "==", "ne": "!="}
_SYM_CMP = {v: k for k, v in _CMP_OPSYM.items()}


def _bcast(x: jnp.ndarray, bshape) -> jnp.ndarray:
    """Broadcast an operand to the batch shape (explicitly, so the
    Pallas TPU lowering never sees an implicit rank-mismatched op)."""
    if bshape is None or x.shape == tuple(bshape):
        return x
    if x.ndim == 1 and x.shape[0] == bshape[0] != bshape[1]:
        x = x[:, None]            # (n_q,) slot column -> (n_q, 1)
    return jnp.broadcast_to(x, bshape)


def eval_program(program: PredProgram, cols: Sequence[jnp.ndarray],
                 iconsts: Optional[jnp.ndarray] = None,
                 fconsts: Optional[jnp.ndarray] = None,
                 bshape: Optional[Tuple[int, int]] = None) -> jnp.ndarray:
    stack = []
    for op in program:
        if op[0] in _CMP:
            _, idx, const = op
            c = cols[idx]
            if isinstance(const, tuple):   # slot reference
                arr = iconsts if const[0] == "$i" else fconsts
                v = arr[..., const[1]]
                if v.ndim == 1:
                    v = v[:, None]         # (n_q,) -> (n_q, 1) row consts
                stack.append(_CMP[op[0]](_bcast(c, bshape),
                                         _bcast(v, bshape)))
                continue
            if (isinstance(const, float) and not float(const).is_integer()
                    and jnp.issubdtype(c.dtype, jnp.integer)):
                from ...relational.expr import fold_int_cmp

                folded = fold_int_cmp(_CMP_OPSYM[op[0]], float(const),
                                      bits=jnp.iinfo(c.dtype).bits)
                if folded[0] == "all":
                    fill = jnp.ones_like if folded[1] else jnp.zeros_like
                    stack.append(_bcast(fill(c, dtype=jnp.bool_), bshape))
                    continue
                _, opsym, b = folded
                stack.append(_CMP[_SYM_CMP[opsym]](
                    _bcast(c, bshape),
                    _bcast(jnp.asarray(b, c.dtype), bshape)))
                continue
            stack.append(_CMP[op[0]](_bcast(c, bshape),
                                     _bcast(jnp.asarray(const, c.dtype),
                                            bshape)))
        elif op[0] == "in":
            _, idx, values = op
            c = cols[idx]
            m = jnp.zeros(c.shape, jnp.bool_)
            is_int = jnp.issubdtype(c.dtype, jnp.integer)
            info = jnp.iinfo(c.dtype) if is_int else None
            for v in values:
                if is_int and isinstance(v, float):
                    if not float(v).is_integer():
                        continue            # an int never equals a fraction
                    v = int(v)
                if is_int and not (info.min <= int(v) <= info.max):
                    continue                # out of range: never equal
                m = m | (c == jnp.asarray(v, c.dtype))
            stack.append(_bcast(m, bshape))
        elif op[0] == "const":
            shape = tuple(bshape) if bshape is not None else cols[0].shape
            fill = jnp.ones if op[1] else jnp.zeros
            stack.append(fill(shape, jnp.bool_))
        elif op[0] in _CMP_CC:
            _, ia, ib = op
            a, b = cols[ia], cols[ib]
            if a.dtype != b.dtype:
                a, b = a.astype(jnp.float32), b.astype(jnp.float32)
            stack.append(_bcast(_CMP[_CMP_CC[op[0]]](a, b), bshape))
        elif op[0] == "and":
            b, a = stack.pop(), stack.pop()
            stack.append(a & b)
        elif op[0] == "or":
            b, a = stack.pop(), stack.pop()
            stack.append(a | b)
        elif op[0] == "not":
            stack.append(~stack.pop())
        else:
            raise ValueError(op)
    (mask,) = stack
    return mask


def filter_scan_ref(columns: Sequence[jnp.ndarray], program: PredProgram,
                    nrows: int | jnp.ndarray, block: int = 1024
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (mask bool (N,), per-block selected counts (N//block,))."""
    n = columns[0].shape[0]
    mask = eval_program(program, columns)
    mask = mask & (jnp.arange(n) < nrows)
    counts = jnp.sum(mask.reshape(n // block, block).astype(jnp.int32),
                     axis=1)
    return mask, counts


def filter_scan_batch_ref(columns: Sequence[jnp.ndarray],
                          program: PredProgram, nrows: int | jnp.ndarray,
                          iconsts: jnp.ndarray, fconsts: jnp.ndarray,
                          block: int = 1024
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Batched oracle: one pass over the columns evaluates a SLOTTED
    program for every row of the const arrays at once.

    Returns (mask bool (n_q, N), per-block counts (n_q, N//block)).
    """
    n = columns[0].shape[0]
    n_q = iconsts.shape[0]
    mask = eval_program(program, columns, iconsts=iconsts,
                        fconsts=fconsts, bshape=(n_q, n))
    mask = mask & (jnp.arange(n)[None, :] < nrows)
    counts = jnp.sum(
        mask.reshape(n_q, n // block, block).astype(jnp.int32), axis=2)
    return mask, counts


def parse_i32_ref(digits: jnp.ndarray) -> jnp.ndarray:
    """(n, 10) uint8 zero-padded decimal digits -> int32 (oracle)."""
    pows = jnp.asarray([10**k for k in range(9, -1, -1)], jnp.int32)
    return jnp.einsum("nd,d->n", digits.astype(jnp.int32) - 48, pows,
                      preferred_element_type=jnp.int32)
