"""Pure-jnp oracle for the fused filter/parse scan kernel."""
from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp

# Predicate program IR (static): postfix ops over a stack.
#   ("lt"|"le"|"gt"|"ge"|"eq"|"ne", col_idx, const)    -> push col OP const
#   ("ltc"|"lec"|"gtc"|"gec"|"eqc"|"nec", ia, ib)      -> push col_a OP col_b
#   ("and",) / ("or",)                                 -> pop 2, push
#   ("not",)                                           -> pop 1, push
# A float const with a fractional part against an integer column folds
# into an exact integer compare at trace time (f32 promotion would be
# inexact beyond 2^24); col-col compares over mixed dtypes promote both
# sides to f32 (matching jnp's promotion in the XLA path — inexact
# beyond 2^24, like every f32 compare in the engine).
PredProgram = Tuple[tuple, ...]

_CMP = {
    "lt": lambda a, b: a < b, "le": lambda a, b: a <= b,
    "gt": lambda a, b: a > b, "ge": lambda a, b: a >= b,
    "eq": lambda a, b: a == b, "ne": lambda a, b: a != b,
}

# col-col variants -> base compare op
_CMP_CC = {k + "c": k for k in _CMP}

# kernel opcode <-> relational op symbol (for constant folding)
_CMP_OPSYM = {"lt": "<", "le": "<=", "gt": ">", "ge": ">=",
              "eq": "==", "ne": "!="}
_SYM_CMP = {v: k for k, v in _CMP_OPSYM.items()}


def eval_program(program: PredProgram, cols: Sequence[jnp.ndarray]
                 ) -> jnp.ndarray:
    stack = []
    for op in program:
        if op[0] in _CMP:
            _, idx, const = op
            c = cols[idx]
            if (isinstance(const, float) and not float(const).is_integer()
                    and jnp.issubdtype(c.dtype, jnp.integer)):
                from ...relational.expr import fold_int_cmp

                folded = fold_int_cmp(_CMP_OPSYM[op[0]], float(const))
                if folded[0] == "all":
                    fill = jnp.ones_like if folded[1] else jnp.zeros_like
                    stack.append(fill(c, dtype=jnp.bool_))
                    continue
                _, opsym, b = folded
                stack.append(_CMP[_SYM_CMP[opsym]](c, jnp.asarray(
                    b, c.dtype)))
                continue
            stack.append(_CMP[op[0]](c, jnp.asarray(const, c.dtype)))
        elif op[0] in _CMP_CC:
            _, ia, ib = op
            a, b = cols[ia], cols[ib]
            if a.dtype != b.dtype:
                a, b = a.astype(jnp.float32), b.astype(jnp.float32)
            stack.append(_CMP[_CMP_CC[op[0]]](a, b))
        elif op[0] == "and":
            b, a = stack.pop(), stack.pop()
            stack.append(a & b)
        elif op[0] == "or":
            b, a = stack.pop(), stack.pop()
            stack.append(a | b)
        elif op[0] == "not":
            stack.append(~stack.pop())
        else:
            raise ValueError(op)
    (mask,) = stack
    return mask


def filter_scan_ref(columns: Sequence[jnp.ndarray], program: PredProgram,
                    nrows: int | jnp.ndarray, block: int = 1024
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (mask bool (N,), per-block selected counts (N//block,))."""
    n = columns[0].shape[0]
    mask = eval_program(program, columns)
    mask = mask & (jnp.arange(n) < nrows)
    counts = jnp.sum(mask.reshape(n // block, block).astype(jnp.int32),
                     axis=1)
    return mask, counts


def parse_i32_ref(digits: jnp.ndarray) -> jnp.ndarray:
    """(n, 10) uint8 zero-padded decimal digits -> int32 (oracle)."""
    pows = jnp.asarray([10**k for k in range(9, -1, -1)], jnp.int32)
    return jnp.einsum("nd,d->n", digits.astype(jnp.int32) - 48, pows,
                      preferred_element_type=jnp.int32)
