"""Pure-jnp oracle for the fused filter/parse scan kernel."""
from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp

# Predicate program IR (static): postfix ops over a stack.
#   ("lt"|"le"|"gt"|"ge"|"eq"|"ne", col_idx, const)  -> push col OP const
#   ("and",) / ("or",)                               -> pop 2, push
#   ("not",)                                         -> pop 1, push
PredProgram = Tuple[tuple, ...]

_CMP = {
    "lt": lambda a, b: a < b, "le": lambda a, b: a <= b,
    "gt": lambda a, b: a > b, "ge": lambda a, b: a >= b,
    "eq": lambda a, b: a == b, "ne": lambda a, b: a != b,
}


def eval_program(program: PredProgram, cols: Sequence[jnp.ndarray]
                 ) -> jnp.ndarray:
    stack = []
    for op in program:
        if op[0] in _CMP:
            _, idx, const = op
            c = cols[idx]
            stack.append(_CMP[op[0]](c, jnp.asarray(const, c.dtype)))
        elif op[0] == "and":
            b, a = stack.pop(), stack.pop()
            stack.append(a & b)
        elif op[0] == "or":
            b, a = stack.pop(), stack.pop()
            stack.append(a | b)
        elif op[0] == "not":
            stack.append(~stack.pop())
        else:
            raise ValueError(op)
    (mask,) = stack
    return mask


def filter_scan_ref(columns: Sequence[jnp.ndarray], program: PredProgram,
                    nrows: int | jnp.ndarray, block: int = 1024
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (mask bool (N,), per-block selected counts (N//block,))."""
    n = columns[0].shape[0]
    mask = eval_program(program, columns)
    mask = mask & (jnp.arange(n) < nrows)
    counts = jnp.sum(mask.reshape(n // block, block).astype(jnp.int32),
                     axis=1)
    return mask, counts


def parse_i32_ref(digits: jnp.ndarray) -> jnp.ndarray:
    """(n, 10) uint8 zero-padded decimal digits -> int32 (oracle)."""
    pows = jnp.asarray([10**k for k in range(9, -1, -1)], jnp.int32)
    return jnp.einsum("nd,d->n", digits.astype(jnp.int32) - 48, pows,
                      preferred_element_type=jnp.int32)
