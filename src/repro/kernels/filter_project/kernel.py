"""Fused columnar filter-scan Pallas kernel (the paper's hot path).

The paper's micro-benchmarks show scan+parse+filter dominates query
time for CSV inputs (§6.3).  On TPU we adapt the insight rather than
port row-wise CPU code:

  * columns stream HBM → VMEM in row-blocks (BlockSpec over the row
    dim, block size a multiple of the 8×128 VPU tile);
  * the predicate program is STATIC — the kernel body is specialized at
    trace time to the query's predicate, so the whole disjunction of a
    covering expression evaluates in registers in one pass (exactly the
    shared-operator fusion a CE needs);
  * optional fixed-width decimal parse runs as a (block, 10) × (10,)
    dot — MXU-friendly — fusing the CSV "parse+typecast" cost in;
  * outputs are a boolean mask plus per-block selected counts; the
    compaction (data-dependent shape) stays outside in XLA, where a
    sort/scatter is already optimal — a TPU kernel gains nothing there.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import PredProgram, eval_program

DEFAULT_BLOCK = 2048  # rows per block: 2048*4B = 8 KiB/column in VMEM


def _kernel_body(program: PredProgram, n_cols: int, block: int,
                 nrows_ref, *refs):
    col_refs = refs[:n_cols]
    mask_ref, count_ref = refs[n_cols], refs[n_cols + 1]
    bid = pl.program_id(0)

    cols = [r[...] for r in col_refs]
    # the program is static, so the whole postfix evaluation unrolls at
    # trace time into plain VPU element-wise ops (see ref.eval_program —
    # shared with the XLA oracle so both paths agree bit-for-bit)
    mask = eval_program(program, cols)

    # validity: global row index < nrows
    row0 = bid * block
    valid = (row0 + jax.lax.iota(jnp.int32, block)) < nrows_ref[0]
    mask = mask & valid
    mask_ref[...] = mask
    # dtype pinned: under x64 jnp.sum would promote the
    # accumulator to int64 and mismatch the int32 count ref
    count_ref[0] = jnp.sum(mask.astype(jnp.int32), dtype=jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("program", "block", "interpret"))
def filter_scan(columns: Tuple[jnp.ndarray, ...], program: PredProgram,
                nrows, *, block: int = DEFAULT_BLOCK,
                interpret: bool = False):
    """Blocked fused predicate scan.

    Args:
      columns: tuple of (N,) int32/float32 column arrays, N % block == 0.
      program: static postfix predicate program (see ref.PredProgram).
      nrows: live row count (rows beyond it never match).
    Returns:
      (mask bool (N,), per-block counts int32 (N//block,)).
    """
    n = columns[0].shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    n_cols = len(columns)

    in_specs = [pl.BlockSpec((1,), lambda i: (0,))]  # nrows scalar
    in_specs += [pl.BlockSpec((block,), lambda i: (i,))
                 for _ in range(n_cols)]
    out_specs = [
        pl.BlockSpec((block,), lambda i: (i,)),
        pl.BlockSpec((1,), lambda i: (i,)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((n,), jnp.bool_),
        jax.ShapeDtypeStruct((grid[0],), jnp.int32),
    ]
    kernel = functools.partial(_kernel_body, program, n_cols, block)
    mask, counts = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(jnp.asarray([nrows], jnp.int32), *columns)
    return mask, counts


def _batch_kernel_body(program: PredProgram, n_cols: int, n_q: int,
                       block: int, nrows_ref, ic_ref, fc_ref, *refs):
    col_refs = refs[:n_cols]
    mask_ref, count_ref = refs[n_cols], refs[n_cols + 1]
    bid = pl.program_id(0)

    cols = [r[...] for r in col_refs]
    # one pass over the block evaluates every query's slotted program
    # row: the (n_q, k) const arrays broadcast against the (block,)
    # columns inside eval_program, giving an (n_q, block) mask
    mask = eval_program(program, cols, iconsts=ic_ref[...],
                        fconsts=fc_ref[...], bshape=(n_q, block))

    row0 = bid * block
    # 2-D iota: TPU cannot lower a 1-D iota (see pallas guide)
    valid = (row0 + jax.lax.broadcasted_iota(jnp.int32, (n_q, block), 1)
             ) < nrows_ref[0]
    mask = mask & valid
    mask_ref[...] = mask
    count_ref[...] = jnp.sum(mask.astype(jnp.int32), axis=1,
                             keepdims=True, dtype=jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("program", "block", "interpret"))
def filter_scan_batch(columns: Tuple[jnp.ndarray, ...],
                      program: PredProgram, nrows,
                      iconsts: jnp.ndarray, fconsts: jnp.ndarray, *,
                      block: int = DEFAULT_BLOCK,
                      interpret: bool = False):
    """Window-batched fused predicate scan: n queries, ONE launch.

    The program is SLOTTED — literals live in the ``(n_q, k)`` operand
    arrays, not the static program — so every window of the same plan
    shape reuses one trace, and the columns stream HBM -> VMEM once for
    all n queries instead of once per query.

    Args:
      columns: tuple of (N,) numeric column arrays, N % block == 0.
      program: static slotted postfix program (see ref.PredProgram).
      nrows: live row count (rows beyond it never match).
      iconsts / fconsts: (n_q, k_i) int32 / (n_q, k_f) float32 operand
        arrays (k >= 1; pad with zeros when a class is unused).
    Returns:
      (mask bool (n_q, N), per-block counts int32 (n_q, N//block)).
    """
    n = columns[0].shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    n_cols = len(columns)
    n_q, ki = iconsts.shape
    kf = fconsts.shape[1]

    in_specs = [
        pl.BlockSpec((1,), lambda i: (0,)),            # nrows scalar
        pl.BlockSpec((n_q, ki), lambda i: (0, 0)),     # int consts
        pl.BlockSpec((n_q, kf), lambda i: (0, 0)),     # float consts
    ]
    in_specs += [pl.BlockSpec((block,), lambda i: (i,))
                 for _ in range(n_cols)]
    out_specs = [
        pl.BlockSpec((n_q, block), lambda i: (0, i)),
        pl.BlockSpec((n_q, 1), lambda i: (0, i)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((n_q, n), jnp.bool_),
        jax.ShapeDtypeStruct((n_q, grid[0]), jnp.int32),
    ]
    kernel = functools.partial(_batch_kernel_body, program, n_cols, n_q,
                               block)
    mask, counts = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(jnp.asarray([nrows], jnp.int32), iconsts, fconsts, *columns)
    return mask, counts


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def parse_i32(digits: jnp.ndarray, *, block: int = DEFAULT_BLOCK,
              interpret: bool = False) -> jnp.ndarray:
    """Fixed-width decimal parse: (N, 10) uint8 -> int32 (N,).

    float32 accumulate is exact for < 2^24; 10-digit values up to 1e9
    exceed that, so the kernel splits high/low 5 digits and recombines
    in int32.
    """
    n = digits.shape[0]
    assert n % block == 0 and digits.shape[1] == 10

    def body(digits_ref, out_ref):
        d = digits_ref[...].astype(jnp.float32) - 48.0
        # powers of ten built in-kernel (pallas forbids captured consts)
        hi_p = jnp.power(10.0, 4.0 - jax.lax.iota(jnp.float32, 5))
        hi = jax.lax.dot_general(d[:, :5], hi_p, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        lo = jax.lax.dot_general(d[:, 5:], hi_p, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        out_ref[...] = (hi.astype(jnp.int32) * 100000
                        + lo.astype(jnp.int32))

    return pl.pallas_call(
        body,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block, 10), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(digits)
