"""Blocked causal/sliding-window GQA flash attention (Pallas, TPU).

Canonical TPU tiling: grid (B, Hq, T/Bq, S/Bk) with the key/value block
dimension sequential ("arbitrary"), online-softmax state (m, l, acc)
carried in VMEM scratch across kv steps, output written on the last kv
step.  Q tiles are (Bq, D); K/V tiles (Bk, D) are selected per kv-head
(GQA: q-head h reads kv-head h // group).  MXU work: the two
(Bq, D) x (D, Bk) / (Bq, Bk) x (Bk, D) contractions per step — block
sizes default to 128 so every matmul dim is MXU-aligned.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_body(block_q: int, block_k: int, n_kv_blocks: int, group: int,
             causal: bool, window: Optional[int], scale: float,
             t_total: int, s_total: int,
             q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
    iq = pl.program_id(2)
    jk = pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q0 = iq * block_q + (s_total - t_total)  # global key-offset of row 0
    k0 = jk * block_k

    # skip kv blocks that are entirely masked out
    run = True
    if causal:
        run = k0 <= q0 + block_q - 1
    if window is not None:
        run = jnp.logical_and(run, k0 + block_k > q0 - window + 1)

    @pl.when(run if not isinstance(run, bool) else jnp.bool_(run))
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)           # (Bq, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (Bk, D)
        v = v_ref[0, 0].astype(jnp.float32)           # (Bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (Bq, Bk)

        rows = q0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k),
                                             0)
        cols = k0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k),
                                             1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= rows >= cols
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                            # (Bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                         # (Bq, Bk)
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1,
                                                  keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(jk == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "sm_scale", "block_q", "block_k",
                     "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    sm_scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, T, D); k, v: (B, Hkv, S, D); returns (B, Hq, T, D)."""
    b, hq, t, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    assert hq % hkv == 0 and t % block_q == 0 and s % block_k == 0
    group = hq // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    n_kv_blocks = s // block_k
    grid = (b, hq, t // block_q, n_kv_blocks)

    kernel = functools.partial(
        _fa_body, block_q, block_k, n_kv_blocks, group, causal, window,
        scale, t, s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, i, j: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, i, j: (b_, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),   # accumulator
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
