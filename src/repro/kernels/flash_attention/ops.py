"""Public jit'd wrappers for flash attention.

``attention`` dispatches between the Pallas kernel (TPU target;
interpret-mode on CPU) and the pure-XLA reference, and carries a
custom VJP: forward through the kernel, backward via the reference
recompute (flash backward kernels are a follow-up; the VJP keeps the
kernel usable inside ``train_step`` either way).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention
from .ref import mha_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6))
def attention(q, k, v, causal: bool = True, window: Optional[int] = None,
              sm_scale: Optional[float] = None, impl: str = "pallas"):
    if impl == "pallas":
        return flash_attention(q, k, v, causal=causal, window=window,
                               sm_scale=sm_scale,
                               interpret=_use_interpret())
    return mha_ref(q, k, v, causal=causal, window=window,
                   sm_scale=sm_scale)


def _fwd(q, k, v, causal, window, sm_scale, impl):
    out = attention(q, k, v, causal, window, sm_scale, impl)
    return out, (q, k, v)


def _bwd(causal, window, sm_scale, impl, res, g):
    q, k, v = res

    def f(q_, k_, v_):
        return mha_ref(q_, k_, v_, causal=causal, window=window,
                       sm_scale=sm_scale)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


attention.defvjp(_fwd, _bwd)
