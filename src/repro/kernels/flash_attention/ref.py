"""Pure-jnp oracle for blocked (flash) attention."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
            causal: bool = True, window: Optional[int] = None,
            sm_scale: Optional[float] = None) -> jnp.ndarray:
    """Reference attention.

    q: (B, Hq, T, D); k, v: (B, Hkv, S, D); GQA via head repetition.
    window: sliding-window size (a query attends to keys in
    (qi - window, qi]); None = full.
    """
    b, hq, t, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = hq // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qi = jnp.arange(t)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), jnp.bool_)
    if causal:
        offset = s - t  # decode-style: last t queries of an s-long ctx
        mask &= (qi + offset) >= ki
    if window is not None:
        offset = s - t
        mask &= ki > (qi + offset - window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(-1, keepdims=True))
    probs = jnp.where(jnp.isfinite(logits), probs, 0.0)
    out = jnp.einsum("bhts,bhsd->bhtd", probs, v.astype(jnp.float32))
    denom = probs.sum(-1, keepdims=True)
    return (out / jnp.maximum(denom, 1e-30)).astype(q.dtype)


def decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               kv_len: jnp.ndarray, *, sm_scale: Optional[float] = None,
               window: Optional[int] = None) -> jnp.ndarray:
    """Single-token decode oracle.

    q: (B, Hq, D); k, v: (B, Hkv, S, D) padded caches; kv_len: (B,)
    live lengths (the new token's KV already appended).
    """
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = hq // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    ki = jnp.arange(s)[None, None, :]
    mask = ki < kv_len[:, None, None]
    if window is not None:
        mask &= ki >= (kv_len[:, None, None] - window)
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(-1, keepdims=True))
    probs = jnp.where(jnp.isfinite(logits), probs, 0.0)
    out = jnp.einsum("bhs,bhsd->bhd", probs, v.astype(jnp.float32))
    denom = probs.sum(-1, keepdims=True)
    return (out / jnp.maximum(denom, 1e-30)).astype(q.dtype)
