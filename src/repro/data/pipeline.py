"""Deterministic synthetic token pipeline with packing and prefetch.

Restart-safe by construction: batch ``i`` is a pure function of
(seed, i), so a trainer resumed from step N sees exactly the batches it
would have seen — checkpoint/restart reproduces the loss curve bitwise
(tested).  Per-host sharding slices the global batch by process index;
a background thread keeps ``prefetch`` batches ready (the straggler-
hiding measure on real clusters where host input pipelines jitter).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 256
    eos_id: int = 1
    n_prefix_tokens: int = 0
    d_model: int = 0                  # for frontend-stub prefix embeds
    process_index: int = 0
    process_count: int = 1

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.process_count == 0
        return self.global_batch // self.process_count


def _pack_documents(rng: np.random.Generator, cfg: DataConfig,
                    rows: int) -> np.ndarray:
    """Sample doc lengths ~ exp(mean) and pack them with EOS separators."""
    out = np.zeros((rows, cfg.seq_len), np.int32)
    for r in range(rows):
        pos = 0
        while pos < cfg.seq_len:
            dl = int(rng.exponential(cfg.mean_doc_len)) + 1
            dl = min(dl, cfg.seq_len - pos)
            out[r, pos:pos + dl] = rng.integers(
                2, cfg.vocab_size, dl, dtype=np.int64)
            pos += dl
            if pos < cfg.seq_len:
                out[r, pos] = cfg.eos_id
                pos += 1
    return out


def make_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """The batch for global step ``step`` (this host's slice)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.process_index]))
    rows = cfg.host_batch
    seq = _pack_documents(rng, cfg, rows)
    n_tok = cfg.seq_len - cfg.n_prefix_tokens
    batch = {
        "tokens": seq[:, :n_tok],
        "labels": np.concatenate(
            [seq[:, 1:], np.full((rows, 1), cfg.eos_id, np.int32)], 1),
        "mask": np.ones((rows, cfg.seq_len), np.float32),
    }
    if cfg.n_prefix_tokens:
        batch["prefix_embeds"] = rng.standard_normal(
            (rows, cfg.n_prefix_tokens, cfg.d_model)).astype(np.float32)
        batch["mask"][:, : cfg.n_prefix_tokens] = 0.0
    return batch


class Pipeline:
    """Double-buffered prefetching iterator over make_batch."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 prefetch: int = 2):
        self.cfg = cfg
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer,
                                        daemon=True)
        self._thread.start()

    def _producer(self):
        step = self._step
        while not self._stop.is_set():
            batch = make_batch(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
