from .pipeline import DataConfig, Pipeline, make_batch
