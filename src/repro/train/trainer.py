"""Training loop with fault tolerance.

Responsibilities: jit the step with buffer donation, drive the
prefetching pipeline, checkpoint asynchronously every
``ckpt_every`` steps, restore-and-resume on start, survive injected
preemptions (the failure-simulation hook used by tests), and log
step metrics.  Straggler mitigation at this layer = async checkpoint
writes + prefetched input (slow host I/O never blocks the step);
cross-host straggler handling is the runtime's job on real pods.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..ckpt.checkpoint import CheckpointManager
from ..data.pipeline import DataConfig, Pipeline, make_batch
from ..models.config import ArchConfig
from ..models.model import init_params
from .optimizer import OptConfig
from .train_step import init_train_state, make_train_step


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3
    log_every: int = 10
    seed: int = 0
    # failure injection for tests: raise after N steps (None = never)
    fail_after_step: Optional[int] = None


class PreemptionError(RuntimeError):
    pass


@dataclass
class TrainResult:
    final_step: int
    metrics_log: List[Dict[str, float]] = field(default_factory=list)
    resumed_from: Optional[int] = None
    params: Any = None
    opt_state: Any = None


def train(cfg: ArchConfig, data_cfg: DataConfig, opt_cfg: OptConfig,
          tcfg: TrainerConfig, params=None) -> TrainResult:
    ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)

    if params is None:
        params = init_params(cfg, tcfg.seed)
    opt_state = init_train_state(cfg, params)

    resumed_from = None
    latest = ckpt.latest_step()
    if latest is not None:
        _, state = ckpt.restore({"params": params, "opt": opt_state},
                                latest)
        params, opt_state = state["params"], state["opt"]
        resumed_from = latest

    step_fn = jax.jit(make_train_step(cfg, opt_cfg),
                      donate_argnums=(0, 1))

    start_step = (resumed_from or 0)
    pipe = Pipeline(data_cfg, start_step=start_step)
    result = TrainResult(final_step=start_step,
                         resumed_from=resumed_from)

    try:
        for step, batch in pipe:
            if step >= tcfg.total_steps:
                break
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if (step + 1) % tcfg.log_every == 0 or step == 0:
                metrics = {k: float(v) for k, v in metrics.items()}
                metrics["step"] = step
                metrics["step_seconds"] = time.perf_counter() - t0
                result.metrics_log.append(metrics)
            if (step + 1) % tcfg.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state})
            result.final_step = step + 1
            if (tcfg.fail_after_step is not None
                    and step + 1 >= tcfg.fail_after_step):
                raise PreemptionError(f"injected failure at {step + 1}")
    finally:
        pipe.close()
        try:
            ckpt.wait()
        except Exception:
            pass

    result.params, result.opt_state = params, opt_state
    return result
