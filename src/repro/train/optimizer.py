"""AdamW + cosine schedule + global-norm clipping, in pure jnp.

Optimizer state (m, v) is float32 and lives in the same pytree
structure as the params, so the launch-layer sharding rules apply to it
unchanged (FSDP shards optimizer state along with its param).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_schedule(step: jnp.ndarray, cfg: OptConfig) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    progress = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(cfg.decay_steps - cfg.warmup_steps,
                                      1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    decayed = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, decayed)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    import copy

    return {"m": zeros,
            "v": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state, cfg: OptConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"],
                     grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"],
                     grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    lr = lr_schedule(step, cfg)

    def upd(p, m_, v_):
        mh = m_ / bc1
        vh = v_ / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, {"m": m, "v": v, "step": step}, metrics
