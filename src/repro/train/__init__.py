from .optimizer import OptConfig, adamw_update, init_opt_state
from .train_step import make_compressed_train_step, make_train_step
from .trainer import PreemptionError, TrainerConfig, train
