"""Train-step builders: standard pjit path and the gradient-compressed
shard_map path (bf16 all-reduce + error feedback).

The compressed path halves data-axis all-reduce bytes — one of the
§Perf candidates for collective-bound cells.  Error feedback keeps the
update unbiased over time: the fp32 residual that bf16 quantization
drops is carried in the optimizer state and re-added next step.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ArchConfig
from ..models.model import loss_fn
from .optimizer import OptConfig, adamw_update, init_opt_state


def make_train_step(cfg: ArchConfig, opt_cfg: OptConfig
                    ) -> Callable:
    """Standard step: value_and_grad + AdamW.  Collectives are inserted
    by the SPMD partitioner from the in/out shardings."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
        params, opt_state, metrics = adamw_update(params, grads,
                                                  opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_compressed_train_step(cfg: ArchConfig, opt_cfg: OptConfig,
                               mesh, data_axes: Tuple[str, ...]
                               ) -> Callable:
    """Gradient-compressed step (shard_map over the data axes).

    Per-shard fp32 grads + carried error feedback are quantized to
    bf16, all-reduced across the data axes in bf16 (half the ICI
    bytes), then de-quantized; the quantization residual becomes the
    next step's feedback term.
    """
    from jax.sharding import PartitionSpec as P

    def compress_and_reduce(g, err):
        g = g.astype(jnp.float32) + err
        g16 = g.astype(jnp.bfloat16)
        new_err = g - g16.astype(jnp.float32)
        for ax in data_axes:
            g16 = jax.lax.pmean(g16, ax)
        return g16.astype(jnp.float32), new_err

    def train_step(params, opt_state, batch):
        def local_grads(params, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
            return loss, grads

        loss, grads = local_grads(params, batch)
        err = opt_state.get("err")
        if err is None:
            err = jax.tree.map(
                lambda p: jnp.zeros_like(p, jnp.float32), params)
        pairs = jax.tree.map(compress_and_reduce, grads, err)
        grads = jax.tree.map(lambda pr: pr[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda pr: pr[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        params, new_state, metrics = adamw_update(
            params, grads, {k: v for k, v in opt_state.items()
                            if k != "err"}, opt_cfg)
        new_state["err"] = new_err
        metrics["loss"] = loss
        return params, new_state, metrics

    return train_step


def init_train_state(cfg: ArchConfig, params) -> Dict[str, Any]:
    return init_opt_state(params)
