"""Unified telemetry: lifecycle span tracing + a metrics registry.

The repo's observability story used to be scattered — per-window
``ExecMetrics`` counters, ``BatchResult.resilience`` dicts,
``FaultInjector.report()``, per-pool books in ``core.memory`` — with no
timeline view and no latency distributions.  This module supplies the
two missing primitives; ``relational.observe`` wires them into the
query engine behind one ``Session.telemetry()`` surface.

**Span tracer.**  Nested wall-clock spans over an injectable monotonic
clock::

    tracer = SpanTracer()
    with tracer.span("window", window=0, n_queries=4) as sp:
        with tracer.span("mqo.solve"):
            ...
        sp.set(route="batched")

Spans are context managers, so every opened span closes even when the
instrumented region raises (the span is marked ``status="error"`` and
the exception propagates).  Closed root spans accumulate in
``tracer.finished`` and export as JSON-lines (one span per line,
depth-annotated) or Chrome trace-event JSON (complete ``"ph": "X"``
events, loadable in Perfetto / ``chrome://tracing``).

**Zero cost when disabled.**  The default tracer is :data:`NOOP_TRACER`
whose ``span()`` returns one preallocated singleton no-op context
manager — no clock reads, no allocations, nothing retained.  Hot paths
additionally guard on ``tracer.enabled`` so attribute dicts are never
even built.

**Metrics registry.**  Named counters / gauges / EWMAs and fixed-bucket
histograms (t-digest-free: percentiles are interpolated within
log-spaced buckets, exact min/max tracked outside them).  Everything is
create-on-first-use and snapshots to one plain dict.

**Labels (PR 10).**  Every accessor takes an optional ``labels``
mapping; a labeled series is a separate child metric stored under the
canonical rendered key ``name{k=v,...}`` (label keys sorted), e.g.
``queries.submitted{tenant=acme}``.  The rendering is the snapshot
format — call sites never name-mangle — and :meth:`MetricsRegistry.series`
gives structured access (label dict + rendered key per child) so
report builders don't re-parse the rendered form.
"""
from __future__ import annotations

import json
import time
from bisect import bisect_left
from typing import (Any, Callable, Dict, List, Mapping, Optional,
                    Sequence, Tuple)

__all__ = [
    "Span", "SpanTracer", "NoopTracer", "NOOP_TRACER",
    "Counter", "Gauge", "Ewma", "Histogram", "MetricsRegistry",
    "DEFAULT_LATENCY_EDGES", "labeled_key",
]


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------
class Span:
    """One timed region.  Opened by ``with tracer.span(name, **attrs)``;
    nesting follows the with-statement structure."""

    __slots__ = ("name", "t_start", "t_end", "attrs", "children",
                 "status", "_tracer")

    def __init__(self, tracer: "SpanTracer", name: str,
                 attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.t_start: Optional[float] = None
        self.t_end: Optional[float] = None
        self.children: List["Span"] = []
        self.status = "ok"

    @property
    def duration(self) -> Optional[float]:
        if self.t_start is None or self.t_end is None:
            return None
        return self.t_end - self.t_start

    def set(self, **attrs) -> "Span":
        """Attach/overwrite attributes mid-span."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        tr = self._tracer
        self.t_start = tr.clock()
        if tr._stack:
            tr._stack[-1].children.append(self)
        tr._stack.append(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        tr = self._tracer
        now = tr.clock()
        if exc_type is not None:
            self.status = "error"
            self.attrs.setdefault("error", repr(exc))
        # close any child left open by a non-with escape below us, then
        # pop ourselves: the stack can never wedge on an unwound frame
        while tr._stack and tr._stack[-1] is not self:
            leaked = tr._stack.pop()
            if leaked.t_end is None:
                leaked.t_end = now
                leaked.status = "error"
        self.t_end = now
        if tr._stack and tr._stack[-1] is self:
            tr._stack.pop()
        if not tr._stack:
            tr.finished.append(self)
        return False

    def walk(self, depth: int = 0):
        yield depth, self
        for c in self.children:
            yield from c.walk(depth + 1)

    def __repr__(self) -> str:   # pragma: no cover - debugging aid
        dur = self.duration
        return (f"Span({self.name!r}, dur="
                f"{'open' if dur is None else f'{dur:.6f}s'}, "
                f"{len(self.children)} children)")


class _NoopSpan:
    """The shared do-nothing span: one module-level instance serves
    every disabled-mode ``span()`` call (zero per-call allocations)."""

    __slots__ = ()
    name = "noop"
    status = "ok"
    attrs: Dict[str, Any] = {}
    children: Sequence = ()
    duration = None

    def set(self, **attrs):
        return self

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


NOOP_SPAN = _NoopSpan()


class NoopTracer:
    """Disabled-mode tracer: ``span()`` hands back the singleton no-op
    span without touching a clock or allocating anything."""

    enabled = False
    finished: Sequence = ()

    def span(self, name: str, **attrs) -> _NoopSpan:
        return NOOP_SPAN

    def clear(self) -> None:
        pass

    def export_jsonl(self, path: Optional[str] = None) -> str:
        if path:
            with open(path, "w") as f:
                f.write("")
        return ""

    def export_chrome_trace(self, path: Optional[str] = None) -> dict:
        doc = {"traceEvents": [], "displayTimeUnit": "ms"}
        if path:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc


NOOP_TRACER = NoopTracer()


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, bytes):
        return v.hex()[:12]
    return str(v)


class SpanTracer:
    """Collecting tracer with an injectable monotonic clock."""

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.finished: List[Span] = []    # closed root spans, in order
        self._stack: List[Span] = []

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    def clear(self) -> None:
        self.finished.clear()
        self._stack.clear()

    # -- exporters ----------------------------------------------------------
    def export_jsonl(self, path: Optional[str] = None) -> str:
        """One JSON object per span (pre-order, ``depth`` gives the
        nesting level within its root)."""
        lines = []
        for root in self.finished:
            for depth, sp in root.walk():
                rec: Dict[str, Any] = {
                    "name": sp.name, "depth": depth,
                    "ts": sp.t_start, "dur": sp.duration,
                    "status": sp.status,
                }
                if sp.attrs:
                    rec["attrs"] = {k: _jsonable(v)
                                    for k, v in sp.attrs.items()}
                lines.append(json.dumps(rec))
        text = "\n".join(lines) + ("\n" if lines else "")
        if path:
            with open(path, "w") as f:
                f.write(text)
        return text

    def export_chrome_trace(self, path: Optional[str] = None) -> dict:
        """Chrome trace-event JSON (complete events), loadable in
        Perfetto or ``chrome://tracing``."""
        events = []
        for root in self.finished:
            for _, sp in root.walk():
                if sp.t_start is None or sp.t_end is None:
                    continue
                events.append({
                    "name": sp.name, "ph": "X", "cat": "repro",
                    "ts": sp.t_start * 1e6,
                    "dur": max((sp.t_end - sp.t_start) * 1e6, 0.0),
                    "pid": 1, "tid": 1,
                    "args": {k: _jsonable(v)
                             for k, v in sp.attrs.items()},
                })
        doc = {"traceEvents": events, "displayTimeUnit": "ms"}
        if path:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------
class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: float = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Ewma:
    """Exponentially-weighted moving average (first observation seeds
    the value) — e.g. query inter-arrival times for adaptive windowing."""

    __slots__ = ("alpha", "value", "n")

    def __init__(self, alpha: float = 0.2):
        self.alpha = float(alpha)
        self.value = 0.0
        self.n = 0

    def observe(self, v: float) -> None:
        self.n += 1
        self.value = (float(v) if self.n == 1
                      else self.alpha * float(v)
                      + (1.0 - self.alpha) * self.value)


# log-spaced seconds, 10 us .. ~178 s (4 buckets per decade)
DEFAULT_LATENCY_EDGES = tuple(10.0 ** (e / 4.0) for e in range(-20, 10))


class Histogram:
    """Fixed-bucket histogram with interpolated percentiles.

    ``edges`` are bucket UPPER bounds (ascending); one implicit
    overflow bucket catches everything beyond the last edge.  Exact
    min/max are tracked outside the buckets, so ``percentile(0)`` /
    ``percentile(1)`` are exact and interpolation never extrapolates
    past observed values."""

    __slots__ = ("edges", "counts", "count", "total", "vmin", "vmax")

    def __init__(self, edges: Optional[Sequence[float]] = None):
        self.edges = tuple(float(e) for e in
                           (edges if edges is not None
                            else DEFAULT_LATENCY_EDGES))
        if any(b <= a for a, b in zip(self.edges, self.edges[1:])):
            raise ValueError("histogram edges must be ascending")
        self.counts = [0] * (len(self.edges) + 1)
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect_left(self.edges, v)] += 1
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def percentile(self, q: float) -> float:
        """Linear interpolation within the bucket holding the q-th
        rank; NaN when empty."""
        if self.count == 0:
            return float("nan")
        q = min(max(float(q), 0.0), 1.0)
        target = q * self.count
        if target <= 0:
            return self.vmin
        cum = 0
        for i, c in enumerate(self.counts):
            if c and cum + c >= target:
                lo = self.edges[i - 1] if i > 0 else self.vmin
                hi = self.edges[i] if i < len(self.edges) else self.vmax
                lo = max(lo, self.vmin)
                hi = min(hi, self.vmax)
                if hi <= lo:
                    return lo
                frac = (target - cum) / c
                return min(max(lo + frac * (hi - lo), self.vmin),
                           self.vmax)
            cum += c
        return self.vmax

    def as_dict(self) -> dict:
        return {
            "count": self.count,
            "sum": self.total,
            "mean": self.mean,
            "min": self.vmin if self.count else None,
            "max": self.vmax if self.count else None,
            "p50": self.percentile(0.50),
            "p90": self.percentile(0.90),
            "p99": self.percentile(0.99),
        }


def labeled_key(name: str,
                labels: Optional[Mapping[str, Any]] = None) -> str:
    """Canonical rendered key of a (possibly labeled) series:
    ``name`` bare, or ``name{k=v,...}`` with label keys sorted.  This
    is the snapshot's wire format — the ONE place label rendering
    lives, so call sites never mangle names by hand."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Create-on-first-use named metrics; one ``snapshot()`` dict.

    Labeled children (``labels={"tenant": "acme"}``) are independent
    series keyed by :func:`labeled_key`; :meth:`series` enumerates a
    name's children with their label dicts."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._ewmas: Dict[str, Ewma] = {}
        self._histograms: Dict[str, Histogram] = {}
        # base name -> [(labels, rendered key)], insertion-ordered
        self._series: Dict[str, List[Tuple[Dict[str, str], str]]] = {}

    def _key(self, name: str,
             labels: Optional[Mapping[str, Any]]) -> str:
        if not labels:
            return name
        key = labeled_key(name, labels)
        children = self._series.setdefault(name, [])
        if all(k != key for _, k in children):
            children.append(
                ({k: str(v) for k, v in labels.items()}, key))
        return key

    def series(self, name: str) -> List[Tuple[Dict[str, str], str]]:
        """Every labeled child of ``name`` as ``(labels, rendered
        key)`` pairs, in first-use order (empty for unlabeled names)."""
        return list(self._series.get(name, ()))

    # -- accessors (get-or-create) ------------------------------------------
    def counter(self, name: str,
                labels: Optional[Mapping[str, Any]] = None) -> Counter:
        name = self._key(name, labels)
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str,
              labels: Optional[Mapping[str, Any]] = None) -> Gauge:
        name = self._key(name, labels)
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def ewma(self, name: str, alpha: float = 0.2,
             labels: Optional[Mapping[str, Any]] = None) -> Ewma:
        name = self._key(name, labels)
        e = self._ewmas.get(name)
        if e is None:
            e = self._ewmas[name] = Ewma(alpha)
        return e

    def histogram(self, name: str,
                  edges: Optional[Sequence[float]] = None,
                  labels: Optional[Mapping[str, Any]] = None
                  ) -> Histogram:
        name = self._key(name, labels)
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(edges)
        return h

    # -- conveniences --------------------------------------------------------
    def inc(self, name: str, n: float = 1,
            labels: Optional[Mapping[str, Any]] = None) -> None:
        self.counter(name, labels=labels).inc(n)

    def set_gauge(self, name: str, v: float,
                  labels: Optional[Mapping[str, Any]] = None) -> None:
        self.gauge(name, labels=labels).set(v)

    def observe(self, name: str, v: float,
                labels: Optional[Mapping[str, Any]] = None) -> None:
        self.histogram(name, labels=labels).observe(v)

    def value(self, name: str,
              labels: Optional[Mapping[str, Any]] = None) -> float:
        """Current counter value (0 when never incremented)."""
        c = self._counters.get(labeled_key(name, labels))
        return c.value if c is not None else 0

    def snapshot(self) -> dict:
        return {
            "counters": {k: c.value
                         for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value
                       for k, g in sorted(self._gauges.items())},
            "ewmas": {k: {"value": e.value, "n": e.n}
                      for k, e in sorted(self._ewmas.items())},
            "histograms": {k: h.as_dict()
                           for k, h in sorted(self._histograms.items())},
        }
