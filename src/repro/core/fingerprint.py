"""Merkle-tree plan fingerprints (paper §4.1, Definitions 1–3).

The fingerprint of a sub-tree is a cryptographic hash combining the
operator identifier of the root with the fingerprints of its children
(a modified Merkle / hash tree).  Two kinds of operator identifiers:

  * **loose**  — ``ID(u) = (u.label)`` for filter / project / input
    relation.  Different predicates or column lists therefore produce
    the SAME fingerprint, which is what later lets a *shared operator*
    subsume the variants (covering expression).
  * **strict** — ``ID(u) = (u.label, u.attributes)`` for every other
    operator (joins, unions, aggregates, sorts).  Those can only be
    shared when syntactically equal.

For commutative binary operators the child fingerprints are sorted
before hashing so that ``A join B`` and ``B join A`` are isomorphic
(same fingerprint), per the paper's remark under Definition 2.
"""
from __future__ import annotations

import hashlib
from typing import Dict

from .plan import PlanNode

Fingerprint = bytes  # 16-byte digest (truncated sha256)

_DIGEST_BYTES = 16


def _canon(obj: object) -> bytes:
    """Deterministic byte encoding of canonical attribute structures."""
    if obj is None:
        return b"\x00N"
    if isinstance(obj, bytes):
        return b"\x00B" + obj
    if isinstance(obj, str):
        return b"\x00S" + obj.encode("utf-8")
    if isinstance(obj, bool):
        return b"\x00b" + (b"1" if obj else b"0")
    if isinstance(obj, int):
        return b"\x00I" + str(obj).encode()
    if isinstance(obj, float):
        return b"\x00F" + repr(obj).encode()
    if isinstance(obj, (tuple, list)):
        return b"\x00T" + b"".join(_canon(x) for x in obj) + b"\x00t"
    if isinstance(obj, frozenset):
        parts = sorted(_canon(x) for x in obj)
        return b"\x00Z" + b"".join(parts) + b"\x00z"
    raise TypeError(f"unsupported canonical attr type: {type(obj)!r}")


def node_id(node: PlanNode) -> bytes:
    """Operator identifier ID(u) per Definition 1."""
    if node.loose:
        return _canon(node.label)
    return _canon(node.label) + _canon(node.strict_attrs)


def _h(data: bytes) -> Fingerprint:
    return hashlib.sha256(data).digest()[:_DIGEST_BYTES]


def _merkle(node: PlanNode, memo: Dict[int, Fingerprint],
            id_fn, salt: bytes) -> Fingerprint:
    """Shared iterative post-order Merkle walk (no recursion limits);
    ``id_fn`` picks the operator-identifier flavor (loose vs content)."""
    stack = [(node, False)]
    while stack:
        cur, expanded = stack.pop()
        if id(cur) in memo:
            continue
        if not expanded:
            stack.append((cur, True))
            for c in cur.children:
                if id(c) not in memo:
                    stack.append((c, False))
        else:
            child_fps = [memo[id(c)] for c in cur.children]
            if cur.commutative and len(child_fps) > 1:
                child_fps = sorted(child_fps)
            memo[id(cur)] = _h(salt + id_fn(cur) + b"|"
                               + b"|".join(child_fps))
    return memo[id(node)]


def fingerprint(node: PlanNode, memo: Dict[int, Fingerprint] | None = None) -> Fingerprint:
    """F(τ) per Definition 2."""
    if memo is None:
        memo = {}
    return _merkle(node, memo, node_id, b"")


def _content_id(node: PlanNode) -> bytes:
    """Operator identifier INCLUDING loose attributes.

    ψ is deliberately loose (Def. 1) so similar subexpressions share
    it — but that means ψ identifies a covering *structure*, not the
    covering *content*: two batches can produce the same ψ with
    different merged predicates / column sets.  Cross-batch reuse of a
    materialized CE therefore needs this stricter identity.  Loose
    nodes contribute ``content_attrs`` (e.g. a Filter's canonical
    predicate) when they define it; everything else falls back to
    ``strict_attrs``.
    """
    attrs = getattr(node, "content_attrs", None)
    if attrs is None:
        attrs = node.strict_attrs
    return _canon(node.label) + _canon(attrs)


def strict_fingerprint(node: PlanNode) -> Fingerprint:
    """Merkle fingerprint over full operator content (see _content_id).

    Same ψ + same strict fingerprint ⇒ the materialized bytes of one
    tree are a valid covering relation for the other.
    """
    return _merkle(node, {}, _content_id, b"strict|")


def all_fingerprints(node: PlanNode) -> Dict[int, Fingerprint]:
    """Fingerprints of every sub-tree of ``node``, keyed by ``id(sub)``."""
    memo: Dict[int, Fingerprint] = {}
    fingerprint(node, memo)
    return memo


def fingerprint_set(node: PlanNode) -> frozenset:
    """The set of fingerprints of all sub-trees (used for CE disjointness)."""
    return frozenset(all_fingerprints(node).values())
