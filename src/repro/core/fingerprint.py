"""Merkle-tree plan fingerprints (paper §4.1, Definitions 1–3).

The fingerprint of a sub-tree is a cryptographic hash combining the
operator identifier of the root with the fingerprints of its children
(a modified Merkle / hash tree).  Two kinds of operator identifiers:

  * **loose**  — ``ID(u) = (u.label)`` for filter / project / input
    relation.  Different predicates or column lists therefore produce
    the SAME fingerprint, which is what later lets a *shared operator*
    subsume the variants (covering expression).
  * **strict** — ``ID(u) = (u.label, u.attributes)`` for every other
    operator (joins, unions, aggregates, sorts).  Those can only be
    shared when syntactically equal.

For commutative binary operators the child fingerprints are sorted
before hashing so that ``A join B`` and ``B join A`` are isomorphic
(same fingerprint), per the paper's remark under Definition 2.
"""
from __future__ import annotations

import hashlib
from typing import Dict

from .plan import PlanNode

Fingerprint = bytes  # 16-byte digest (truncated sha256)

_DIGEST_BYTES = 16


def _canon(obj: object) -> bytes:
    """Deterministic byte encoding of canonical attribute structures."""
    if obj is None:
        return b"\x00N"
    if isinstance(obj, bytes):
        return b"\x00B" + obj
    if isinstance(obj, str):
        return b"\x00S" + obj.encode("utf-8")
    if isinstance(obj, bool):
        return b"\x00b" + (b"1" if obj else b"0")
    if isinstance(obj, int):
        return b"\x00I" + str(obj).encode()
    if isinstance(obj, float):
        return b"\x00F" + repr(obj).encode()
    if isinstance(obj, (tuple, list)):
        return b"\x00T" + b"".join(_canon(x) for x in obj) + b"\x00t"
    if isinstance(obj, frozenset):
        parts = sorted(_canon(x) for x in obj)
        return b"\x00Z" + b"".join(parts) + b"\x00z"
    raise TypeError(f"unsupported canonical attr type: {type(obj)!r}")


def node_id(node: PlanNode) -> bytes:
    """Operator identifier ID(u) per Definition 1."""
    if node.loose:
        return _canon(node.label)
    return _canon(node.label) + _canon(node.strict_attrs)


def _h(data: bytes) -> Fingerprint:
    return hashlib.sha256(data).digest()[:_DIGEST_BYTES]


def fingerprint(node: PlanNode, memo: Dict[int, Fingerprint] | None = None) -> Fingerprint:
    """F(τ) per Definition 2 (iterative post-order to avoid recursion limits)."""
    if memo is None:
        memo = {}
    stack = [(node, False)]
    while stack:
        cur, expanded = stack.pop()
        if id(cur) in memo:
            continue
        if not expanded:
            stack.append((cur, True))
            for c in cur.children:
                if id(c) not in memo:
                    stack.append((c, False))
        else:
            child_fps = [memo[id(c)] for c in cur.children]
            if cur.commutative and len(child_fps) > 1:
                child_fps = sorted(child_fps)
            memo[id(cur)] = _h(node_id(cur) + b"|" + b"|".join(child_fps))
    return memo[id(node)]


def all_fingerprints(node: PlanNode) -> Dict[int, Fingerprint]:
    """Fingerprints of every sub-tree of ``node``, keyed by ``id(sub)``."""
    memo: Dict[int, Fingerprint] = {}
    fingerprint(node, memo)
    return memo


def fingerprint_set(node: PlanNode) -> frozenset:
    """The set of fingerprints of all sub-trees (used for CE disjointness)."""
    return frozenset(all_fingerprints(node).values())
