"""Budgeted in-memory cache manager for materialized covering relations.

The MCKP decides *admission* offline (the paper's core departure from
eviction-based caching literature); this manager enforces the budget at
materialization time.  Cardinality-estimation error can make the true
materialized size exceed the estimate — mirroring the paper (§6.3,
footnote 6-ii) the overflow is *spilled*: the payload is moved to host
memory (the Spark `MEMORY_AND_DISK` analog on a TPU is HBM → host DRAM
offload) and reads become more expensive.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional


@dataclass
class CacheEntry:
    psi: bytes
    payload: Any                  # device arrays (Table / KV blocks / …)
    nbytes: int
    est_bytes: int
    spilled: bool = False
    hits: int = 0
    created_at: float = field(default_factory=time.monotonic)


@dataclass
class CacheStats:
    budget: int = 0
    used: int = 0
    spilled_bytes: int = 0
    admissions: int = 0
    hits: int = 0
    misses: int = 0

    def as_dict(self) -> dict:
        return dict(budget=self.budget, used=self.used,
                    spilled_bytes=self.spilled_bytes,
                    admissions=self.admissions, hits=self.hits,
                    misses=self.misses)


class CacheManager:
    """Holds materialized CE outputs keyed by fingerprint ψ."""

    def __init__(self, budget_bytes: int,
                 spill_fn: Optional[Callable[[Any], Any]] = None,
                 unspill_fn: Optional[Callable[[Any], Any]] = None):
        self.budget = int(budget_bytes)
        self._entries: Dict[bytes, CacheEntry] = {}
        self._spill_fn = spill_fn
        self._unspill_fn = unspill_fn
        self.stats = CacheStats(budget=self.budget)

    # -- admission ---------------------------------------------------------
    def put(self, psi: bytes, payload: Any, nbytes: int,
            est_bytes: int = 0) -> CacheEntry:
        entry = CacheEntry(psi=psi, payload=payload, nbytes=int(nbytes),
                           est_bytes=int(est_bytes))
        overflow = (self.stats.used + entry.nbytes) - self.budget
        if overflow > 0 and self._spill_fn is not None:
            entry.payload = self._spill_fn(entry.payload)
            entry.spilled = True
            self.stats.spilled_bytes += entry.nbytes
        else:
            self.stats.used += entry.nbytes
        self._entries[psi] = entry
        self.stats.admissions += 1
        return entry

    # -- lookup ------------------------------------------------------------
    def get(self, psi: bytes) -> Optional[Any]:
        entry = self._entries.get(psi)
        if entry is None:
            self.stats.misses += 1
            return None
        entry.hits += 1
        self.stats.hits += 1
        if entry.spilled and self._unspill_fn is not None:
            return self._unspill_fn(entry.payload)
        return entry.payload

    def contains(self, psi: bytes) -> bool:
        return psi in self._entries

    def entry(self, psi: bytes) -> Optional[CacheEntry]:
        return self._entries.get(psi)

    # -- maintenance ---------------------------------------------------------
    def evict(self, psi: bytes) -> None:
        entry = self._entries.pop(psi, None)
        if entry is None:
            return
        if entry.spilled:
            self.stats.spilled_bytes -= entry.nbytes
        else:
            self.stats.used -= entry.nbytes

    def clear(self) -> None:
        self._entries.clear()
        self.stats.used = 0
        self.stats.spilled_bytes = 0

    @property
    def used_bytes(self) -> int:
        return self.stats.used

    def report(self) -> dict:
        return {
            **self.stats.as_dict(),
            "entries": [
                dict(psi=e.psi.hex()[:12], nbytes=e.nbytes,
                     est_bytes=e.est_bytes, spilled=e.spilled, hits=e.hits)
                for e in self._entries.values()
            ],
        }
