"""Budgeted cache of materialized covering relations — a thin pool view
over :class:`repro.core.memory.MemoryManager`.

The MCKP decides *admission* offline (the paper's core departure from
eviction-based caching literature); this view enforces the budget at
materialization time.  Cardinality-estimation error can make the true
materialized size exceed the estimate — mirroring the paper (§6.3,
footnote 6-ii) the overflow takes the manager's spill path: device →
host (the Spark ``MEMORY_AND_DISK`` analog on a TPU is HBM → host DRAM
offload) → drop.

By default the view owns a private single-pool manager with the
``"admission"`` policy (residents are never evicted — pure paper
semantics).  Passing ``manager=`` instead registers the pool on a
shared :class:`MemoryManager`, where the session-level eviction policy
(``lru`` / ``benefit``) and the shared budget apply — the unified
memory hierarchy used by ``relational.Session`` and
``serving.ServingEngine``.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional

from .memory import MemoryEntry, MemoryManager, MemoryPool, PoolStats

# Backward-compatible aliases (PR 2): entries and stats now live in the
# unified memory subsystem.
CacheEntry = MemoryEntry
CacheStats = PoolStats


class CacheTransaction:
    """All-or-nothing multi-entry admission (PR 6).

    A partition-grained CE materializes as several ``(ψ, pid)`` entries;
    a fault part-way through must not leave the earlier partitions
    charged against the pool budget while the CE as a whole is unusable.
    Used as a context manager the transaction rolls back every entry it
    admitted when the block raises, and commits (keeps them) otherwise::

        with cache.transaction() as txn:
            for pid in pids:
                txn.put((psi, pid), tbl, nbytes)

    Rollback evicts through the manager's normal path, so the journal
    records the reversal and ``audit()`` stays clean either way.
    """

    def __init__(self, cache: "CacheManager"):
        self._cache = cache
        self._keys: List[Any] = []
        self.rolled_back = False

    def put(self, psi, payload: Any, nbytes: int,
            est_bytes: int = 0, benefit: float = 0.0) -> MemoryEntry:
        entry = self._cache.put(psi, payload, nbytes=nbytes,
                                est_bytes=est_bytes, benefit=benefit)
        self._keys.append(psi)
        return entry

    def rollback(self) -> int:
        """Evict every entry admitted by this transaction; returns how
        many were reversed."""
        n = 0
        for key in self._keys:
            self._cache.evict(key)
            n += 1
        self._keys.clear()
        self.rolled_back = True
        return n

    def commit(self) -> None:
        self._keys.clear()

    def __enter__(self) -> "CacheTransaction":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self.rollback()
        else:
            self.commit()
        return False                 # never swallow the exception


class CacheManager:
    """Holds materialized CE outputs keyed by fingerprint ψ."""

    def __init__(self, budget_bytes: int,
                 spill_fn: Optional[Callable[[Any], Any]] = None,
                 unspill_fn: Optional[Callable[[Any], Any]] = None,
                 *,
                 manager: Optional[MemoryManager] = None,
                 pool: str = "ce",
                 policy: Optional[str] = None):
        if manager is None:
            manager = MemoryManager(int(budget_bytes),
                                    policy=policy or "admission")
        else:
            assert int(budget_bytes) == manager.device_budget, (
                "a pool view cannot enforce a budget different from its "
                "shared manager's device budget")
        self.manager = manager
        self.budget = manager.device_budget
        self._pool: MemoryPool = manager.pool(
            pool, spill_fn=spill_fn, unspill_fn=unspill_fn, policy=policy)

    # -- admission ---------------------------------------------------------
    def put(self, psi: bytes, payload: Any, nbytes: int,
            est_bytes: int = 0, benefit: float = 0.0) -> MemoryEntry:
        return self._pool.put(psi, payload, nbytes=nbytes,
                              est_bytes=est_bytes, benefit=benefit)

    # -- lookup ------------------------------------------------------------
    def get(self, psi: bytes) -> Optional[Any]:
        return self._pool.get(psi)

    def contains(self, psi: bytes) -> bool:
        return self._pool.contains(psi)

    def touch(self, psi: bytes) -> bool:
        return self._pool.touch(psi)

    def entry(self, psi: bytes) -> Optional[MemoryEntry]:
        return self._pool.entry(psi)

    def resident_psis(self) -> frozenset:
        """ψ of every entry still materialized (device or host tier) —
        the cross-batch reuse set the optimizer re-prices as
        already-paid."""
        return frozenset(self._pool.keys())

    def keys(self):
        """Every live cache key: whole-CE entries are ``bytes`` strict
        fingerprints, partition-grained entries are ``(strict, pid)``
        tuples (see relational.partition)."""
        return self._pool.keys()

    def transaction(self) -> CacheTransaction:
        """Open an all-or-nothing admission scope (see
        :class:`CacheTransaction`)."""
        return CacheTransaction(self)

    # -- maintenance ---------------------------------------------------------
    def evict(self, psi: bytes) -> None:
        self._pool.evict(psi)

    def clear(self) -> None:
        self._pool.clear()

    @property
    def stats(self) -> PoolStats:
        return self._pool.stats

    @property
    def used_bytes(self) -> int:
        return self._pool.used_bytes

    def report(self) -> dict:
        return self._pool.report()
