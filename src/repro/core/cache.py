"""Budgeted cache of materialized covering relations — a thin pool view
over :class:`repro.core.memory.MemoryManager`.

The MCKP decides *admission* offline (the paper's core departure from
eviction-based caching literature); this view enforces the budget at
materialization time.  Cardinality-estimation error can make the true
materialized size exceed the estimate — mirroring the paper (§6.3,
footnote 6-ii) the overflow takes the manager's spill path: device →
host (the Spark ``MEMORY_AND_DISK`` analog on a TPU is HBM → host DRAM
offload) → drop.

By default the view owns a private single-pool manager with the
``"admission"`` policy (residents are never evicted — pure paper
semantics).  Passing ``manager=`` instead registers the pool on a
shared :class:`MemoryManager`, where the session-level eviction policy
(``lru`` / ``benefit``) and the shared budget apply — the unified
memory hierarchy used by ``relational.Session`` and
``serving.ServingEngine``.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

from .memory import MemoryEntry, MemoryManager, MemoryPool, PoolStats

# Backward-compatible aliases (PR 2): entries and stats now live in the
# unified memory subsystem.
CacheEntry = MemoryEntry
CacheStats = PoolStats


class CacheManager:
    """Holds materialized CE outputs keyed by fingerprint ψ."""

    def __init__(self, budget_bytes: int,
                 spill_fn: Optional[Callable[[Any], Any]] = None,
                 unspill_fn: Optional[Callable[[Any], Any]] = None,
                 *,
                 manager: Optional[MemoryManager] = None,
                 pool: str = "ce",
                 policy: Optional[str] = None):
        if manager is None:
            manager = MemoryManager(int(budget_bytes),
                                    policy=policy or "admission")
        else:
            assert int(budget_bytes) == manager.device_budget, (
                "a pool view cannot enforce a budget different from its "
                "shared manager's device budget")
        self.manager = manager
        self.budget = manager.device_budget
        self._pool: MemoryPool = manager.pool(
            pool, spill_fn=spill_fn, unspill_fn=unspill_fn, policy=policy)

    # -- admission ---------------------------------------------------------
    def put(self, psi: bytes, payload: Any, nbytes: int,
            est_bytes: int = 0, benefit: float = 0.0) -> MemoryEntry:
        return self._pool.put(psi, payload, nbytes=nbytes,
                              est_bytes=est_bytes, benefit=benefit)

    # -- lookup ------------------------------------------------------------
    def get(self, psi: bytes) -> Optional[Any]:
        return self._pool.get(psi)

    def contains(self, psi: bytes) -> bool:
        return self._pool.contains(psi)

    def touch(self, psi: bytes) -> bool:
        return self._pool.touch(psi)

    def entry(self, psi: bytes) -> Optional[MemoryEntry]:
        return self._pool.entry(psi)

    def resident_psis(self) -> frozenset:
        """ψ of every entry still materialized (device or host tier) —
        the cross-batch reuse set the optimizer re-prices as
        already-paid."""
        return frozenset(self._pool.keys())

    def keys(self):
        """Every live cache key: whole-CE entries are ``bytes`` strict
        fingerprints, partition-grained entries are ``(strict, pid)``
        tuples (see relational.partition)."""
        return self._pool.keys()

    # -- maintenance ---------------------------------------------------------
    def evict(self, psi: bytes) -> None:
        self._pool.evict(psi)

    def clear(self) -> None:
        self._pool.clear()

    @property
    def stats(self) -> PoolStats:
        return self._pool.stats

    @property
    def used_bytes(self) -> int:
        return self._pool.used_bytes

    def report(self) -> dict:
        return self._pool.report()
