# The paper's primary contribution: cache-based multi-query optimization.
# Generic machinery (fingerprints -> SEs -> CEs -> MCKP -> rewrite), used
# by both the relational engine (faithful repro) and the LLM serving
# layer (beyond-paper prefix-cache MQO).
from .cache import (CacheEntry, CacheManager, CacheStats,
                    CacheTransaction)
from .candidates import KnapsackItem, generate_knapsack_items
from .costmodel import CostModel, price_ce, price_ces, price_resident_ce
from .covering import (CoveringExpression, build_covering_expression,
                       build_covering_expressions)
from .faults import (FAULT_POINTS, DegradationEvent, FaultConfig,
                     FaultInjector, InjectedFault, TransientError)
from .fingerprint import (Fingerprint, all_fingerprints, fingerprint,
                          fingerprint_set, node_id)
from .identify import (Occurrence, SimilarSubexpression,
                       identify_similar_subexpressions)
from .mckp import MCKPSolution, solve_bruteforce, solve_mckp
from .memory import (Journal, MemoryEntry, MemoryManager, MemoryPool,
                     PoolStats)
from .optimizer import MQOReport, MultiQueryOptimizer, OptimizedBatch
from .plan import PlanNode, contains_unfriendly, tree_depth, tree_size, walk
from .rewrite import RewrittenBatch, Rewriter, rewrite_batch

__all__ = [
    "CacheEntry", "CacheManager", "CacheStats", "CacheTransaction",
    "FAULT_POINTS", "DegradationEvent", "FaultConfig", "FaultInjector",
    "InjectedFault", "TransientError", "Journal", "KnapsackItem",
    "generate_knapsack_items", "CostModel", "price_ce", "price_ces",
    "price_resident_ce",
    "CoveringExpression", "build_covering_expression",
    "build_covering_expressions", "Fingerprint", "all_fingerprints",
    "fingerprint", "fingerprint_set", "node_id", "Occurrence",
    "SimilarSubexpression", "identify_similar_subexpressions",
    "MCKPSolution", "solve_bruteforce", "solve_mckp",
    "MemoryEntry", "MemoryManager", "MemoryPool", "PoolStats",
    "MQOReport",
    "MultiQueryOptimizer", "OptimizedBatch", "PlanNode",
    "contains_unfriendly", "tree_depth", "tree_size", "walk",
    "RewrittenBatch", "Rewriter", "rewrite_batch",
]
