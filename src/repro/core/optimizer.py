"""End-to-end multi-query optimizer (the paper's four phases, §4).

    input set ──identify SEs──▶ build CEs ──price──▶ Algorithm 2 groups
       ──MCKP(budget)──▶ selected sharing plans ──rewrite──▶ output set

Generic over the plan type: the caller supplies a cost model, a
rewriter, and (optionally) a CE validator — e.g. the relational layer
rejects CEs whose member variants cannot be re-extracted through a
non-commuting operator.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Mapping, Optional, Sequence

from .candidates import PartitionKnapsackItem, generate_knapsack_items
from .costmodel import CostModel, price_ces, price_resident_ce
from .covering import CoveringExpression, build_covering_expressions
from .identify import identify_similar_subexpressions
from .mckp import MCKPSolution, solve_mckp
from .plan import PlanNode
from .rewrite import RewrittenBatch, Rewriter, rewrite_batch
from .telemetry import NOOP_SPAN


@dataclass
class MQOReport:
    n_queries: int = 0
    n_ses: int = 0
    n_ces: int = 0
    n_valid_ces: int = 0
    n_items: int = 0
    n_resident: int = 0
    n_single_resume: int = 0
    n_hinted: int = 0             # CEs re-priced by a cache_hint()
    n_partitioned: int = 0        # CEs split into per-partition items
    n_partition_items: int = 0
    n_resident_parts: int = 0     # partitions re-priced as already paid
    # queries resumed from a resident CE by predicate SUBSUMPTION (no
    # exact fingerprint match; see relational.canonical) — rewritten
    # before this optimizer ran, recorded here so window reports show
    # semantic reuse next to the re-priced residents it composes with
    n_subsumed: int = 0
    n_selected: int = 0
    selected_value: float = 0.0
    selected_weight: int = 0
    budget: int = 0
    optimize_seconds: float = 0.0
    details: dict = field(default_factory=dict)


@dataclass
class OptimizedBatch:
    rewritten: RewrittenBatch
    solution: MCKPSolution
    report: MQOReport


class MultiQueryOptimizer:
    def __init__(
        self,
        cost_model: CostModel,
        rewriter: Rewriter,
        *,
        budget_bytes: int,
        k: int = 2,
        ce_transform: Optional[
            Callable[[CoveringExpression], Optional[CoveringExpression]]
        ] = None,
        max_compound_size: int = 4,
        chain_cache_plans: bool = True,
        partitioner: Optional[Callable[[CoveringExpression],
                                       Optional[tuple]]] = None,
        tracer=None,
    ):
        self.cost_model = cost_model
        self.rewriter = rewriter
        self.budget = int(budget_bytes)
        self.k = k
        self.ce_transform = ce_transform
        self.max_compound_size = max_compound_size
        self.chain_cache_plans = chain_cache_plans
        # plan-type-specific hook splitting an eligible CE into
        # independent per-partition knapsack items (see
        # repro.relational.partition.make_ce_partitioner); returns
        # (plan_record, [slices]) or None
        self.partitioner = partitioner
        # optional SpanTracer (repro.core.telemetry): phase-level spans
        # for the identify / solve stages when tracing is enabled
        self.tracer = tracer

    def _span(self, name: str, **attrs):
        if self.tracer is not None and self.tracer.enabled:
            return self.tracer.span(name, **attrs)
        return NOOP_SPAN

    def optimize(self, plans: Sequence[PlanNode], *,
                 resident: Optional[Mapping[bytes, object]] = None,
                 resident_parts: Optional[Mapping[bytes, object]] = None,
                 hinted: Optional[frozenset] = None
                 ) -> OptimizedBatch:
        """Run the four phases.  ``resident`` maps the ψ of every CE
        still materialized from a previous window (the unified
        MemoryManager's CE pool) to the strict fingerprint(s) of the
        tree(s) that were materialized — a single ``bytes`` value or a
        collection of them (several same-structure CEs with different
        merged predicates can be resident at once under strict-keyed
        caching).  A new CE whose ψ AND strict content both match is
        re-priced as a zero-weight, already-paid knapsack item — its
        C_E and C_W were spent by window *k*, so window *k+1* pays only
        the reads and per-consumer extraction.  (ψ alone is loose: same
        structure, possibly different merged predicates — the strict
        check is what makes reuse sound.)  This turns per-window MQO
        into cross-window work sharing on recurring workloads.

        Single-query resident resume: subexpressions with fewer than
        ``k`` consumers in THIS window are normally never candidates,
        but when their ψ matches a resident CE they are admitted as
        single-member SEs — a lone recurring query can resume from a
        still-resident covering relation instead of recomputing
        (non-matching singles price at negative value and drop out).

        ``hinted`` is the set of loose ψ under ``cache_hint()``-marked
        submissions: their sub-k SEs are admitted as candidates too,
        and a hinted CE that prices at ≤ 0 is re-priced with one
        *phantom future consumer* (the hint asserts the query recurs),
        so a lone hinted query can materialize covering state for later
        windows to resume from — still subject to the budget."""
        t0 = time.perf_counter()
        report = MQOReport(n_queries=len(plans), budget=self.budget)
        res: Mapping[bytes, frozenset] = {}
        if resident:
            res = {psi: (frozenset((s,)) if isinstance(s, bytes)
                         else frozenset(s))
                   for psi, s in resident.items()}
        hinted = hinted or frozenset()

        # Phase 1: similar subexpression identification (Algorithm 1).
        with self._span("mqo.identify", n_queries=len(plans)) as sp:
            if (res or hinted) and self.k > 1:
                # one k=1 walk, partitioned: the >= k SEs are exactly
                # what identify(k=self.k) returns (k only filters at
                # the end), and sub-k SEs whose structure matches a
                # resident CE (or a cache hint) are admitted too, so
                # the strict content check below can decide
                # single-query resident resume
                every = identify_similar_subexpressions(plans, k=1)
                ses = [se for se in every if se.m >= self.k]
                ses += [se for se in every
                        if se.m < self.k and (se.psi in res
                                              or se.psi in hinted)]
            else:
                ses = identify_similar_subexpressions(plans, k=self.k)
            sp.set(n_ses=len(ses))
        report.n_ses = len(ses)

        # Phase 2a: covering expressions (+ plan-type specific transform:
        # extractability validation, projection augmentation, ...).
        ces = build_covering_expressions(ses)
        report.n_ces = len(ces)
        if self.ce_transform is not None:
            ces = [t for t in (self.ce_transform(ce) for ce in ces)
                   if t is not None]
        report.n_valid_ces = len(ces)

        # Phase 2b: pricing (Eq. 1–3) + Algorithm 2 candidate groups.
        price_ces(ces, self.cost_model)
        for ce in ces:
            if ce.psi not in hinted or ce.value > 0:
                continue
            # phantom future consumer: the hint asserts the query
            # recurs, so credit one extra read's worth of sharing —
            # avg per-consumer unshared cost minus the read +
            # extraction it would pay (never a net penalty)
            d = ce.cost_detail
            m = max(ce.m, 1)
            marginal = ((d["C_omega"] - d["C_X"]) / m) - d["C_R"]
            if marginal > 0:
                ce.value += marginal
                ce.cost_detail = {**d, "hinted": True}
                report.n_hinted += 1

        # Partition-grained admission: split eligible CEs into
        # independent per-partition items so the solver can keep the
        # hot fraction of a CE the budget cannot hold whole.  Only CEs
        # structurally disjoint from every other CE are split — a
        # nested CE stays in its Algorithm 2 group, where mutual
        # exclusion with its ancestors/descendants is what keeps
        # value/weight additive.  Must run BEFORE resident re-pricing:
        # a partitioned CE's residency is per partition, so whole-CE
        # re-pricing (which assumes all bytes are resident) would be
        # unsound for it.
        partitioned: List[CoveringExpression] = []
        if self.partitioner is not None:
            for ce in ces:
                if any(o is not ce and (ce.psi in o.fp_set
                                        or o.psi in ce.fp_set)
                       for o in ces):
                    continue
                detail = self.partitioner(ce)
                if detail is not None:
                    ce.partition_detail = detail
                    partitioned.append(ce)
        report.n_partitioned = len(partitioned)

        if res:
            for ce in ces:
                # cheap psi membership first — the strict content hash
                # (a full Merkle walk, memoized on the CE) only runs
                # for actual candidates
                if (ce.partition_detail is None and ce.psi in res
                        and ce.strict_psi() in res[ce.psi]):
                    price_resident_ce(ce)
                    report.n_resident += 1
                    if ce.m < self.k:
                        report.n_single_resume += 1
        items = generate_knapsack_items(
            [ce for ce in ces if ce.partition_detail is None],
            max_compound_size=self.max_compound_size)
        gid = 1 + max((it.group for it in items), default=-1)
        rp = resident_parts or {}
        for ce in partitioned:
            _, slices = ce.partition_detail
            res_pids = rp.get(ce.strict_psi(), frozenset())
            for sl in slices:
                if sl.pid in res_pids:
                    # this partition's bytes are already materialized:
                    # C_E and C_W are sunk, weight is zero (the
                    # per-partition analog of price_resident_ce)
                    item = PartitionKnapsackItem(
                        ce, sl.pid, value=max(sl.resident_value, 1e-12),
                        weight=0, group=gid)
                    report.n_resident_parts += 1
                else:
                    item = PartitionKnapsackItem(
                        ce, sl.pid, value=sl.value, weight=sl.weight,
                        group=gid)
                gid += 1
                if item.value > 0:
                    items.append(item)
        report.n_items = len(items)
        report.n_partition_items = sum(
            1 for it in items if isinstance(it, PartitionKnapsackItem))

        # Phase 3: sharing-plan selection (MCKP, Eq. 5).
        with self._span("mqo.solve", n_items=len(items),
                        budget=self.budget) as sp:
            solution = solve_mckp(items, self.budget)
            sp.set(selected_value=solution.total_value,
                   selected_weight=solution.total_weight)
        for it in solution.items:
            if isinstance(it, PartitionKnapsackItem):
                have = it.ce.admitted_partitions or frozenset()
                it.ce.admitted_partitions = have | {it.pid}
        selected: List[CoveringExpression] = []
        seen_ids = set()
        for ce in solution.ces:
            if id(ce) not in seen_ids:
                seen_ids.add(id(ce))
                selected.append(ce)
        report.n_selected = len(selected)
        report.selected_value = solution.total_value
        report.selected_weight = solution.total_weight

        # Phase 4: query rewriting.
        rewritten = rewrite_batch(
            plans, selected, self.rewriter,
            chain_cache_plans=self.chain_cache_plans)

        report.optimize_seconds = time.perf_counter() - t0
        report.details = {
            "ces": [
                {"label": ce.tree.label, "value": ce.value,
                 "weight": ce.weight, **ce.cost_detail}
                for ce in ces
            ],
        }
        return OptimizedBatch(rewritten=rewritten, solution=solution,
                              report=report)
