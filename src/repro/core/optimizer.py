"""End-to-end multi-query optimizer (the paper's four phases, §4).

    input set ──identify SEs──▶ build CEs ──price──▶ Algorithm 2 groups
       ──MCKP(budget)──▶ selected sharing plans ──rewrite──▶ output set

Generic over the plan type: the caller supplies a cost model, a
rewriter, and (optionally) a CE validator — e.g. the relational layer
rejects CEs whose member variants cannot be re-extracted through a
non-commuting operator.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Mapping, Optional, Sequence

from .candidates import generate_knapsack_items
from .costmodel import CostModel, price_ces, price_resident_ce
from .covering import CoveringExpression, build_covering_expressions
from .identify import identify_similar_subexpressions
from .mckp import MCKPSolution, solve_mckp
from .plan import PlanNode
from .rewrite import RewrittenBatch, Rewriter, rewrite_batch


@dataclass
class MQOReport:
    n_queries: int = 0
    n_ses: int = 0
    n_ces: int = 0
    n_valid_ces: int = 0
    n_items: int = 0
    n_resident: int = 0
    n_selected: int = 0
    selected_value: float = 0.0
    selected_weight: int = 0
    budget: int = 0
    optimize_seconds: float = 0.0
    details: dict = field(default_factory=dict)


@dataclass
class OptimizedBatch:
    rewritten: RewrittenBatch
    solution: MCKPSolution
    report: MQOReport


class MultiQueryOptimizer:
    def __init__(
        self,
        cost_model: CostModel,
        rewriter: Rewriter,
        *,
        budget_bytes: int,
        k: int = 2,
        ce_transform: Optional[
            Callable[[CoveringExpression], Optional[CoveringExpression]]
        ] = None,
        max_compound_size: int = 4,
        chain_cache_plans: bool = True,
    ):
        self.cost_model = cost_model
        self.rewriter = rewriter
        self.budget = int(budget_bytes)
        self.k = k
        self.ce_transform = ce_transform
        self.max_compound_size = max_compound_size
        self.chain_cache_plans = chain_cache_plans

    def optimize(self, plans: Sequence[PlanNode], *,
                 resident: Optional[Mapping[bytes, bytes]] = None
                 ) -> OptimizedBatch:
        """Run the four phases.  ``resident`` maps the ψ of every CE
        still materialized from a previous batch (the unified
        MemoryManager's CE pool) to the strict fingerprint of the tree
        that was materialized.  A new CE whose ψ AND strict content
        both match is re-priced as a zero-weight, already-paid knapsack
        item — its C_E and C_W were spent by batch *k*, so batch *k+1*
        pays only the reads and per-consumer extraction.  (ψ alone is
        loose: same structure, possibly different merged predicates —
        the strict check is what makes reuse sound.)  This turns
        per-batch MQO into cross-batch work sharing on recurring
        workloads."""
        t0 = time.perf_counter()
        report = MQOReport(n_queries=len(plans), budget=self.budget)

        # Phase 1: similar subexpression identification (Algorithm 1).
        ses = identify_similar_subexpressions(plans, k=self.k)
        report.n_ses = len(ses)

        # Phase 2a: covering expressions (+ plan-type specific transform:
        # extractability validation, projection augmentation, ...).
        ces = build_covering_expressions(ses)
        report.n_ces = len(ces)
        if self.ce_transform is not None:
            ces = [t for t in (self.ce_transform(ce) for ce in ces)
                   if t is not None]
        report.n_valid_ces = len(ces)

        # Phase 2b: pricing (Eq. 1–3) + Algorithm 2 candidate groups.
        price_ces(ces, self.cost_model)
        if resident:
            for ce in ces:
                # cheap psi membership first — the strict content hash
                # (a full Merkle walk, memoized on the CE) only runs
                # for actual candidates
                if (ce.psi in resident
                        and resident[ce.psi] == ce.strict_psi()):
                    price_resident_ce(ce)
                    report.n_resident += 1
        items = generate_knapsack_items(
            ces, max_compound_size=self.max_compound_size)
        report.n_items = len(items)

        # Phase 3: sharing-plan selection (MCKP, Eq. 5).
        solution = solve_mckp(items, self.budget)
        selected: List[CoveringExpression] = solution.ces
        report.n_selected = len(selected)
        report.selected_value = solution.total_value
        report.selected_weight = solution.total_weight

        # Phase 4: query rewriting.
        rewritten = rewrite_batch(
            plans, selected, self.rewriter,
            chain_cache_plans=self.chain_cache_plans)

        report.optimize_seconds = time.perf_counter() - t0
        report.details = {
            "ces": [
                {"label": ce.tree.label, "value": ce.value,
                 "weight": ce.weight, **ce.cost_detail}
                for ce in ces
            ],
        }
        return OptimizedBatch(rewritten=rewritten, solution=solution,
                              report=report)
