"""Multiple-Choice Knapsack solver (paper §4.3, Eq. 5).

Dynamic programming over a discretized capacity axis.  Selecting *at
most* one item per group (the classic MCKP uses exactly-one; the paper's
constraint is ≤ 1, equivalent to adding a zero-value/zero-weight item to
every group).  Weights are bytes, so the capacity axis is bucketed at a
configurable resolution — weights are rounded UP, hence the real budget
is never exceeded (the solution can only be conservatively sub-optimal
by the rounding slack).

Zero-weight items — cross-batch residents the optimizer re-prices as
"already paid" (their bytes are materialized from a previous batch) —
are lifted out of the DP: within a group the best zero-weight option is
a free baseline, so it is credited up front and every heavier option in
the group competes with its value *relative to* that baseline.  This is
an exact transformation (choosing nothing from the transformed group
means choosing the baseline) and keeps the capacity axis reserved for
bytes that still need materializing.

Partition-grained CEs (repro.relational.partition) feed the solver one
item PER PARTITION, each in its own singleton group
(candidates.PartitionKnapsackItem): partitions of a CE are
independently admissible, so under a budget that cannot hold the full
CE the DP admits a strict subset — the CE's hot fraction — and a
partition already resident from an earlier window arrives as a
zero-weight item and rides the same baseline lifting.  The solver only
sees the (value, weight, group) protocol; nothing here is
partition-specific.

``solve_bruteforce`` enumerates all choices and is used by property
tests to validate the DP.
"""
from __future__ import annotations

import math
from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List, Sequence

from .candidates import KnapsackItem


@dataclass
class MCKPSolution:
    items: List[KnapsackItem]
    total_value: float
    total_weight: int      # true (un-bucketed) bytes
    capacity: int
    buckets: int

    @property
    def ces(self):
        return [ce for item in self.items for ce in item.ces]


def solve_mckp(
    items: Sequence[KnapsackItem],
    capacity: int,
    *,
    max_buckets: int = 4096,
) -> MCKPSolution:
    """DP solution of Eq. 5.  O(g · |G_i| · buckets) time."""
    feasible = [it for it in items if it.weight <= capacity and it.value > 0]
    if not feasible or capacity < 0:
        return MCKPSolution([], 0.0, 0, capacity, 0)

    groups: Dict[int, List[KnapsackItem]] = defaultdict(list)
    for it in feasible:
        groups[it.group].append(it)

    # Lift out zero-weight (already-paid) baselines per group.
    base_value = 0.0
    base_choice: Dict[int, KnapsackItem] = {}
    base_of: Dict[int, float] = {}
    for gid in list(groups):
        zero = [it for it in groups[gid] if it.weight == 0]
        if not zero:
            continue
        best = max(zero, key=lambda it: it.value)
        base_value += best.value
        base_choice[gid] = best
        base_of[gid] = best.value
        # only heavier options that beat the free baseline stay in play
        groups[gid] = [it for it in groups[gid]
                       if it.weight > 0 and it.value > best.value]
        if not groups[gid]:
            del groups[gid]
    if not groups:
        picked0 = list(base_choice.values())
        return MCKPSolution(picked0, base_value, 0, capacity, 0)
    group_ids = sorted(groups)

    resolution = max(1, math.ceil(capacity / max_buckets))
    n_buckets = capacity // resolution
    scaled = {
        id(it): min(n_buckets + 1, math.ceil(it.weight / resolution)) if it.weight > 0 else 0
        for it in feasible
    }

    NEG = float("-inf")
    # dp[c] = best value using groups processed so far with scaled weight ≤ c
    dp = [0.0] * (n_buckets + 1)
    # choice[gi][c] = item chosen for group gi at capacity c (or None)
    choice: List[List[KnapsackItem | None]] = []

    for gi in group_ids:
        new_dp = list(dp)
        ch: List[KnapsackItem | None] = [None] * (n_buckets + 1)
        for it in groups[gi]:
            w = scaled[id(it)]
            if w > n_buckets:
                continue
            v = it.value - base_of.get(gi, 0.0)
            for c in range(n_buckets, w - 1, -1):
                cand = dp[c - w] + v
                if cand > new_dp[c]:
                    new_dp[c] = cand
                    ch[c] = it
        dp = new_dp
        choice.append(ch)

    # Backtrack from the best capacity.
    best_c = max(range(n_buckets + 1), key=lambda c: dp[c])
    picked: List[KnapsackItem] = []
    chosen_groups = set()
    c = best_c
    for gi_idx in range(len(group_ids) - 1, -1, -1):
        it = choice[gi_idx][c]
        if it is not None:
            picked.append(it)
            chosen_groups.add(group_ids[gi_idx])
            c -= scaled[id(it)]
    picked.reverse()
    # groups whose DP choice did not beat their free baseline keep it
    picked.extend(it for gid, it in sorted(base_choice.items())
                  if gid not in chosen_groups)

    total_w = sum(it.weight for it in picked)
    total_v = sum(it.value for it in picked)
    assert total_w <= capacity, "MCKP DP exceeded the memory budget"
    return MCKPSolution(picked, total_v, total_w, capacity, n_buckets)


def solve_bruteforce(items: Sequence[KnapsackItem], capacity: int) -> MCKPSolution:
    """Exact enumeration (exponential) — for tests on small instances."""
    groups: Dict[int, List[KnapsackItem]] = defaultdict(list)
    for it in items:
        groups[it.group].append(it)
    group_lists = [gs + [None] for gs in groups.values()]  # None = skip group

    best: tuple[float, int, List[KnapsackItem]] = (0.0, 0, [])

    def rec(i: int, value: float, weight: int, chosen: List[KnapsackItem]):
        nonlocal best
        if weight > capacity:
            return
        if i == len(group_lists):
            if value > best[0] + 1e-12:
                best = (value, weight, list(chosen))
            return
        for it in group_lists[i]:
            if it is None:
                rec(i + 1, value, weight, chosen)
            else:
                chosen.append(it)
                rec(i + 1, value + it.value, weight + it.weight, chosen)
                chosen.pop()

    rec(0, 0.0, 0, [])
    return MCKPSolution(best[2], best[0], best[1], capacity, 0)
