"""Abstract plan-node protocol shared by every MQO instantiation.

The paper's MQO machinery (fingerprints, SE identification, covering
expressions, cost model, MCKP, rewriting) is generic over the *kind* of
plan being optimized.  Two instantiations live in this repo:

  * ``repro.relational`` — SparkSQL-analog logical plans (the faithful
    reproduction of the paper), and
  * ``repro.serving``    — token-block prefix plans for LLM serving
    (the beyond-paper integration).

A plan node is an immutable tree.  Every node exposes:

  ``children``        tuple of child nodes (0 = leaf, 1 = unary, 2 = binary)
  ``label``           operator label (string).  For leaves the label must
                      identify the input relation (e.g. ``scan:employees``).
  ``loose``           True for operators fingerprinted by label only
                      (paper Def. 1: filter / project / input relation);
                      False for strict operators (label + attributes).
  ``strict_attrs``    hashable canonical attributes, used when ``loose``
                      is False.
  ``cache_friendly``  False for join / cartesian / union — the paper's
                      "cache unfriendly" operators (§4.1).
  ``commutative``     True when child order must not affect the
                      fingerprint (isomorphism property, Def. 2 remark).
  ``merge(others)``   build the covering node for this node merged with
                      the structurally-identical nodes of other SE members
                      (OR of predicates, union of projections, identity
                      for strict operators).
"""
from __future__ import annotations

from typing import Iterator, Protocol, Sequence, runtime_checkable


@runtime_checkable
class PlanNode(Protocol):
    @property
    def children(self) -> tuple["PlanNode", ...]: ...

    @property
    def label(self) -> str: ...

    @property
    def loose(self) -> bool: ...

    @property
    def strict_attrs(self) -> object: ...

    @property
    def cache_friendly(self) -> bool: ...

    @property
    def commutative(self) -> bool: ...

    def merge(self, others: Sequence["PlanNode"]) -> "PlanNode": ...

    def with_children(self, children: tuple["PlanNode", ...]) -> "PlanNode": ...


def walk(node: PlanNode) -> Iterator[PlanNode]:
    """Pre-order traversal of a plan tree."""
    stack = [node]
    while stack:
        cur = stack.pop()
        yield cur
        stack.extend(reversed(cur.children))


def tree_size(node: PlanNode) -> int:
    """Number of operators in the sub-tree rooted at ``node``."""
    return sum(1 for _ in walk(node))


def contains_unfriendly(node: PlanNode) -> bool:
    """True when any descendant (or the node itself) is cache-unfriendly."""
    return any(not n.cache_friendly for n in walk(node))


def tree_depth(node: PlanNode) -> int:
    if not node.children:
        return 1
    return 1 + max(tree_depth(c) for c in node.children)
