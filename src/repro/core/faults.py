"""Deterministic fault injection: named failure points on the critical
path, driven by a seeded schedule.

Shared-work execution couples failure domains: one poisoned query, one
OOM during CE materialization, or one transient device failure can take
down a whole MQO window and strand bytes in the memory pools.  The
resilience layer (per-query isolation in ``relational.service``, the
degradation ladder in ``relational.executor``, transactional pools in
``core.memory``) exists to prevent exactly that — and every one of its
paths must be *property-tested rather than hoped-for*.  This module is
the test driver: each named :data:`FAULT_POINTS` site calls
``injector.check(point)`` on the hot path, and a seeded
:class:`FaultSchedule` decides deterministically whether that
invocation raises :class:`InjectedFault`.

Two scheduling modes, freely combined per point:

* **Bernoulli** — ``rate`` (global) / ``rates[point]`` (override): each
  invocation of the point fires independently with that probability,
  drawn from a per-point ``random.Random`` stream seeded by
  ``(seed, point)``.  The decision sequence is a pure function of the
  seed and the per-point invocation count, so the same workload replays
  the same faults.
* **Explicit** — ``schedule[point] = (i, j, ...)``: fire exactly at the
  given 0-based invocation indices of that point (targeted tests, e.g.
  "fail the SECOND partition admission of this CE").

Named points (wired in ``relational.physical`` / ``core.memory`` /
``relational.service``):

    ``scan_h2d``       host→device transfer of scan columns
    ``kernel_launch``  fused-pipeline dispatch (Pallas or fused-XLA)
    ``batched_launch`` a window's SHARED batched mask dispatch (fires
                       once per window when >= 2 plans are batchable;
                       the window degrades to per-query dispatch)
    ``ce_admission``   CE materialization entering the cache pool
    ``spill_to_host``  device→host spill of an eviction victim
    ``window_close``   the service's window close/execute step
    ``pid_pool``       a partition-ID bitset read (PR 8); a failure
                       degrades to stats-only pruning — a pid hit is an
                       optimization, never a failure domain
    ``async_close``    the async front's background window-closer task
                       (PR 10) closing a deadline-expired window; a
                       fire crashes the closer task — the supervisor
                       restarts it and the due window closes on the
                       next pass, so every pending handle still
                       resolves

Configuration rides on ``SessionConfig.resilience.faults`` (a
:class:`FaultConfig`); a session without one injects nothing and pays
only an attribute check per site.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Tuple

FAULT_POINTS = ("scan_h2d", "kernel_launch", "batched_launch",
                "ce_admission", "spill_to_host", "window_close",
                "pid_pool", "async_close")


class TransientError(RuntimeError):
    """Base for failures the resilience layer may retry: the operation
    is expected to succeed on a later attempt (transient device/transfer
    faults).  Non-transient exceptions (a genuinely poisoned query) are
    not retried beyond the degradation ladder's bounded attempts."""


class InjectedFault(TransientError):
    """A scheduled failure fired at a named fault point."""

    def __init__(self, point: str, index: int, key=None):
        self.point = point
        self.index = index          # per-point invocation index
        self.key = key              # site detail (e.g. CE fingerprint)
        detail = f", key={key!r}" if key is not None else ""
        super().__init__(
            f"injected fault at {point!r} (invocation {index}{detail})")


@dataclass(frozen=True)
class FaultConfig:
    """Seeded fault schedule (``SessionConfig.resilience.faults``).

    ``rate`` is the default per-invocation Bernoulli probability for
    every point; ``rates`` overrides it per point; ``schedule`` adds
    exact invocation indices that always fire.  ``max_faults`` bounds
    the total number of fires (a soak can guarantee forward progress).
    """

    seed: int = 0
    rate: float = 0.0
    rates: Optional[Mapping[str, float]] = None
    schedule: Optional[Mapping[str, Tuple[int, ...]]] = None
    max_faults: Optional[int] = None

    def __post_init__(self):
        for pt in (self.rates or {}):
            assert pt in FAULT_POINTS, f"unknown fault point {pt!r}"
        for pt in (self.schedule or {}):
            assert pt in FAULT_POINTS, f"unknown fault point {pt!r}"

    @property
    def enabled(self) -> bool:
        return (self.rate > 0.0 or bool(self.rates)
                or bool(self.schedule))


@dataclass
class FaultRecord:
    point: str
    index: int
    key: object = None


class FaultInjector:
    """Runtime half of the schedule: per-point invocation counters plus
    the seeded decision streams.  ``check`` is the only hot-path call;
    everything else is telemetry for tests and window reports."""

    def __init__(self, config: FaultConfig):
        self.config = config
        self._counts: Dict[str, int] = {p: 0 for p in FAULT_POINTS}
        self._rngs: Dict[str, random.Random] = {
            p: random.Random(f"{config.seed}:{p}") for p in FAULT_POINTS}
        self._scheduled = {p: frozenset(v) for p, v in
                           (config.schedule or {}).items()}
        self.fired: List[FaultRecord] = []
        self.suppressed = 0         # fires skipped past max_faults
        # optional core.telemetry.MetricsRegistry; when set, every
        # check() mirrors its outcome into fault.* counters so soak
        # tests can assert fault/degradation counts from one place
        self.registry = None

    @classmethod
    def from_config(cls, config: Optional[FaultConfig]
                    ) -> Optional["FaultInjector"]:
        if config is None or not config.enabled:
            return None
        return cls(config)

    def _rate(self, point: str) -> float:
        rates = self.config.rates
        if rates is not None and point in rates:
            return float(rates[point])
        return float(self.config.rate)

    def check(self, point: str, key=None) -> None:
        """Count one invocation of ``point``; raise :class:`InjectedFault`
        when the schedule says this one fails.  The Bernoulli stream is
        advanced on EVERY invocation (fired or not), so the decision
        sequence depends only on the seed and the invocation index —
        not on which earlier faults were caught or retried."""
        assert point in FAULT_POINTS, f"unknown fault point {point!r}"
        index = self._counts[point]
        self._counts[point] = index + 1
        reg = self.registry
        if reg is not None:
            reg.inc(f"fault.invocations.{point}")
        draw = self._rngs[point].random()
        fire = index in self._scheduled.get(point, frozenset())
        rate = self._rate(point)
        if not fire and rate > 0.0:
            fire = draw < rate
        if not fire:
            return
        mx = self.config.max_faults
        if mx is not None and len(self.fired) >= mx:
            self.suppressed += 1
            if reg is not None:
                reg.inc("fault.suppressed")
            return
        rec = FaultRecord(point=point, index=index, key=key)
        self.fired.append(rec)
        if reg is not None:
            reg.inc(f"fault.fired.{point}")
            reg.inc("fault.fired.total")
        raise InjectedFault(point, index, key=key)

    # -- telemetry -----------------------------------------------------------
    @property
    def n_fired(self) -> int:
        return len(self.fired)

    def invocations(self, point: str) -> int:
        return self._counts[point]

    def fired_by_point(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for rec in self.fired:
            out[rec.point] = out.get(rec.point, 0) + 1
        return out

    def report(self) -> dict:
        return {
            "seed": self.config.seed,
            "invocations": dict(self._counts),
            "fired": self.fired_by_point(),
            "n_fired": self.n_fired,
            "suppressed": self.suppressed,
        }


@dataclass
class DegradationEvent:
    """One step of a query's journey down the resilience ladder —
    collected into the window report (``BatchResult.resilience``) and
    the failed handle's ``explain()``."""

    query: int                    # position in the window
    attempt: int                  # 1-based execution attempt
    action: str                   # "retry" | "degrade" | "fallback" | ...
    level: str                    # route after the action
    error: str = ""               # repr of the triggering exception
    detail: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return dict(query=self.query, attempt=self.attempt,
                    action=self.action, level=self.level,
                    error=self.error, **self.detail)
