"""CE value / weight pricing (paper §4.2, Equations 1–3).

The cost model is abstract here: a concrete :class:`CostModel` knows how
to price the execution of a sub-tree (CPU + disk + network), the cost of
materializing ``n`` output bytes into the cache, and the cost of reading
them back.  ``repro.relational.stats`` supplies the SparkSQL-analog
implementation (cardinality-estimation based); ``repro.serving`` supplies
a FLOPs/HBM-based one for prefix caching.

    C(ω_i) = Σ_j C_E(τ_j)                                     (Eq. 1)
    C(Ω_i) = C_E(τ*_i) + C_W(|τ*_i|) + m · C_R(|τ*_i|)        (Eq. 2)
    v(Ω_i) = C(ω_i) − C(Ω_i)                                  (Eq. 3)
    w(Ω_i) = |τ*_i|  (bytes of the materialized output)
"""
from __future__ import annotations

from typing import Protocol, Sequence

from .covering import CoveringExpression
from .plan import PlanNode


class CostModel(Protocol):
    def execution_cost(self, tree: PlanNode) -> float:
        """C_E(τ): estimated cost of computing τ's output from scratch."""
        ...

    def output_rows(self, tree: PlanNode) -> int:
        """Estimated output cardinality |τ| in rows (or tokens)."""
        ...

    def output_bytes(self, tree: PlanNode) -> int:
        """Estimated materialized size of τ's output, in bytes."""
        ...

    def write_cost(self, tree: PlanNode) -> float:
        """C_W(|τ|): cost of materializing the output into the cache."""
        ...

    def read_cost(self, tree: PlanNode) -> float:
        """C_R(|τ|): cost of one consumer reading the cached output."""
        ...

    # Optional: concrete models may also provide
    #   extraction_cost(tree, member) -> float
    # pricing the per-consumer residual work (re-applying the member's
    # own filter/project over the cached CE output — one fused pipeline
    # pass in the relational engine).  When absent, consumers are priced
    # as m bare cache reads, which overvalues CEs whose members diverge
    # from the covering expression.


def price_ce(ce: CoveringExpression, model: CostModel) -> CoveringExpression:
    """Fill ``value`` / ``weight`` of a CE in-place (returns it too)."""
    unshared = sum(model.execution_cost(o.node) for o in ce.se.occurrences)
    exec_ce = model.execution_cost(ce.tree)
    write_c = model.write_cost(ce.tree)
    read_c = model.read_cost(ce.tree)
    extraction = getattr(model, "extraction_cost", None)
    if extraction is not None:
        extract_c = sum(extraction(ce.tree, o.node)
                        for o in ce.se.occurrences)
    else:
        extract_c = 0.0
    total_ce = exec_ce + write_c + ce.m * read_c + extract_c
    ce.value = unshared - total_ce
    ce.weight = int(model.output_bytes(ce.tree))
    ce.est_rows = int(model.output_rows(ce.tree))
    ce.cost_detail = {
        "C_omega": unshared,
        "C_E_star": exec_ce,
        "C_W": write_c,
        "C_R": read_c,
        "C_X": extract_c,
        "m": ce.m,
        "C_Omega": total_ce,
    }
    return ce


def price_ces(ces: Sequence[CoveringExpression], model: CostModel):
    for ce in ces:
        price_ce(ce, model)
    return list(ces)


def price_resident_ce(ce: CoveringExpression) -> CoveringExpression:
    """Eq. 2 for an already-materialized CE (cross-batch retention):
    C_E(τ*) and C_W are sunk costs paid by the batch that admitted it,
    so the remaining price is m reads plus per-consumer extraction, and
    the knapsack weight is zero — the bytes already sit inside the
    memory manager's accounting.  Must run after :func:`price_ce` (it
    consumes the cost_detail breakdown)."""
    d = ce.cost_detail
    remaining = ce.m * d.get("C_R", 0.0) + d.get("C_X", 0.0)
    ce.value = d.get("C_omega", ce.value) - remaining
    ce.weight = 0
    ce.cost_detail = {**d, "resident": True, "C_Omega": remaining}
    return ce
