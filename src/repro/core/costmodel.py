"""CE value / weight pricing (paper §4.2, Equations 1–3).

The cost model is abstract here: a concrete :class:`CostModel` knows how
to price the execution of a sub-tree (CPU + disk + network), the cost of
materializing ``n`` output bytes into the cache, and the cost of reading
them back.  ``repro.relational.stats`` supplies the SparkSQL-analog
implementation (cardinality-estimation based); ``repro.serving`` supplies
a FLOPs/HBM-based one for prefix caching.

    C(ω_i) = Σ_j C_E(τ_j)                                     (Eq. 1)
    C(Ω_i) = C_E(τ*_i) + C_W(|τ*_i|) + m · C_R(|τ*_i|)        (Eq. 2)
    v(Ω_i) = C(ω_i) − C(Ω_i)                                  (Eq. 3)
    w(Ω_i) = |τ*_i|  (bytes of the materialized output)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Sequence

from .covering import CoveringExpression
from .plan import PlanNode


class CostModel(Protocol):
    def execution_cost(self, tree: PlanNode) -> float:
        """C_E(τ): estimated cost of computing τ's output from scratch."""
        ...

    def output_rows(self, tree: PlanNode) -> int:
        """Estimated output cardinality |τ| in rows (or tokens)."""
        ...

    def output_bytes(self, tree: PlanNode) -> int:
        """Estimated materialized size of τ's output, in bytes."""
        ...

    def write_cost(self, tree: PlanNode) -> float:
        """C_W(|τ|): cost of materializing the output into the cache."""
        ...

    def read_cost(self, tree: PlanNode) -> float:
        """C_R(|τ|): cost of one consumer reading the cached output."""
        ...

    # Optional: concrete models may also provide
    #   extraction_cost(tree, member) -> float
    # pricing the per-consumer residual work (re-applying the member's
    # own filter/project over the cached CE output — one fused pipeline
    # pass in the relational engine).  When absent, consumers are priced
    # as m bare cache reads, which overvalues CEs whose members diverge
    # from the covering expression.
    #
    # Optional: concrete models may also provide
    #   calibration() -> dict
    # the predicted-vs-measured accuracy report assembled from an
    # attached CalibrationLog (set ``model.calibration_log``) — see
    # below.  repro.relational.stats.RelationalCostModel implements it.


# ---------------------------------------------------------------------------
# cost-model accuracy accounting
# ---------------------------------------------------------------------------
@dataclass
class CalibrationSample:
    """One predicted-vs-measured observation: a CE materialization
    (Eq. 2's C_E(τ*) + C_W against the wall clock) or a cached read
    (C_R against the wall clock).  Costs are in the model's arbitrary
    time units; ``measured_seconds`` is wall time — the per-kind ratio
    of the two sums is the model's implied unit scale, and the spread
    of per-sample ratios around it is its (in)accuracy."""

    kind: str                      # "materialize" | "cached_read"
    key: str                       # strict fingerprint hex (short)
    predicted_cost: float
    measured_seconds: float
    predicted_bytes: int = 0
    measured_bytes: int = 0
    predicted_rows: int = 0
    measured_rows: int = 0

    def as_dict(self) -> dict:
        return {
            "kind": self.kind, "key": self.key,
            "predicted_cost": self.predicted_cost,
            "measured_seconds": self.measured_seconds,
            "predicted_bytes": self.predicted_bytes,
            "measured_bytes": self.measured_bytes,
            "predicted_rows": self.predicted_rows,
            "measured_rows": self.measured_rows,
        }


@dataclass
class CalibrationLog:
    """Accumulates :class:`CalibrationSample`\\ s and aggregates them
    into the ``CostModel.calibration()`` report: per kind, the implied
    cost-unit-per-second scale and mean absolute relative errors of the
    byte/row predictions.  Bounded: keeps the most recent
    ``max_samples`` raw samples (aggregates cover everything seen)."""

    max_samples: int = 1024
    samples: List[CalibrationSample] = field(default_factory=list)
    _agg: Dict[str, Dict[str, float]] = field(default_factory=dict)

    def record(self, sample: CalibrationSample) -> None:
        self.samples.append(sample)
        if len(self.samples) > self.max_samples:
            del self.samples[: len(self.samples) - self.max_samples]
        a = self._agg.setdefault(sample.kind, {
            "n": 0, "predicted_cost": 0.0, "measured_seconds": 0.0,
            "predicted_bytes": 0, "measured_bytes": 0,
            "abs_rel_err_bytes": 0.0, "abs_rel_err_rows": 0.0,
        })
        a["n"] += 1
        a["predicted_cost"] += sample.predicted_cost
        a["measured_seconds"] += sample.measured_seconds
        a["predicted_bytes"] += sample.predicted_bytes
        a["measured_bytes"] += sample.measured_bytes
        if sample.measured_bytes > 0:
            a["abs_rel_err_bytes"] += abs(
                sample.predicted_bytes - sample.measured_bytes
            ) / sample.measured_bytes
        if sample.measured_rows > 0:
            a["abs_rel_err_rows"] += abs(
                sample.predicted_rows - sample.measured_rows
            ) / sample.measured_rows

    def report(self) -> dict:
        kinds = {}
        for kind, a in sorted(self._agg.items()):
            n = max(int(a["n"]), 1)
            kinds[kind] = {
                "n": int(a["n"]),
                "predicted_cost": a["predicted_cost"],
                "measured_seconds": a["measured_seconds"],
                "cost_units_per_second": (
                    a["predicted_cost"] / a["measured_seconds"]
                    if a["measured_seconds"] > 0 else None),
                "predicted_bytes": int(a["predicted_bytes"]),
                "measured_bytes": int(a["measured_bytes"]),
                "bytes_mean_abs_rel_err": a["abs_rel_err_bytes"] / n,
                "rows_mean_abs_rel_err": a["abs_rel_err_rows"] / n,
            }
        return {
            "n_samples": sum(k["n"] for k in kinds.values()),
            "kinds": kinds,
            "samples": [s.as_dict() for s in self.samples],
        }


def model_calibration(model) -> dict:
    """``calibration()`` for any model: the attached log's report, or
    an empty report when no log was ever attached."""
    log: Optional[CalibrationLog] = getattr(model, "calibration_log",
                                            None)
    return (log or CalibrationLog()).report()


def price_ce(ce: CoveringExpression, model: CostModel) -> CoveringExpression:
    """Fill ``value`` / ``weight`` of a CE in-place (returns it too)."""
    unshared = sum(model.execution_cost(o.node) for o in ce.se.occurrences)
    exec_ce = model.execution_cost(ce.tree)
    write_c = model.write_cost(ce.tree)
    read_c = model.read_cost(ce.tree)
    extraction = getattr(model, "extraction_cost", None)
    if extraction is not None:
        extract_c = sum(extraction(ce.tree, o.node)
                        for o in ce.se.occurrences)
    else:
        extract_c = 0.0
    total_ce = exec_ce + write_c + ce.m * read_c + extract_c
    ce.value = unshared - total_ce
    ce.weight = int(model.output_bytes(ce.tree))
    ce.est_rows = int(model.output_rows(ce.tree))
    ce.cost_detail = {
        "C_omega": unshared,
        "C_E_star": exec_ce,
        "C_W": write_c,
        "C_R": read_c,
        "C_X": extract_c,
        "m": ce.m,
        "C_Omega": total_ce,
    }
    return ce


def price_ces(ces: Sequence[CoveringExpression], model: CostModel):
    for ce in ces:
        price_ce(ce, model)
    return list(ces)


def price_resident_ce(ce: CoveringExpression) -> CoveringExpression:
    """Eq. 2 for an already-materialized CE (cross-batch retention):
    C_E(τ*) and C_W are sunk costs paid by the batch that admitted it,
    so the remaining price is m reads plus per-consumer extraction, and
    the knapsack weight is zero — the bytes already sit inside the
    memory manager's accounting.  Must run after :func:`price_ce` (it
    consumes the cost_detail breakdown)."""
    d = ce.cost_detail
    remaining = ce.m * d.get("C_R", 0.0) + d.get("C_X", 0.0)
    ce.value = d.get("C_omega", ce.value) - remaining
    ce.weight = 0
    ce.cost_detail = {**d, "resident": True, "C_Omega": remaining}
    return ce
