"""Knapsack-candidate generation (paper §4.2, Algorithm 2).

Nested CEs cannot be priced independently (value/weight are only
additive for *disjoint* CEs), so the optimizer is fed **groups of
mutually-exclusive options**: for each maximal CE, the group holds the
CE itself, each of its descendant CEs, and every compound of pairwise
disjoint descendants.  The MCKP then picks at most one option per
group.
"""
from __future__ import annotations

from dataclasses import dataclass
from itertools import combinations
from typing import List, Sequence, Tuple

from .covering import CoveringExpression
from .plan import tree_size


@dataclass(frozen=True)
class KnapsackItem:
    """One selectable option: a single CE or a compound of disjoint CEs."""

    ces: Tuple[CoveringExpression, ...]
    group: int

    @property
    def value(self) -> float:
        return sum(ce.value for ce in self.ces)

    @property
    def weight(self) -> int:
        return sum(ce.weight for ce in self.ces)

    def __repr__(self) -> str:  # pragma: no cover
        labels = ",".join(ce.tree.label for ce in self.ces)
        return f"Item(g={self.group}, [{labels}], v={self.value:.3g}, w={self.weight})"


@dataclass(frozen=True)
class PartitionKnapsackItem:
    """One PARTITION of a partition-grained CE as its own knapsack
    option (its own group — partitions of a CE are independently
    admissible, which is what lets the solver keep the hot fraction of
    a CE when the whole CE does not fit).  Duck-types KnapsackItem for
    the solver: value/weight are the partition's slice prices, ``ces``
    exposes the parent CE for MCKPSolution bookkeeping."""

    ce: CoveringExpression
    pid: int
    value: float
    weight: int
    group: int

    @property
    def ces(self) -> Tuple[CoveringExpression, ...]:
        return (self.ce,)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"PartItem(g={self.group}, {self.ce.tree.label}#p{self.pid}, "
                f"v={self.value:.3g}, w={self.weight})")


def _is_descendant(child: CoveringExpression, parent: CoveringExpression) -> bool:
    """child ⊂ parent: child's fingerprint appears as a proper sub-tree
    fingerprint of the parent's covering tree."""
    if child is parent:
        return False
    sub_fps = parent.fp_set
    return child.psi in sub_fps and child.psi != parent.psi


def _disjoint(a: CoveringExpression, b: CoveringExpression) -> bool:
    """No common sub-trees (paper: compounds must be of disjoint CEs so
    that value and weight stay additive)."""
    return not (a.fp_set & b.fp_set)


def generate_knapsack_items(
    ces: Sequence[CoveringExpression],
    *,
    max_compound_size: int = 4,
    max_options_per_group: int = 64,
) -> List[KnapsackItem]:
    """Algorithm 2: GenerateKPItems.

    ``max_compound_size`` / ``max_options_per_group`` bound the
    combinatorial expansion of compounds (the paper's DescSets are small;
    these caps only matter for adversarial inputs).
    """
    remaining: List[CoveringExpression] = sorted(
        ces, key=lambda ce: (tree_size(ce.tree), ce.weight, ce.psi))
    items: List[KnapsackItem] = []
    group = 0

    while remaining:
        top = remaining.pop()  # PopLargest
        desc = [ce for ce in remaining if _is_descendant(ce, top)]
        options: List[Tuple[CoveringExpression, ...]] = [(top,)]
        options.extend((d,) for d in desc)
        # Compounds of pairwise disjoint descendants.
        for size in range(2, min(max_compound_size, len(desc)) + 1):
            for combo in combinations(desc, size):
                if all(_disjoint(a, b) for a, b in combinations(combo, 2)):
                    options.append(tuple(combo))
                if len(options) >= max_options_per_group:
                    break
            if len(options) >= max_options_per_group:
                break
        for opt in options:
            item = KnapsackItem(ces=opt, group=group)
            # Options that can never help the objective are dropped here
            # (selecting nothing from a group is always allowed).
            if item.value > 0:
                items.append(item)
        for d in desc:
            remaining.remove(d)
        group += 1

    return items
