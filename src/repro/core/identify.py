"""Similar-subexpression identification (paper §4.1, Algorithm 1).

Top-down exploration of each input plan.  A sub-tree is recorded in the
fingerprint table only when its root is cache-friendly; exploration
descends into children only when the root is cache-unfriendly OR the
sub-tree still contains a cache-unfriendly operator somewhere below —
i.e. the lookup stops "as early and as high as possible", preferring a
small number of large (high-in-the-plan) SE candidates with small
expected memory footprints.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence

from .fingerprint import Fingerprint, fingerprint
from .plan import PlanNode, contains_unfriendly


@dataclass(frozen=True)
class Occurrence:
    """One sub-tree occurrence of an SE inside an input plan."""

    query_index: int      # which plan of the input set
    node: PlanNode        # the sub-tree root (identity matters for rewriting)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Occurrence(q={self.query_index}, {self.node.label})"


@dataclass
class SimilarSubexpression:
    """An SE ω = set of sub-trees sharing fingerprint ψ (Definition 3)."""

    psi: Fingerprint
    occurrences: List[Occurrence] = field(default_factory=list)

    @property
    def m(self) -> int:
        """Number of consumer sub-trees (paper's m in Eq. 2)."""
        return len(self.occurrences)

    @property
    def query_indices(self) -> frozenset:
        return frozenset(o.query_index for o in self.occurrences)


def identify_similar_subexpressions(
    plans: Sequence[PlanNode],
    k: int = 2,
    *,
    require_distinct_queries: bool = False,
) -> List[SimilarSubexpression]:
    """Algorithm 1: IdentifySEs.

    Args:
      plans: the input set of (locally optimized) logical plans.
      k: keep only SEs with at least ``k`` member sub-trees.
      require_distinct_queries: additionally require members from >=2
        distinct queries (an SE repeated inside a single query still
        offers sharing, so this defaults to False, matching the paper's
        ``|FT.GetValue(ψ)| ≥ k`` test).

    Returns:
      The list of SEs, ordered by (tree height of first member desc,
      member count desc) for deterministic downstream processing.
    """
    table: Dict[Fingerprint, SimilarSubexpression] = {}
    memo: Dict[int, Fingerprint] = {}

    for qi, root in enumerate(plans):
        to_visit: List[PlanNode] = [root]
        while to_visit:
            cur = to_visit.pop()
            psi = fingerprint(cur, memo)
            friendly = cur.cache_friendly
            if friendly:
                se = table.get(psi)
                if se is None:
                    se = table[psi] = SimilarSubexpression(psi=psi)
                se.occurrences.append(Occurrence(qi, cur))
            if (not friendly) or contains_unfriendly(cur):
                to_visit.extend(cur.children)

    out: List[SimilarSubexpression] = []
    for se in table.values():
        if se.m < k:
            continue
        if require_distinct_queries and len(se.query_indices) < 2:
            continue
        # Leaf-only SEs (bare scans) are kept: sharing a scan is the
        # paper's "simple approach" baseline and is still a valid CE.
        out.append(se)

    from .plan import tree_size

    out.sort(key=lambda s: (-tree_size(s.occurrences[0].node), -s.m,
                            s.psi))
    return out
