"""Covering-expression construction (paper §4.2, Definition 4).

Given an SE ω = {τ_1 … τ_m} (sub-trees with identical fingerprints,
hence identical operator structure), build the covering sub-tree
τ* = f(ω): walk the members in lock-step and merge node-by-node.
Loose operators merge their attributes (OR of filter predicates, union
of projection columns — delegated to ``node.merge``); strict operators
are syntactically equal by construction and are copied.

The resulting τ* has the same fingerprint as every member (checked),
and every member's output can be derived from τ*'s output by a cheap
extraction plan (per-member filter/project re-applied).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from .fingerprint import Fingerprint, fingerprint, fingerprint_set
from .identify import SimilarSubexpression
from .plan import PlanNode


@dataclass
class CoveringExpression:
    """A CE Ω = f(ω): the sharing plan whose output gets cached."""

    se: SimilarSubexpression
    tree: PlanNode                      # covering sub-tree τ*
    psi: Fingerprint                    # == se.psi
    # Filled in by the cost model (repro.core.costmodel.price_ce):
    value: float = 0.0                  # v(Ω) = C(ω) − C(Ω), Eq. 3
    weight: int = 0                     # w(Ω) = |Ω| in bytes
    est_rows: int = 0                   # estimated output cardinality
    cost_detail: dict = field(default_factory=dict)
    # memoized strict content fingerprint of the covering tree (filled
    # lazily by strict_psi(); cross-batch retention identity)
    _strict_psi: Optional[Fingerprint] = None
    # Partition-grained admission (see repro.relational.partition): a
    # plan-type-specific partitioner may split this CE into independent
    # per-partition MCKP items; the solver then fills the subset it
    # admitted.  None for unpartitioned CEs.
    partition_detail: Optional[object] = None    # (plan record, slices)
    admitted_partitions: Optional[frozenset] = None

    def strict_psi(self) -> Fingerprint:
        if self._strict_psi is None:
            from .fingerprint import strict_fingerprint

            self._strict_psi = strict_fingerprint(self.tree)
        return self._strict_psi

    @property
    def m(self) -> int:
        return self.se.m

    @property
    def fp_set(self) -> frozenset:
        return fingerprint_set(self.tree)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"CE({self.tree.label}, m={self.m}, v={self.value:.3g}, "
                f"w={self.weight})")


def _merge_trees(members: Sequence[PlanNode]) -> PlanNode:
    """Lock-step structural merge of fingerprint-identical sub-trees."""
    first = members[0]
    n_children = len(first.children)
    if any(len(m.children) != n_children for m in members[1:]):
        raise ValueError("SE members disagree on arity — fingerprint bug")
    if n_children == 0:
        return first.merge(members[1:])
    # NOTE on commutative binaries: members share a fingerprint computed
    # with sorted child fingerprints, so lock-step children may be
    # swapped between members.  Align children by fingerprint first.
    if n_children == 2 and first.commutative:
        ref = [fingerprint(c) for c in first.children]
        aligned: List[List[PlanNode]] = [list(first.children)]
        for m in members[1:]:
            fps = [fingerprint(c) for c in m.children]
            if fps == ref:
                aligned.append(list(m.children))
            elif fps == ref[::-1]:
                aligned.append(list(m.children[::-1]))
            else:
                # identical sorted multiset but ambiguous (fp0 == fp1)
                aligned.append(list(m.children))
        merged_children = tuple(
            _merge_trees([a[i] for a in aligned]) for i in range(2)
        )
    else:
        merged_children = tuple(
            _merge_trees([m.children[i] for m in members])
            for i in range(n_children)
        )
    return first.merge(members[1:]).with_children(merged_children)


def build_covering_expression(se: SimilarSubexpression) -> CoveringExpression:
    members = [o.node for o in se.occurrences]
    tree = _merge_trees(members)
    psi = fingerprint(tree)
    if psi != se.psi:
        raise AssertionError(
            "covering tree fingerprint differs from SE fingerprint — "
            "merge must preserve loose/strict identity")
    return CoveringExpression(se=se, tree=tree, psi=psi)


def build_covering_expressions(
    ses: Sequence[SimilarSubexpression],
) -> List[CoveringExpression]:
    return [build_covering_expression(se) for se in ses]
