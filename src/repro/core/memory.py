"""Unified memory hierarchy: one budget-aware :class:`MemoryManager`.

The paper's MCKP formulation assumes a SINGLE memory budget governs
what gets materialized.  This module is that budget's runtime owner:
every byte of device-resident cached state — CE materializations
(``core.cache``), device scan columns (``relational.physical``),
serving prefix states (``serving.engine``) — is admitted through one
manager, partitioned into named *pools*.

Hierarchy (two spill tiers instead of the old binary spill):

    device (budgeted)  ──evict──▶  host (optionally budgeted)  ──▶  drop

* A put that does not fit evicts victims chosen by the pool's
  **eviction policy**:

    - ``"lru"``      least-recently-used first (logical clock);
    - ``"benefit"``  lowest benefit-per-byte first, where *benefit* is
      the caller-supplied savings estimate (the CostModel's Eq. 3 value
      for CEs, the transfer cost for scan columns) — the
      benefit-aware eviction of Yang et al. 2018;
    - ``"admission"`` no eviction of residents: the INCOMING entry
      spills (the paper's semantics — the MCKP already decided
      admission offline, residents are load-bearing).

* An evicted entry spills to the host tier when its pool has a
  ``spill_fn`` (HBM → host DRAM offload); pools without one (e.g. the
  scan cache, whose source host arrays still live in the catalog) drop
  the payload instead — a later get is a miss and the caller
  recomputes.

* A host-tier hit is unspilled and **promoted back to device when
  there is headroom** (fixing the old CacheManager's re-unspill-per-hit
  churn); without headroom the unspilled payload is returned but the
  entry stays on the host tier.

Invariants (property-tested in ``tests/test_memory.py``):

    device_used ≤ device_budget        after ANY op sequence
    host_used   ≤ host_budget          (when a host budget is set)
    *_used      == Σ nbytes of entries actually resident on that tier

Dropping or spilling never changes results — every consumer treats a
miss as "recompute from the retained plan" — so batches are
bit-identical under a pathologically tiny budget and an unlimited one.

**Failure model (PR 6).**  Admissions, spills and evictions are
journaled two-phase operations: a :class:`Journal` record opens before
the books are touched and commits after — an exception escaping
mid-operation leaves an open record that :meth:`MemoryManager.audit`
flags instead of silently corrupting ``used`` counters.  ``audit()``
re-derives every invariant from the entries actually present
(``used ≤ budget``, tier bookkeeping matches residency, no orphaned or
transient-tier entries) and returns the violations;
:meth:`MemoryManager.reconcile` *quarantines-then-drops* inconsistent
entries and recomputes the books from the survivors, so a corrupt
entry is never served.  ``get`` applies the same guard inline: an
entry in an impossible state is quarantined and reported as a miss.
A spill that fails (the ``spill_to_host`` fault point, or a raising
``spill_fn``) degrades to a drop — the victim's consumers recompute,
results are unchanged, and the books stay exact.
"""
from __future__ import annotations

import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional

POLICIES = ("lru", "benefit", "admission")

DEVICE, HOST, DROPPED = "device", "host", "dropped"
# transient tier label used inside _make_room while a victim is between
# tiers; must never be observable between operations (audit flags it)
EVICTING = "evicting"


@dataclass
class MemoryEntry:
    key: Any
    pool: str
    payload: Any
    nbytes: int
    est_bytes: int = 0
    benefit: float = 0.0          # savings estimate (policy="benefit")
    tier: str = DEVICE            # "device" | "host" | "dropped"
    hits: int = 0
    seq: int = 0                  # logical clock (policy="lru")
    created_at: float = field(default_factory=time.monotonic)
    # per-tenant attribution (PR 10): the tenant whose query first
    # materialized this entry ("first-toucher pays"); None == shared /
    # untenanted.  Attribution only — eviction stays tenant-blind.
    owner: Optional[str] = None

    @property
    def spilled(self) -> bool:    # CacheEntry-compat view
        return self.tier == HOST

    @property
    def psi(self):                # CacheEntry-compat view
        return self.key


@dataclass
class JournalRecord:
    """One two-phase pool operation: opened before the books move,
    committed after.  An open record surviving past its operation means
    the op died mid-flight — ``audit()`` reports it, ``reconcile()``
    closes it after repairing the books."""

    seq: int
    op: str                       # "put" | "evict" | "promote" | ...
    pool: str
    key: Any
    committed: bool = False
    note: str = ""


class Journal:
    """Bounded journal of pool operations (ring buffer — telemetry and
    crash detection, not a redo log; the books themselves are repaired
    by recomputation from entries in ``reconcile``)."""

    def __init__(self, maxlen: int = 512):
        self.records: deque = deque(maxlen=maxlen)
        self._open: Dict[int, JournalRecord] = {}
        self._seq = 0

    def begin(self, op: str, pool: str, key: Any) -> JournalRecord:
        self._seq += 1
        rec = JournalRecord(seq=self._seq, op=op, pool=pool, key=key)
        self.records.append(rec)
        self._open[rec.seq] = rec
        return rec

    def commit(self, rec: JournalRecord, note: str = "") -> None:
        rec.committed = True
        if note:
            rec.note = note
        self._open.pop(rec.seq, None)

    def open_records(self) -> List[JournalRecord]:
        return list(self._open.values())


@dataclass
class PoolStats:
    """Per-pool accounting (field names match the old CacheStats)."""

    budget: int = 0               # the manager's device budget
    used: int = 0                 # this pool's device-tier bytes
    spilled_bytes: int = 0        # this pool's host-tier bytes
    admissions: int = 0
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    drops: int = 0
    promotions: int = 0
    spill_failures: int = 0       # spills downgraded to drops

    def as_dict(self) -> dict:
        return dict(budget=self.budget, used=self.used,
                    spilled_bytes=self.spilled_bytes,
                    admissions=self.admissions, hits=self.hits,
                    misses=self.misses, evictions=self.evictions,
                    drops=self.drops, promotions=self.promotions,
                    spill_failures=self.spill_failures)


class MemoryPool:
    """A named view over the manager: one keyspace, one spill path."""

    def __init__(self, manager: "MemoryManager", name: str,
                 spill_fn: Optional[Callable[[Any], Any]] = None,
                 unspill_fn: Optional[Callable[[Any], Any]] = None,
                 policy: Optional[str] = None):
        self.manager = manager
        self.name = name
        self.spill_fn = spill_fn
        self.unspill_fn = unspill_fn
        self.policy = policy or manager.policy
        assert self.policy in POLICIES, self.policy
        self.entries: Dict[Any, MemoryEntry] = {}
        self.stats = PoolStats(budget=manager.device_budget)

    # -- delegated operations ------------------------------------------------
    def put(self, key, payload, nbytes: int, est_bytes: int = 0,
            benefit: float = 0.0) -> MemoryEntry:
        return self.manager.put(self, key, payload, nbytes,
                                est_bytes=est_bytes, benefit=benefit)

    def get(self, key, default=None):
        return self.manager.get(self, key, default)

    def touch(self, key) -> bool:
        """Presence check that refreshes LRU recency (counted as a hit)
        without unspilling or promoting — for callers that only need to
        know the entry exists and will read the payload later."""
        entry = self.entries.get(key)
        if entry is None:
            return False
        self.manager._seq += 1
        entry.seq = self.manager._seq
        entry.hits += 1
        self.stats.hits += 1
        return True

    def contains(self, key) -> bool:
        return key in self.entries

    def __contains__(self, key) -> bool:
        return key in self.entries

    def entry(self, key) -> Optional[MemoryEntry]:
        return self.entries.get(key)

    def evict(self, key) -> None:
        self.manager.evict(self, key)

    def invalidate(self, pred: Callable[[Any], bool]) -> int:
        """Drop every entry whose key matches ``pred``; returns count."""
        victims = [k for k in self.entries if pred(k)]
        for k in victims:
            self.manager.evict(self, k)
        return len(victims)

    def clear(self) -> None:
        for k in list(self.entries):
            self.manager.evict(self, k)
        # counters other than occupancy survive a clear (they are
        # lifetime telemetry); occupancy is zeroed by the evictions

    def keys(self) -> Iterable:
        return self.entries.keys()

    @property
    def used_bytes(self) -> int:
        return self.stats.used

    def report(self) -> dict:
        return {
            **self.stats.as_dict(),
            "entries": [
                dict(psi=_short_key(e.key), nbytes=e.nbytes,
                     est_bytes=e.est_bytes, spilled=e.spilled,
                     hits=e.hits)
                for e in self.entries.values()
            ],
        }


class MemoryManager:
    """Owns the device-byte budget shared by every registered pool."""

    def __init__(self, device_budget: int,
                 host_budget: Optional[int] = None,
                 policy: str = "lru"):
        assert policy in POLICIES, policy
        self.device_budget = int(device_budget)
        self.host_budget = None if host_budget is None else int(host_budget)
        self.policy = policy
        self.pools: Dict[str, MemoryPool] = {}
        self.device_used = 0
        self.host_used = 0
        self._seq = 0
        self.journal = Journal()
        # optional core.faults.FaultInjector (the "spill_to_host" point);
        # installed by the owning Session when fault injection is on
        self.faults = None
        self.quarantined = 0      # entries dropped by the serving guard
        # optional relational.observe.Telemetry; when set, the session's
        # metrics registry mirrors eviction / spill / drop events live
        # (per-pool lifetime books stay in PoolStats regardless)
        self.telemetry = None
        # per-tenant attribution (PR 10): admissions while an owner is
        # set (see ``owning``) stamp the entry with it
        self.current_owner: Optional[str] = None

    @contextmanager
    def owning(self, owner: Optional[str]):
        """Scope during which admissions are attributed to ``owner``
        (the async front wraps each query's execution in the tenant
        that submitted it).  ``None`` attributes to the shared pool."""
        prev = self.current_owner
        self.current_owner = owner
        try:
            yield
        finally:
            self.current_owner = prev

    def owner_usage(self) -> Dict[str, Dict[str, int]]:
        """``{owner: {pool: resident bytes}}`` over live (device + host)
        entries — recomputed from the entries themselves on every call,
        so attribution can never drift from the books the audit checks.
        Entries with no owner (untenanted work) are omitted."""
        out: Dict[str, Dict[str, int]] = {}
        for name, pool in self.pools.items():
            # list(): admissions may race this read from another thread
            # (the async front's executor); a point-in-time copy is all
            # attribution needs
            for e in list(pool.entries.values()):
                if e.owner is None or e.tier not in (DEVICE, HOST):
                    continue
                by_pool = out.setdefault(e.owner, {})
                by_pool[name] = by_pool.get(name, 0) + e.nbytes
        return out

    def owner_bytes(self, owner: str) -> int:
        """Total live bytes attributed to ``owner`` across all pools
        (the quantity a TenantQuota's ``max_bytes`` is charged against)."""
        return sum(self.owner_usage().get(owner, {}).values())

    def _tinc(self, name: str, n: float = 1) -> None:
        tel = self.telemetry
        if tel is not None:
            tel.registry.inc(name, n)

    # -- pool registry -------------------------------------------------------
    def pool(self, name: str, *,
             spill_fn: Optional[Callable[[Any], Any]] = None,
             unspill_fn: Optional[Callable[[Any], Any]] = None,
             policy: Optional[str] = None) -> MemoryPool:
        """Get-or-create the named pool (idempotent; first caller wins
        the configuration)."""
        p = self.pools.get(name)
        if p is None:
            p = self.pools[name] = MemoryPool(
                self, name, spill_fn=spill_fn, unspill_fn=unspill_fn,
                policy=policy)
        return p

    # -- admission -----------------------------------------------------------
    def put(self, pool: MemoryPool, key, payload, nbytes: int,
            est_bytes: int = 0, benefit: float = 0.0) -> MemoryEntry:
        nbytes = int(nbytes)
        rec = self.journal.begin("put", pool.name, key)
        if key in pool.entries:          # re-put invalidates the old entry
            self.evict(pool, key)
        self._seq += 1
        entry = MemoryEntry(key=key, pool=pool.name, payload=payload,
                            nbytes=nbytes, est_bytes=int(est_bytes),
                            benefit=float(benefit), seq=self._seq,
                            owner=self.current_owner)
        pool.stats.admissions += 1

        if self.device_used + nbytes > self.device_budget:
            # admission pools protect their own residents (victim
            # selection skips them) but may still displace entries of
            # evictable pools; when nothing can be freed the INCOMING
            # entry takes the spill path
            self._make_room(nbytes)

        if self.device_used + nbytes <= self.device_budget:
            self.device_used += nbytes
            pool.stats.used += nbytes
            pool.entries[key] = entry
        else:
            # could not free enough (entry bigger than the whole budget,
            # or every resident is admission-pinned)
            self._demote(pool, entry)
            if entry.tier != DROPPED:
                pool.entries[key] = entry
        self.journal.commit(rec, note=entry.tier)
        return entry

    # -- lookup --------------------------------------------------------------
    def get(self, pool: MemoryPool, key, default=None):
        entry = pool.entries.get(key)
        if entry is None:
            pool.stats.misses += 1
            return default
        if entry.tier not in (DEVICE, HOST) or entry.payload is None:
            # serving guard: an entry stranded in an impossible state
            # (a crashed mid-operation) must not be served — quarantine
            # it (drop + repair the books) and report a miss so the
            # caller recomputes from the retained plan
            self._quarantine(pool, entry)
            pool.stats.misses += 1
            return default
        self._seq += 1
        entry.seq = self._seq
        entry.hits += 1
        pool.stats.hits += 1
        if entry.tier == DEVICE:
            return entry.payload
        # host tier: unspill, promoting back to device when there is
        # headroom (the old manager re-unspilled on EVERY hit and never
        # promoted — the satellite-1 churn fix).  Without an unspill_fn
        # the payload stays in host form, so it must not be relabeled
        # (and re-accounted) as device-resident.
        if pool.unspill_fn is None:
            return entry.payload
        payload = pool.unspill_fn(entry.payload)
        if self.device_used + entry.nbytes <= self.device_budget:
            rec = self.journal.begin("promote", pool.name, key)
            entry.payload = payload
            entry.tier = DEVICE
            self.host_used -= entry.nbytes
            self.device_used += entry.nbytes
            pool.stats.spilled_bytes -= entry.nbytes
            pool.stats.used += entry.nbytes
            pool.stats.promotions += 1
            self.journal.commit(rec)
        return payload

    # -- maintenance ---------------------------------------------------------
    def evict(self, pool: MemoryPool, key) -> None:
        entry = pool.entries.pop(key, None)
        if entry is None:
            return
        rec = self.journal.begin("evict", pool.name, key)
        self._release(pool, entry)
        entry.tier = DROPPED
        entry.payload = None
        self.journal.commit(rec)

    def clear(self) -> None:
        for p in self.pools.values():
            p.clear()

    @property
    def device_headroom(self) -> int:
        return max(0, self.device_budget - self.device_used)

    # -- self-audit ----------------------------------------------------------
    def audit(self) -> List[str]:
        """Verify every pool invariant from first principles and return
        the violations (empty list == clean).  Checks, per pool and for
        the manager totals:

        * ``used ≤ budget`` on both tiers;
        * tier bookkeeping matches actual residency (``stats.used`` ==
          Σ nbytes of entries actually on the device tier, ditto host);
        * no orphaned buffers (an entry on a live tier with a ``None``
          payload) and no entries stranded on a transient tier
          (``evicting`` / ``dropped`` ghosts left in the key map);
        * no journal record still open (a crashed mid-operation).
        """
        v: List[str] = []
        dev_total = host_total = 0
        for name, pool in self.pools.items():
            dev = host = 0
            for e in pool.entries.values():
                if e.tier == DEVICE:
                    dev += e.nbytes
                elif e.tier == HOST:
                    host += e.nbytes
                else:
                    v.append(f"{name}: entry {_short_key(e.key)} stranded"
                             f" on transient tier {e.tier!r}")
                if e.tier in (DEVICE, HOST) and e.payload is None:
                    v.append(f"{name}: orphaned {e.tier} buffer for "
                             f"{_short_key(e.key)} (payload is None)")
            if dev != pool.stats.used:
                v.append(f"{name}: device books {pool.stats.used} != "
                         f"actual residency {dev}")
            if host != pool.stats.spilled_bytes:
                v.append(f"{name}: host books {pool.stats.spilled_bytes}"
                         f" != actual residency {host}")
            dev_total += dev
            host_total += host
        if dev_total != self.device_used:
            v.append(f"manager: device_used {self.device_used} != "
                     f"Σ pool residency {dev_total}")
        if host_total != self.host_used:
            v.append(f"manager: host_used {self.host_used} != "
                     f"Σ pool residency {host_total}")
        if self.device_used > self.device_budget:
            v.append(f"manager: device_used {self.device_used} > "
                     f"budget {self.device_budget}")
        if (self.host_budget is not None
                and self.host_used > self.host_budget):
            v.append(f"manager: host_used {self.host_used} > "
                     f"host budget {self.host_budget}")
        for rec in self.journal.open_records():
            v.append(f"journal: {rec.op} on {rec.pool}/"
                     f"{_short_key(rec.key)} (seq {rec.seq}) never "
                     f"committed — operation died mid-flight")
        return v

    def reconcile(self) -> dict:
        """Repair after a failed operation: quarantine-then-drop every
        entry in an inconsistent state (transient tier, orphaned
        payload), recompute the books from the surviving entries, and
        close crashed journal records.  Returns a report of what was
        repaired; ``audit()`` is clean afterwards by construction —
        quarantined content is recomputed by its consumers, never
        served."""
        quarantined: List[str] = []
        for name, pool in self.pools.items():
            bad = [e for e in pool.entries.values()
                   if e.tier not in (DEVICE, HOST) or e.payload is None]
            for e in bad:
                pool.entries.pop(e.key, None)
                e.tier = DROPPED
                e.payload = None
                quarantined.append(f"{name}/{_short_key(e.key)}")
            self.quarantined += len(bad)
        # recompute every book from actual residency
        corrections = 0
        dev_total = host_total = 0
        for pool in self.pools.values():
            dev = sum(e.nbytes for e in pool.entries.values()
                      if e.tier == DEVICE)
            host = sum(e.nbytes for e in pool.entries.values()
                       if e.tier == HOST)
            corrections += (dev != pool.stats.used)
            corrections += (host != pool.stats.spilled_bytes)
            pool.stats.used = dev
            pool.stats.spilled_bytes = host
            dev_total += dev
            host_total += host
        corrections += (dev_total != self.device_used)
        corrections += (host_total != self.host_used)
        self.device_used = dev_total
        self.host_used = host_total
        crashed = self.journal.open_records()
        for rec in crashed:
            self.journal.commit(rec, note="closed by reconcile")
        # a recomputation cannot shrink usage below the budget if the
        # surviving residency genuinely exceeds it — evict down to the
        # budget through the normal victim path in that case
        if self.device_used > self.device_budget:
            self._make_room(0)
        return {
            "quarantined": quarantined,
            "corrections": int(corrections),
            "crashed_ops": len(crashed),
        }

    def _quarantine(self, pool: MemoryPool, entry: MemoryEntry) -> None:
        """Serving-side guard: remove a corrupt entry and repair the
        books it may have skewed (used by ``get`` before it would have
        served the entry)."""
        pool.entries.pop(entry.key, None)
        if entry.tier == DEVICE:
            self.device_used -= entry.nbytes
            pool.stats.used -= entry.nbytes
        elif entry.tier == HOST:
            self.host_used -= entry.nbytes
            pool.stats.spilled_bytes -= entry.nbytes
        entry.tier = DROPPED
        entry.payload = None
        self.quarantined += 1

    def report(self) -> dict:
        return {
            "device_budget": self.device_budget,
            "device_used": self.device_used,
            "host_budget": self.host_budget,
            "host_used": self.host_used,
            "policy": self.policy,
            "pools": {n: p.report() for n, p in self.pools.items()},
        }

    # -- internals -----------------------------------------------------------
    def _release(self, pool: MemoryPool, entry: MemoryEntry) -> None:
        if entry.tier == DEVICE:
            self.device_used -= entry.nbytes
            pool.stats.used -= entry.nbytes
        elif entry.tier == HOST:
            self.host_used -= entry.nbytes
            pool.stats.spilled_bytes -= entry.nbytes

    def _victim_score(self, e: MemoryEntry):
        """Ascending victim order: (policy primary, recency).  Benefit
        pools rank by benefit-per-byte; lru pools rank purely by
        recency (primary 0.0 — recomputable state goes first when mixed
        with benefit-ranked pools)."""
        if self.pools[e.pool].policy == "benefit":
            return (e.benefit / max(e.nbytes, 1), e.seq)
        return (0.0, e.seq)

    def _make_room(self, nbytes: int) -> None:
        """Evict device victims (policy order, across evictable pools)
        until ``nbytes`` fits or nothing evictable remains.  The
        incoming entry is not yet in any pool, so it can never be its
        own victim."""
        if nbytes > self.device_budget:
            # can never fit: don't flush residents for nothing — the
            # caller sends the oversized entry down the spill path
            return
        candidates = [
            e for p in self.pools.values() if p.policy != "admission"
            for e in p.entries.values()
            if e.tier == DEVICE
        ]
        candidates.sort(key=self._victim_score)
        for victim in candidates:
            if self.device_used + nbytes <= self.device_budget:
                break
            vpool = self.pools[victim.pool]
            self.device_used -= victim.nbytes
            vpool.stats.used -= victim.nbytes
            vpool.stats.evictions += 1
            self._tinc(f"mem.evictions.{vpool.name}")
            victim.tier = "evicting"   # transient: not on any tier
            self._demote(vpool, victim)
            if victim.tier == DROPPED:
                del vpool.entries[victim.key]

    def _make_host_room(self, nbytes: int) -> None:
        if self.host_budget is None or nbytes > self.host_budget:
            # unbounded tier, or an entry that can never fit (the
            # caller drops it): never flush the host tier for nothing
            return
        candidates = [
            e for p in self.pools.values()
            for e in p.entries.values() if e.tier == HOST
        ]
        candidates.sort(key=self._victim_score)
        for victim in candidates:
            if self.host_used + nbytes <= self.host_budget:
                break
            vpool = self.pools[victim.pool]
            self.host_used -= victim.nbytes
            vpool.stats.spilled_bytes -= victim.nbytes
            vpool.stats.drops += 1
            self._tinc(f"mem.drops.{vpool.name}")
            victim.tier = DROPPED
            del vpool.entries[victim.key]

    def _demote(self, pool: MemoryPool, entry: MemoryEntry) -> None:
        """Tier 2/3 of the spill path: host when the pool can spill and
        the host budget allows, else drop.  A spill that fails — the
        ``spill_to_host`` fault point or a raising ``spill_fn`` — is
        DOWNGRADED to a drop instead of escaping: the victim's consumers
        recompute from the retained plan (results unchanged) and the
        books never see a half-spilled entry."""
        if pool.spill_fn is not None:
            self._make_host_room(entry.nbytes)
            if (self.host_budget is None
                    or self.host_used + entry.nbytes <= self.host_budget):
                rec = self.journal.begin("spill", pool.name, entry.key)
                try:
                    if self.faults is not None:
                        self.faults.check("spill_to_host", key=entry.key)
                    payload = pool.spill_fn(entry.payload)
                except Exception as exc:   # incl. InjectedFault
                    pool.stats.spill_failures += 1
                    self._tinc(f"mem.spill_failures.{pool.name}")
                    self.journal.commit(rec, note=f"failed: {exc!r}")
                else:
                    entry.payload = payload
                    entry.tier = HOST
                    self.host_used += entry.nbytes
                    pool.stats.spilled_bytes += entry.nbytes
                    self._tinc(f"mem.spills.{pool.name}")
                    self._tinc(f"mem.spilled_bytes.{pool.name}",
                               entry.nbytes)
                    self.journal.commit(rec)
                    return
        entry.payload = None
        entry.tier = DROPPED
        pool.stats.drops += 1
        self._tinc(f"mem.drops.{pool.name}")


class PidPool:
    """Partition-identifier bitset pool — the hierarchy's fourth pool
    (``"pid"``, after ``ce`` / ``scan`` / ``prefix``; PR 8).

    One entry per ``(table, canonical-conjunct)``: a bitset over the
    table's partitions recording which of them produced ANY row when a
    scan actually evaluated that predicate (populated as a side effect
    of fused/batched execution).  A bitset is ``(n_partitions + 7) // 8``
    bytes — orders of magnitude cheaper than the materialized rows it
    summarizes (PartitionCache's observation) — so entries practically
    never face eviction, yet later conjunctive queries can intersect
    them to prune partitions by observed *history* on top of what
    min/max statistics can refute.

    Soundness contract (enforced by the recording side): a recorded
    bitset's ABSENT partitions held zero qualifying rows for the stored
    predicate over the whole table — partitions the recording scan
    itself pruned count as absent only because pruning is conservative
    (a pruned partition is exactly empty for the predicate).  Hence for
    any query predicate *q* with rows(q) ⊆ rows(p), absent partitions
    are empty for *q* too, and intersecting is exact, never lossy.

    The core stays plan-agnostic: predicates are opaque payloads and
    the "does stored *p* subsume query *q*" decision is delegated to the
    ``implies`` callable the caller passes to :meth:`intersect` (the
    relational layer closes ``canonical.subsumes`` over the table
    schema).
    """

    POOL = "pid"

    def __init__(self, manager: "MemoryManager",
                 policy: Optional[str] = None):
        # no spill_fn: a bitset is cheaper to recompute (one scan) than
        # to stage through the host tier, and entries are tiny anyway
        self._pool = manager.pool(self.POOL, policy=policy)

    @staticmethod
    def _nbytes(n_partitions: int) -> int:
        return max(1, (int(n_partitions) + 7) // 8)

    # -- recording -----------------------------------------------------------
    def record(self, table: str, pred_key, pred, n_partitions: int,
               present: Iterable[int]) -> MemoryEntry:
        """Admit the observed presence set for ``(table, pred_key)``.
        ``pred`` rides along as payload so later lookups can test
        subsumption against the stored predicate object."""
        mask = 0
        for pid in present:
            mask |= 1 << int(pid)
        return self._pool.put(
            (table, pred_key), (mask, int(n_partitions), pred),
            nbytes=self._nbytes(n_partitions))

    def contains(self, table: str, pred_key) -> bool:
        return self._pool.contains((table, pred_key))

    # -- lookup --------------------------------------------------------------
    def intersect(self, table: str, pred_key, pred, n_partitions: int,
                  live: Iterable[int], implies=None):
        """Shrink ``live`` by every resident bitset whose stored
        predicate provably subsumes ``pred`` (exact-key entries match
        without the subsumption test).  Returns ``(pruned ascending
        pid tuple, n_bitsets_used)`` — with no usable bitset the input
        comes back unchanged (history composes with, never overrides,
        the stats pruner that produced ``live``)."""
        out = {int(p) for p in live}
        hits = 0
        for key, entry in list(self._pool.entries.items()):
            if not (isinstance(key, tuple) and len(key) == 2
                    and key[0] == table):
                continue
            payload = entry.payload
            if payload is None:
                continue
            mask, n_parts, stored = payload
            if int(n_parts) != int(n_partitions):
                continue     # stale layout (belt: invalidated on register)
            if key[1] == pred_key:
                usable = True
            elif implies is not None:
                usable = bool(implies(stored, pred))
            else:
                usable = False
            if not usable:
                continue
            out = {p for p in out if (mask >> p) & 1}
            self._pool.touch(key)
            hits += 1
        return tuple(sorted(out)), hits

    # -- maintenance ---------------------------------------------------------
    def invalidate_table(self, table: str) -> int:
        """Drop every bitset of ``table`` (re-register: old data's
        observed history must not prune the new data's partitions)."""
        return self._pool.invalidate(
            lambda k: isinstance(k, tuple) and len(k) == 2
            and k[0] == table)

    def clear(self) -> None:
        self._pool.clear()

    def keys(self) -> Iterable:
        return self._pool.keys()

    @property
    def used_bytes(self) -> int:
        return self._pool.used_bytes

    @property
    def stats(self) -> PoolStats:
        return self._pool.stats

    def report(self) -> dict:
        return self._pool.report()


def _short_key(key) -> str:
    if isinstance(key, bytes):
        return key.hex()[:12]
    return str(key)[:48]
