"""Query rewriting (paper §4.4).

Each input query that consumes a selected CE gets its shared sub-tree
replaced by an *extraction plan*: the CachedRelation leaf plus, when the
SE members were merely similar (not syntactically equal), the member's
own filter predicates / projection columns re-applied on the cached
covering relation.  Extraction-plan construction is plan-type specific
and is delegated to a :class:`Rewriter`.

Selected CE trees themselves become *cache plans* (the covering tree
with a terminal Cache operator).  Cache plans are optionally chained:
a larger selected CE whose tree contains a smaller selected CE will
itself read from the smaller one's cached output.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Protocol, Sequence

from .covering import CoveringExpression
from .plan import PlanNode, tree_size


class Rewriter(Protocol):
    def make_cache_plan(self, ce: CoveringExpression) -> PlanNode:
        """Wrap the covering tree so its output is materialized in RAM."""
        ...

    def make_extraction(self, ce: CoveringExpression, member: PlanNode) -> PlanNode:
        """Plan producing ``member``'s output from the cached CE output."""
        ...

    # Optional: concrete rewriters may also provide
    #   cache_key(ce) -> bytes
    # the runtime cache identity of a CE's materialized output.  The
    # default is the loose structural psi; the relational rewriter uses
    # the STRICT content fingerprint so same-structure CEs with
    # different merged predicates (recurring micro-batch windows over a
    # template family) can stay resident side by side.


@dataclass
class RewrittenBatch:
    plans: List[PlanNode]                   # rewritten input set
    cache_plans: Dict[bytes, PlanNode]      # psi -> cache plan
    ces: List[CoveringExpression]
    stats: dict = field(default_factory=dict)


def _replace_nodes(root: PlanNode, repl: Dict[int, PlanNode]) -> PlanNode:
    """Rebuild ``root`` with node-identity replacements applied."""
    if id(root) in repl:
        return repl[id(root)]
    if not root.children:
        return root
    new_children = tuple(_replace_nodes(c, repl) for c in root.children)
    if all(nc is c for nc, c in zip(new_children, root.children)):
        return root
    return root.with_children(new_children)


def rewrite_batch(
    plans: Sequence[PlanNode],
    selected: Sequence[CoveringExpression],
    rewriter: Rewriter,
    *,
    chain_cache_plans: bool = True,
) -> RewrittenBatch:
    # Build per-plan replacement maps: occurrence node -> extraction plan.
    repl: Dict[int, PlanNode] = {}
    for ce in selected:
        for occ in ce.se.occurrences:
            repl[id(occ.node)] = rewriter.make_extraction(ce, occ.node)

    new_plans = [_replace_nodes(p, repl) for p in plans]

    # Cache plans; larger CEs may consume smaller selected CEs' caches.
    # Keys come from the rewriter's cache identity (loose psi by
    # default; see Rewriter.cache_key) and must be computed on the
    # ORIGINAL covering tree, before any chaining substitution below.
    key_fn = getattr(rewriter, "cache_key", None) or (lambda ce: ce.psi)
    cache_plans: Dict[bytes, PlanNode] = {}
    ordered = sorted(selected, key=lambda ce: tree_size(ce.tree))
    built: List[CoveringExpression] = []
    for ce in ordered:
        cache_key = key_fn(ce)
        tree = ce.tree
        if chain_cache_plans and built:
            from .fingerprint import all_fingerprints

            fps = all_fingerprints(tree)
            inner_repl: Dict[int, PlanNode] = {}
            for node_id_, fp in fps.items():
                for small in built:
                    if fp == small.psi and node_id_ != id(tree):
                        # locate the node instance by id within the tree
                        node = _find_by_id(tree, node_id_)
                        if node is not None:
                            inner_repl[node_id_] = rewriter.make_extraction(
                                small, node)
            if inner_repl:
                tree = _replace_nodes(tree, inner_repl)
        cache_plans[cache_key] = rewriter.make_cache_plan(
            ce if tree is ce.tree else _with_tree(ce, tree))
        built.append(ce)

    return RewrittenBatch(
        plans=new_plans,
        cache_plans=cache_plans,
        ces=list(selected),
        stats={"n_rewritten_occurrences": len(repl)},
    )


def attach_recompute_plan(batch: RewrittenBatch, cache_key: bytes,
                          plan: PlanNode) -> None:
    """Register a cache plan built OUTSIDE this batch's CE selection —
    e.g. a subsumption-resumed query (PR 8) reading a CE retained by an
    *earlier* window: the entry lets the executor recompute that CE
    from its covering tree if the hierarchy evicts it mid-window,
    instead of failing the consumer.  Never overwrites a plan this
    batch selected itself (an intra-window plan is already
    chain-consistent)."""
    batch.cache_plans.setdefault(cache_key, plan)


def _find_by_id(root: PlanNode, node_id_: int) -> PlanNode | None:
    from .plan import walk

    for n in walk(root):
        if id(n) == node_id_:
            return n
    return None


def _with_tree(ce: CoveringExpression, tree: PlanNode) -> CoveringExpression:
    clone = CoveringExpression(se=ce.se, tree=tree, psi=ce.psi)
    clone.value, clone.weight, clone.est_rows = ce.value, ce.weight, ce.est_rows
    clone.cost_detail = ce.cost_detail
    # the chained tree computes the SAME relation (inner CachedScan
    # substitutions are output-preserving), so it keeps the original
    # tree's content identity — recomputing it on the substituted tree
    # would diverge from the consumers' cache keys
    clone._strict_psi = ce.strict_psi()
    return clone
