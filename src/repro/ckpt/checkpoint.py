"""Sharded, atomic, async checkpointing with elastic restore.

Layout per step::

    <dir>/step_00000042.tmp/      (written, fsynced)
        manifest.json             (tree structure, shapes, dtypes, step)
        shard_<host>.npz          (this host's leaf arrays)
    <dir>/step_00000042/          (atomic rename = commit)

Fault-tolerance properties (tested):
  * atomic commit — a crash mid-write leaves only a .tmp dir, which
    restore ignores and GC removes;
  * async — saving overlaps the next train steps; ``wait()`` joins;
  * keep-k GC;
  * **elastic restore** — arrays are re-sharded onto whatever mesh the
    restoring job runs (checkpoint stores full logical arrays per leaf;
    device placement is the restorer's choice), so a 512-chip run can
    resume on 256 chips and vice versa.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _paths_of(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 process_index: int = 0):
        self.dir = directory
        self.keep = keep
        self.process_index = process_index
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = False) -> None:
        # snapshot to host memory NOW (donation may reuse the buffers)
        leaves = [(k, np.asarray(v)) for k, v in _paths_of(tree)]
        structure = jax.tree_util.tree_structure(tree)
        self.wait()

        def work():
            try:
                self._write(step, leaves, structure)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, step: int, leaves, structure):
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        shard = os.path.join(tmp, f"shard_{self.process_index}.npz")
        np.savez(shard, **{k: v for k, v in leaves})
        manifest = {
            "step": step,
            "keys": [k for k, _ in leaves],
            "shapes": {k: list(v.shape) for k, v in leaves},
            "dtypes": {k: str(v.dtype) for k, v in leaves},
            "treedef": str(structure),
            "time": time.time(),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic commit

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------
    def _steps(self) -> List[int]:
        steps = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                steps.append(int(d.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self._steps()
        return steps[-1] if steps else None

    def _gc(self):
        steps = self._steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
        # drop stale tmp dirs from crashed writers
        for d in os.listdir(self.dir):
            if d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, d),
                              ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, like_tree, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``like_tree``.

        ``shardings``: optional matching pytree of jax.sharding.Sharding
        — arrays are placed (re-sharded) accordingly: the elastic-
        rescale path.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None
        d = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(d, f"shard_{self.process_index}.npz"))
        keys = [k for k, _ in _paths_of(like_tree)]
        leaves = []
        for k in keys:
            arr = data[k]
            leaves.append(arr)
        structure = jax.tree_util.tree_structure(like_tree)
        tree = jax.tree_util.tree_unflatten(structure, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s) if s is not None
                else jax.numpy.asarray(a), tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return step, tree
