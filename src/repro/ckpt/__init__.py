from .checkpoint import CheckpointManager
