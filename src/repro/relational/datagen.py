"""Synthetic dataset generation (paper §6.1 micro-benchmark data).

The paper's table: 30 columns — n1..n10 int uniform in [1, 10^{i+2}],
d1..d10 double in [0,1], s1..s10 strings of length 20.  Deviations for
the JAX engine (documented in DESIGN.md): ints are clipped to < 1e9 so
int32 + 10-digit fixed-width CSV fields hold them exactly.

Also provides the "people" aliasing used in the paper's figures
(n1 -> age, s1 -> name, ...), and CSV/columnar serialization.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from .physical import TableStorage
from .schema import F32, I32, STR, Schema


def synthetic_schema(n_int: int = 10, n_dbl: int = 10, n_str: int = 10,
                     str_width: int = 20,
                     names: Optional[Tuple[str, ...]] = None) -> Schema:
    fields = []
    for i in range(1, n_int + 1):
        fields.append((f"n{i}", I32))
    for i in range(1, n_dbl + 1):
        fields.append((f"d{i}", F32))
    for i in range(1, n_str + 1):
        fields.append((f"s{i}", STR(str_width)))
    if names:
        fields = [(names[i] if i < len(names) and names[i] else f[0], f[1])
                  for i, f in enumerate(fields)]
    return Schema.of(*fields)


def generate_columns(schema: Schema, nrows: int,
                     seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    cols: Dict[str, np.ndarray] = {}
    int_idx = dbl_idx = 0
    for name, t in schema.fields:
        if t.kind == "i32":
            int_idx += 1
            hi = min(10 ** (int_idx + 2), 999_999_999)
            cols[name] = rng.integers(1, hi + 1, nrows, dtype=np.int64
                                      ).astype(np.int32)
        elif t.kind == "i64":
            # wide ints: beyond both int32 and exact-f32 range, so only
            # a true 64-bit lane holds them
            cols[name] = rng.integers(1, 2 ** 40, nrows, dtype=np.int64)
        elif t.kind == "f32":
            dbl_idx += 1
            cols[name] = rng.random(nrows, dtype=np.float64
                                    ).astype(np.float32)
        else:
            letters = rng.integers(97, 123, (nrows, t.width),
                                   dtype=np.int64).astype(np.uint8)
            # limit NDV so string-equality predicates are selective:
            # draw from 1000 distinct prefixes
            prefix_pool = rng.integers(97, 123, (1000, 4),
                                       dtype=np.int64).astype(np.uint8)
            which = rng.integers(0, 1000, nrows)
            letters[:, :4] = prefix_pool[which]
            cols[name] = letters
    return cols


def to_csv_bytes(schema: Schema, cols: Dict[str, np.ndarray],
                 nrows: int) -> np.ndarray:
    """Fixed-width UTF-8 serialization (the CSV-analog 'disk' format)."""
    row_w = schema.row_csv_bytes
    out = np.zeros((nrows, row_w), np.uint8)
    off = 0
    for name, t in schema.fields:
        w = t.csv_width
        arr = cols[name]
        if t.kind == "i32":
            digits = np.zeros((nrows, 10), np.uint8)
            v = arr.astype(np.int64)
            for k in range(9, -1, -1):
                digits[:, k] = (v % 10) + 48
                v //= 10
            out[:, off:off + w] = digits
        elif t.kind == "f32":
            frac = np.clip((arr.astype(np.float64) * 1e8), 0,
                           99_999_999).astype(np.int64)
            digits = np.zeros((nrows, 8), np.uint8)
            for k in range(7, -1, -1):
                digits[:, k] = (frac % 10) + 48
                frac //= 10
            out[:, off:off + w] = digits
        else:
            out[:, off:off + w] = arr
        off += w
    return out


def make_storage(name: str, schema: Schema, nrows: int, fmt: str,
                 seed: int = 0,
                 cols: Optional[Dict[str, np.ndarray]] = None
                 ) -> Tuple[TableStorage, Dict[str, np.ndarray]]:
    """Build host-side storage in the requested format + typed columns
    (the latter are needed for the stats pre-processing phase)."""
    if cols is None:
        cols = generate_columns(schema, nrows, seed)
    if any(t.kind == "i64" for _, t in schema.fields):
        if fmt == "csv":
            raise ValueError("i64 columns are columnar-only (no fixed-"
                             "width CSV encoding)")
        import jax

        if not jax.config.jax_enable_x64:
            raise ValueError("i64 columns require JAX x64 mode (enable "
                             "jax_enable_x64 before building storage)")
    if fmt == "csv":
        st = TableStorage(name=name, schema=schema, nrows=nrows, fmt="csv",
                          csv_bytes=to_csv_bytes(schema, cols, nrows))
    else:
        st = TableStorage(name=name, schema=schema, nrows=nrows,
                          fmt="columnar", columnar=cols)
    return st, cols


# The paper's illustrative aliasing: a 'people' relation over the same
# synthetic data, with n1=age, n3=salary, s1=name, s2=dept.
PEOPLE_ALIASES = ("age", "n2", "salary", "n4", "n5", "n6", "n7", "n8",
                  "n9", "n10",
                  "d1", "d2", "d3", "d4", "d5", "d6", "d7", "d8",
                  "d9", "d10",
                  "name", "dept", "s3", "s4", "s5", "s6", "s7", "s8",
                  "s9", "s10")


def people_schema() -> Schema:
    return synthetic_schema(names=PEOPLE_ALIASES)
