"""Single-query (Catalyst-analog) optimizer rules.

The MQO input set consists of *locally optimized* plans (paper §3):
early filtering, predicate push-down, plan collapse.  These rules are
applied per-query before the multi-query optimizer ever sees the plans
— which also canonicalizes them so equivalent queries produce equal
fingerprints more often.
"""
from __future__ import annotations

from dataclasses import replace
from typing import FrozenSet

from . import expr as E
from . import logical as L


def _push_filter(node: L.Node) -> L.Node:
    """Push filters below projects and into join sides where possible."""
    if isinstance(node, L.Filter):
        child = node.child
        if isinstance(child, L.Filter):
            # merge adjacent filters into one conjunction
            return _push_filter(
                L.Filter(child=child.child,
                         pred=E.and_(node.pred, child.pred)))
        if isinstance(child, L.Project):
            pred_cols = E.columns_of(node.pred)
            if pred_cols <= set(child.cols):
                pushed = L.Filter(child=child.child, pred=node.pred)
                return L.Project(child=_push_filter(pushed),
                                 cols=child.cols)
        if isinstance(child, L.Join):
            lnames = frozenset(child.left.schema.names)
            rnames = frozenset(child.right.schema.names)
            parts = (node.pred.parts if isinstance(node.pred, E.And)
                     else (node.pred,))
            l_parts, r_parts, keep = [], [], []
            for p in parts:
                cols = E.columns_of(p)
                if cols <= lnames:
                    l_parts.append(p)
                elif cols <= rnames:
                    r_parts.append(p)
                else:
                    keep.append(p)
            if l_parts or r_parts:
                left = child.left
                right = child.right
                if l_parts:
                    left = L.Filter(child=left, pred=E.and_(*l_parts))
                if r_parts:
                    right = L.Filter(child=right, pred=E.and_(*r_parts))
                new_join = child.with_children(
                    (_push_filter(left), _push_filter(right)))
                if keep:
                    return L.Filter(child=new_join, pred=E.and_(*keep))
                return new_join
    if not node.children:
        return node
    return node.with_children(tuple(_push_filter(c) for c in node.children))


def _collapse_projects(node: L.Node) -> L.Node:
    if isinstance(node, L.Project) and isinstance(node.child, L.Project):
        inner = node.child
        return _collapse_projects(
            L.Project(child=inner.child, cols=node.cols))
    if not node.children:
        return node
    return node.with_children(
        tuple(_collapse_projects(c) for c in node.children))


def _prune_columns(node: L.Node, needed: FrozenSet[str]) -> L.Node:
    """Insert a Project directly above each Scan keeping only needed
    columns (the Parquet/columnar pruning the paper relies on)."""
    if isinstance(node, L.Scan):
        names = node.schema.names
        keep = tuple(n for n in names if n in needed)
        if keep != names and keep:
            return L.Project(child=node, cols=keep)
        return node
    if isinstance(node, L.Project):
        child_needed = frozenset(node.cols)
        return replace(node, child=_prune_columns(node.child, child_needed))
    if isinstance(node, L.Filter):
        child_needed = needed | E.columns_of(node.pred)
        new_child = _prune_columns(node.child, child_needed)
        return node.with_children((new_child,))
    if isinstance(node, L.Join):
        lnames = frozenset(node.left.schema.names)
        rnames = frozenset(node.right.schema.names)
        keys_l = frozenset(lc for lc, _ in node.on)
        keys_r = frozenset(rc for _, rc in node.on)
        left = _prune_columns(node.left, (needed & lnames) | keys_l)
        right = _prune_columns(node.right, (needed & rnames) | keys_r)
        return node.with_children((left, right))
    if isinstance(node, L.Aggregate):
        need = frozenset(node.group_by) | frozenset(
            c for _, fn, c in node.aggs if c)
        return node.with_children((_prune_columns(node.child, need),))
    if isinstance(node, L.Sort):
        return node.with_children(
            (_prune_columns(node.child, needed | {node.by}),))
    if isinstance(node, (L.Limit, L.Cache)):
        return node.with_children(
            tuple(_prune_columns(c, needed) for c in node.children))
    if isinstance(node, L.Union):
        return node.with_children(
            tuple(_prune_columns(c, needed) for c in node.children))
    return node


def optimize_single(plan: L.Node) -> L.Node:
    """Catalyst-analog local optimization to a (bounded) fixpoint."""
    plan = L.as_node(plan)
    for _ in range(3):
        new = _push_filter(plan)
        new = _collapse_projects(new)
        new = _prune_columns(new, frozenset(new.schema.names))
        new = _collapse_projects(new)
        if L.explain(new) == L.explain(plan):
            break
        plan = new
    return plan
