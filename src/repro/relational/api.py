"""Fluent lazy Relation frontend (the user-facing query API).

Clients no longer hand-assemble ``logical.Node`` / ``expr`` trees.
They compose immutable, lazy :class:`Relation` builders with
operator-overloaded column expressions:

    from repro.relational import c

    top = (session.table("sales")
           .where((c.price > 5) & (c.region == "EU"))
           .select("price", "qty")
           .group_by("qty").agg(("rev", "sum", "price")))
    handle = service.submit(top)

``c.price > 5`` builds a :class:`Pred` over the expression IR; ``&``,
``|`` and ``~`` compose predicates; a literal on either side works
(``5 < c.price`` and ``c.price > 5`` are the same predicate after
canonicalization).  Nothing executes until the Relation reaches a
session/service sink — submission compiles the built tree through
:mod:`relational.canonical`, so every syntactic spelling of a query
maps to one ψ and one strict fingerprint and the MQO can share its
work.  Raw ``logical.Node`` trees remain accepted at every sink as a
deprecation shim (they are canonicalized identically).

The legacy DataFrame-style methods (``filter(E.cmp(...))``,
``project``, ``groupby``) are kept as aliases so existing call sites
migrate incrementally.
"""
from __future__ import annotations

from typing import Sequence, Tuple, Union

import numpy as np

from . import expr as E
from . import logical as L
from .canonical import canonicalize_plan, format_plan

Literal = Union[int, float, str, bytes]


# ---------------------------------------------------------------------------
# operator-overloaded expressions
# ---------------------------------------------------------------------------
class Pred:
    """A boolean predicate: wraps an ``expr`` tree, composable with
    ``&`` (and), ``|`` (or) and ``~`` (not)."""

    __slots__ = ("expr",)

    def __init__(self, expr: E.Expr):
        self.expr = expr

    def __and__(self, other: "Pred") -> "Pred":
        return Pred(E.and_(self.expr, as_expr(other)))

    def __rand__(self, other: "Pred") -> "Pred":
        return Pred(E.and_(as_expr(other), self.expr))

    def __or__(self, other: "Pred") -> "Pred":
        return Pred(E.or_(self.expr, as_expr(other)))

    def __ror__(self, other: "Pred") -> "Pred":
        return Pred(E.or_(as_expr(other), self.expr))

    def __invert__(self) -> "Pred":
        return Pred(E.not_(self.expr))

    def __bool__(self):
        raise TypeError(
            "use & | ~ to compose predicates (not and/or/not, which "
            "coerce to bool)")

    def __repr__(self) -> str:
        return f"Pred({E.pretty(self.expr)})"


class ColExpr:
    """A named column; comparisons against literals or other columns
    build :class:`Pred`.  Python's reflected dispatch makes the
    literal-on-left spelling (``5 < c.price``) arrive here too."""

    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def _cmp(self, op: str, other) -> Pred:
        if isinstance(other, ColExpr):
            return Pred(E.Cmp(op, E.Col(self.name), other.node))
        if isinstance(other, E.Col):
            return Pred(E.Cmp(op, E.Col(self.name), other))
        # numpy scalars coerce so each value has ONE canonical literal
        if isinstance(other, np.integer):
            other = int(other)
        elif isinstance(other, np.floating):
            other = float(other)
        if not isinstance(other, (int, float, str, bytes)):
            # fail at the call site, not later inside fingerprinting
            raise TypeError(
                f"cannot compare column {self.name!r} {op} "
                f"{type(other).__name__} — expected a column or an "
                f"int/float/str/bytes literal")
        if isinstance(other, float) and not np.isfinite(other):
            # NaN satisfies no ordered compare; letting it through
            # would also poison the canonical complement fold
            raise ValueError(
                f"non-finite literal in compare against column "
                f"{self.name!r} — NaN/inf predicates are unsupported")
        return Pred(E.Cmp(op, E.Col(self.name), E.Lit(other)))

    def __lt__(self, other) -> Pred:
        return self._cmp("<", other)

    def __le__(self, other) -> Pred:
        return self._cmp("<=", other)

    def __gt__(self, other) -> Pred:
        return self._cmp(">", other)

    def __ge__(self, other) -> Pred:
        return self._cmp(">=", other)

    def __eq__(self, other) -> Pred:  # type: ignore[override]
        return self._cmp("==", other)

    def __ne__(self, other) -> Pred:  # type: ignore[override]
        return self._cmp("!=", other)

    __hash__ = None  # type: ignore[assignment]

    @property
    def node(self) -> E.Col:
        return E.Col(self.name)

    def isin(self, values: Sequence[Literal]) -> Pred:
        # each value routes through _cmp so the literal coercion +
        # non-finite guard apply exactly as for direct compares; the
        # validated literals then form ONE first-class membership node
        # (the kernel evaluates it as a single opcode)
        lits = []
        for v in values:
            e = self._cmp("==", v).expr
            if not isinstance(e.rhs, E.Lit):
                raise TypeError(
                    f"isin over column {self.name!r} expects literal "
                    f"values, got {type(v).__name__}")
            lits.append(e.rhs.value)
        return Pred(E.In(E.Col(self.name), tuple(lits)))

    def between(self, lo: Literal, hi: Literal) -> Pred:
        return Pred(E.and_(self._cmp(">=", lo).expr,
                           self._cmp("<=", hi).expr))

    def __repr__(self) -> str:
        return f"c.{self.name}"


class _ColNamespace:
    """``c.price`` / ``c["net profit"]`` → :class:`ColExpr`."""

    def __getattr__(self, name: str) -> ColExpr:
        if name.startswith("__"):
            raise AttributeError(name)
        return ColExpr(name)

    def __getitem__(self, name: str) -> ColExpr:
        return ColExpr(name)


#: The column namespace: ``from repro.relational import c``.
c = _ColNamespace()


def col(name: str) -> ColExpr:
    return ColExpr(name)


def as_expr(obj) -> E.Expr:
    """Coerce a predicate-like object (Pred, ColExpr comparison result,
    or raw expr tree) to the expression IR."""
    if isinstance(obj, Pred):
        return obj.expr
    if isinstance(obj, (E.Cmp, E.In, E.And, E.Or, E.Not, E.TrueExpr)):
        return obj
    if isinstance(obj, bool):
        return E.TRUE if obj else E.Not(E.TRUE)
    raise TypeError(f"not a predicate: {type(obj).__name__}")


# ---------------------------------------------------------------------------
# the lazy Relation builder
# ---------------------------------------------------------------------------
class Relation:
    """An immutable, lazy relational expression.

    Every method returns a NEW Relation over an extended logical tree;
    nothing executes until the Relation reaches a session or service
    sink (``collect`` / ``submit`` / ``run_batch``), where the tree is
    compiled through the canonicalization pass.  Mirrors the legacy
    ``logical.Node`` builder surface (filter/project/groupby/...) so it
    is a drop-in replacement for ``Session.table`` results.
    """

    __slots__ = ("_node", "_session", "_hint_cache")

    def __init__(self, node: L.Node, session=None, hint_cache: bool = False):
        self._node = node
        self._session = session
        self._hint_cache = hint_cache

    # -- plumbing ----------------------------------------------------------
    def __plan_node__(self) -> L.Node:
        return self._node

    def _wrap(self, node: L.Node) -> "Relation":
        return Relation(node, self._session, self._hint_cache)

    @property
    def plan(self) -> L.Node:
        """The raw logical tree as built (un-canonicalized)."""
        return self._node

    @property
    def schema(self):
        return self._node.schema

    @property
    def columns(self) -> Tuple[str, ...]:
        return self._node.schema.names

    @property
    def session(self):
        return self._session

    @property
    def hint_cache(self) -> bool:
        return self._hint_cache

    def logical_plan(self) -> L.Node:
        """The canonical logical tree — what fingerprinting sees."""
        return canonicalize_plan(self._node)

    # -- relational operators ----------------------------------------------
    def where(self, pred) -> "Relation":
        """Keep rows satisfying ``pred`` (a :class:`Pred` from the
        ``c`` namespace, or a raw expr tree)."""
        return self._wrap(L.Filter(child=self._node, pred=as_expr(pred)))

    filter = where                      # legacy alias

    def select(self, *cols: str) -> "Relation":
        if len(set(cols)) != len(cols):
            # columnar Tables are keyed by name, so a duplicate output
            # column cannot be represented — fail at the call site
            dupes = sorted({x for x in cols if cols.count(x) > 1})
            raise ValueError(f"duplicate projection columns: {dupes}")
        return self._wrap(L.Project(child=self._node, cols=tuple(cols)))

    project = select                    # legacy alias

    def join(self, other: Union["Relation", L.Node], left_on: str,
             right_on: str) -> "Relation":
        return self._wrap(L.Join(left=self._node, right=L.as_node(other),
                                 on=((left_on, right_on),)))

    def group_by(self, *keys: str) -> "RelationGroupBy":
        return RelationGroupBy(self, tuple(keys))

    groupby = group_by                  # legacy alias

    def sort(self, by: str, desc: bool = False) -> "Relation":
        return self._wrap(L.Sort(child=self._node, by=by, desc=desc))

    def limit(self, n: int) -> "Relation":
        return self._wrap(L.Limit(child=self._node, n=int(n)))

    def union(self, other: Union["Relation", L.Node]) -> "Relation":
        return self._wrap(L.Union(left=self._node, right=L.as_node(other)))

    def cache_hint(self) -> "Relation":
        """Mark this relation as worth caching: in the window that
        executes it, the MQO considers single-consumer subexpressions
        as covering candidates too (k=1), so a hinted one-off query can
        materialize covering state that later windows resume from.
        Admission is still priced by the cost model and budget."""
        return Relation(self._node, self._session, hint_cache=True)

    # -- execution / introspection ------------------------------------------
    def explain_str(self, *, canonical: bool = True,
                    show_schema: bool = False) -> str:
        """Pretty-printed plan (the canonical form by default — what
        the optimizer fingerprints)."""
        node = self.logical_plan() if canonical else self._node
        return format_plan(node, show_schema=show_schema)

    def collect(self):
        """Execute this relation on its bound session (one-query batch
        through the full service path) and return the result Table."""
        if self._session is None:
            raise RuntimeError(
                "Relation is not bound to a Session — build it via "
                "session.table(...) or pass it to run_batch/submit")
        return self._session.run_batch([self]).results[0].table

    def __repr__(self) -> str:
        root = type(self._node).__name__
        return (f"Relation({root}, cols={list(self.columns)}, "
                f"bound={self._session is not None})")


class RelationGroupBy:
    """Intermediate ``group_by`` state; ``agg`` closes it."""

    __slots__ = ("_rel", "_keys")

    def __init__(self, rel: Relation, keys: Tuple[str, ...]):
        self._rel = rel
        self._keys = keys

    def agg(self, *aggs: Tuple[str, str, str]) -> Relation:
        """Each agg is ``(output_name, fn, input_col)`` with fn in
        sum|min|max|count|mean (count ignores input_col)."""
        node = L.Aggregate(child=self._rel._node, group_by=self._keys,
                           aggs=tuple(aggs))
        return self._rel._wrap(node)
