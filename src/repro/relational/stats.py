"""Cardinality estimation and the relational cost model (paper §4.2).

Statistics are collected in a pre-processing phase (as in the paper's
prototype): per column — row count, min/max, approximate NDV, and an
equi-width histogram.  Column names are unique across the catalog and
are never renamed by operators, so a single column-stats registry
serves predicates at any plan depth.

The cost model prices a sub-tree as CPU + I/O + network (Eq. 1–3
inputs).  Constants are per-byte / per-row weights representative of
the compute cluster; §6.3 of the paper notes results are robust to the
exact constants (we verify the same in tests).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Optional, Tuple

import numpy as np

from . import expr as E
from . import logical as L
from .fuse import FusedPipeline
from .partition import PartitionInfo, prune_parts
from .schema import Schema


@dataclass
class ColumnStats:
    count: int
    ndv: int
    vmin: float = 0.0
    vmax: float = 0.0
    hist_counts: Optional[np.ndarray] = None   # equi-width histogram
    hist_edges: Optional[np.ndarray] = None


@dataclass
class TableStats:
    nrows: int
    columns: Dict[str, ColumnStats] = field(default_factory=dict)


def build_table_stats(columns: Dict[str, np.ndarray], nrows: int,
                      schema: Schema, bins: int = 32,
                      sample: int = 200_000) -> TableStats:
    ts = TableStats(nrows=nrows)
    for name, ctype in schema.fields:
        arr = np.asarray(columns[name])[:nrows]
        if nrows > sample:
            idx = np.random.default_rng(0).choice(nrows, sample, replace=False)
            arr_s = arr[idx]
        else:
            arr_s = arr
        if ctype.kind == "str":
            # hash rows to estimate NDV
            flat = np.ascontiguousarray(arr_s).view(
                [("", arr_s.dtype)] * arr_s.shape[1]).ravel()
            ndv = len(np.unique(flat))
            ts.columns[name] = ColumnStats(count=nrows, ndv=max(1, ndv))
        else:
            if (np.issubdtype(arr.dtype, np.floating)
                    and not np.isfinite(arr).all()):
                # checked over the FULL column, not the stats sample —
                # a sampled check would let NaN slip into large tables.
                # Load-bearing invariant, not just hygiene: expression
                # canonicalization (relational.canonical) folds
                # ¬(x <= v) into x > v, which is only sound over
                # totally ordered domains — NaN satisfies neither side.
                # Rejecting NaN/inf here (the only catalog entry point)
                # is what makes that rewrite semantics-preserving for
                # every executable query.
                raise ValueError(
                    f"column {name!r} contains NaN/inf — non-finite "
                    f"float data is unsupported (breaks ordered-"
                    f"compare canonicalization and min/max statistics)")
            ndv = len(np.unique(arr_s))
            cs = ColumnStats(count=nrows, ndv=max(1, ndv),
                             vmin=float(arr_s.min()) if nrows else 0.0,
                             vmax=float(arr_s.max()) if nrows else 0.0)
            if nrows:
                counts, edges = np.histogram(arr_s.astype(np.float64),
                                             bins=bins)
                scale = nrows / max(1, arr_s.shape[0])
                cs.hist_counts = counts.astype(np.float64) * scale
                cs.hist_edges = edges
            ts.columns[name] = cs
    return ts


class StatsRegistry:
    """column name -> ColumnStats across the whole catalog (plus, for
    partitioned tables, the per-partition layout/statistics that drive
    pruning-aware cardinality and cost estimates)."""

    def __init__(self):
        self.tables: Dict[str, TableStats] = {}
        self.columns: Dict[str, ColumnStats] = {}
        self.partitions: Dict[str, PartitionInfo] = {}
        # selectivity memo keyed by predicate identity (the entry keeps
        # a strong ref, so the id stays valid).  A window prices the
        # SAME shared covering predicate once per member — per-window
        # cost was quadratic in batch size without this.  Any stats
        # (re-)registration invalidates.
        self._sel_memo: Dict[int, Tuple[E.Expr, float]] = {}

    def register(self, table: str, stats: TableStats,
                 partitions: Optional[PartitionInfo] = None):
        self.tables[table] = stats
        self.columns.update(stats.columns)
        self._sel_memo.clear()
        # re-registration must REPLACE partition metadata, including
        # dropping it when the new registration is unpartitioned —
        # stale per-partition statistics would mis-prune the new data
        if partitions is not None:
            self.partitions[table] = partitions
        else:
            self.partitions.pop(table, None)

    def col(self, name: str) -> Optional[ColumnStats]:
        return self.columns.get(name)

    def scan_rows(self, node: L.Scan) -> int:
        """Rows a (possibly partition-restricted) Scan produces."""
        ts = self.tables.get(node.table)
        total = int(ts.nrows) if ts else 1000
        info = self.partitions.get(node.table)
        if node.parts is not None and info is not None:
            return int(info.rows_of(node.parts))
        return total

    def pruned_rows(self, table: str, pred: E.Expr) -> Optional[int]:
        """Rows surviving partition pruning of ``pred`` over ``table``
        (None when the table is unpartitioned)."""
        info = self.partitions.get(table)
        if info is None:
            return None
        return int(info.rows_of(prune_parts(pred, info)))


# ---------------------------------------------------------------------------
# selectivity estimation
# ---------------------------------------------------------------------------
def _range_fraction(cs: ColumnStats, op: str, v: float) -> float:
    if cs.hist_counts is None or cs.count == 0:
        return 1.0 / 3.0
    total = float(cs.hist_counts.sum())
    if total <= 0:
        return 0.0
    edges, counts = cs.hist_edges, cs.hist_counts
    # mass strictly below v (linear interpolation within the bucket)
    below = 0.0
    for i in range(len(counts)):
        lo, hi = edges[i], edges[i + 1]
        if v >= hi:
            below += counts[i]
        elif v > lo:
            below += counts[i] * (v - lo) / max(hi - lo, 1e-12)
    frac_lt = below / total
    eq = (1.0 / max(cs.ndv, 1)) if cs.vmin <= v <= cs.vmax else 0.0
    if op == "<":
        return frac_lt
    if op == "<=":
        return min(1.0, frac_lt + eq)
    if op == ">":
        return max(0.0, 1.0 - frac_lt - eq)
    if op == ">=":
        return max(0.0, 1.0 - frac_lt)
    raise ValueError(op)


def selectivity(e: E.Expr, reg: StatsRegistry) -> float:
    memo = getattr(reg, "_sel_memo", None)
    if memo is not None:
        hit = memo.get(id(e))
        if hit is not None and hit[0] is e:
            return hit[1]
    s = _selectivity(e, reg)
    if memo is not None and not isinstance(e, E.TrueExpr):
        if len(memo) > 8192:      # serving streams see fresh predicates
            memo.clear()          # forever; bound the strong refs
        memo[id(e)] = (e, s)
    return s


def _selectivity(e: E.Expr, reg: StatsRegistry) -> float:
    if isinstance(e, E.TrueExpr):
        return 1.0
    if isinstance(e, E.Cmp):
        e = E.oriented(e)
        if isinstance(e.col, E.Lit):       # Lit-Lit constant compare
            return 1.0 if E.const_cmp(e) else 0.0
        cs = reg.col(e.col.name)
        if isinstance(e.rhs, E.Col):
            cs2 = reg.col(e.rhs.name)
            ndv = max(cs.ndv if cs else 100, cs2.ndv if cs2 else 100)
            return 1.0 / ndv if e.op == "==" else 1.0 / 3.0
        if cs is None:
            return 1.0 / 3.0
        if e.op == "==":
            return 1.0 / max(cs.ndv, 1)
        if e.op == "!=":
            return 1.0 - 1.0 / max(cs.ndv, 1)
        v = e.rhs.value
        if isinstance(v, (bytes, str)):
            return 1.0 / 3.0
        return float(np.clip(_range_fraction(cs, e.op, float(v)), 0.0, 1.0))
    if isinstance(e, E.In):
        # membership over k values ~ k distinct-value equality probes
        cs = reg.col(e.col.name)
        ndv = max(cs.ndv, 1) if cs is not None else 100
        return min(1.0, len(e.values) / ndv)
    if isinstance(e, E.And):
        s = 1.0
        for p in e.parts:
            s *= selectivity(p, reg)
        return s
    if isinstance(e, E.Or):
        s = 1.0
        for p in e.parts:
            s *= 1.0 - selectivity(p, reg)
        return 1.0 - s
    if isinstance(e, E.Not):
        return 1.0 - selectivity(e.part, reg)
    raise TypeError(type(e))


# ---------------------------------------------------------------------------
# required-column analysis (projection pruning / scan cost)
# ---------------------------------------------------------------------------
def required_columns(root: L.Node) -> Dict[int, FrozenSet[str]]:
    """id(node) -> columns of that node's OUTPUT needed by its consumers."""
    req: Dict[int, FrozenSet[str]] = {}

    def down(node: L.Node, needed: FrozenSet[str]):
        needed = needed & frozenset(node.schema.names)
        req[id(node)] = req.get(id(node), frozenset()) | needed
        if isinstance(node, L.Project):
            down(node.child, frozenset(node.cols))
        elif isinstance(node, L.Filter):
            down(node.child, needed | E.columns_of(node.pred))
        elif isinstance(node, L.Join):
            keys_l = frozenset(lc for lc, _ in node.on)
            keys_r = frozenset(rc for _, rc in node.on)
            lnames = frozenset(node.left.schema.names)
            rnames = frozenset(node.right.schema.names)
            down(node.left, (needed & lnames) | keys_l)
            down(node.right, (needed & rnames) | keys_r)
        elif isinstance(node, L.Aggregate):
            need = frozenset(node.group_by) | frozenset(
                c for _, fn, c in node.aggs if fn != "count" and c)
            down(node.child, need)
        elif isinstance(node, L.Sort):
            down(node.child, needed | frozenset((node.by,)))
        elif isinstance(node, (L.Limit, L.Cache)):
            down(node.child, needed)
        elif isinstance(node, L.Union):
            down(node.left, needed)
            down(node.right, needed)
        elif isinstance(node, FusedPipeline):
            down(node.source,
                 frozenset(node.cols) | E.columns_of(node.pred))
        # Scan / CachedScan: leaves

    down(root, frozenset(root.schema.names))
    return req


# ---------------------------------------------------------------------------
# the cost model (implements repro.core.costmodel.CostModel)
# ---------------------------------------------------------------------------
@dataclass
class CostConstants:
    """Per-byte / per-row weights (arbitrary time units, calibratable)."""

    io_csv: float = 2.0e-9       # read a CSV byte from storage
    parse: float = 6.0e-9        # parse a CSV byte into a typed value
    io_col: float = 1.0e-9       # read a columnar (Parquet-analog) byte
    cpu_cmp: float = 1.5e-9      # one predicate term on one row
    cpu_copy: float = 0.3e-9     # copy/gather one byte
    sort: float = 2.0e-9         # one row-swap unit in a sort (x log n)
    net: float = 3.0e-9          # shuffle one byte across the interconnect
    cache_w: float = 1.2e-9      # write one byte into the RAM cache
    cache_r: float = 0.4e-9      # read one byte from the RAM cache
    # fused-pipeline predicate term on one row: the fused path skips the
    # per-operator intermediate relation and host sync, so a residual
    # term is cheaper than an eager one (calibratable like the rest)
    fused_cmp: float = 0.6e-9
    # fixed per-kernel-launch overhead (host->device trip + program
    # setup), used to price a window's shared batched dispatch against
    # per-query dispatches
    dispatch: float = 3.0e-6


class RelationalCostModel:
    """CostModel over relational plans using the stats registry.

    ``prune`` mirrors ``ExecutionConfig.prune``: pruning-aware scan
    pricing must only apply when the executor actually prunes —
    otherwise the no-pruning baseline would be priced for an execution
    path it never takes."""

    def __init__(self, reg: StatsRegistry,
                 consts: CostConstants | None = None,
                 prune: bool = True):
        self.reg = reg
        self.c = consts or CostConstants()
        self.prune = prune
        # predicted-vs-measured accuracy log, attached by the session's
        # telemetry (core.costmodel.CalibrationLog); None until then
        self.calibration_log = None

    def calibration(self) -> dict:
        """Predicted-vs-measured accuracy report (CE materializations
        and cached reads recorded by the executor)."""
        from ..core.costmodel import model_calibration

        return model_calibration(self)

    # ---- cardinalities ----------------------------------------------------
    def output_rows(self, node: L.Node) -> int:
        return max(1, int(self._rows(node)))

    def _rows(self, node: L.Node) -> float:
        if isinstance(node, L.Scan):
            return float(self.reg.scan_rows(node))
        if isinstance(node, L.CachedScan):
            return 1000.0  # post-rewrite leaf; not priced
        if isinstance(node, FusedPipeline):
            return (self._rows(node.source)
                    * selectivity(node.pred, self.reg))
        if isinstance(node, L.Filter):
            return self._rows(node.child) * selectivity(node.pred, self.reg)
        if isinstance(node, (L.Project, L.Sort, L.Cache)):
            return self._rows(node.child)
        if isinstance(node, L.Limit):
            return min(float(node.n), self._rows(node.child))
        if isinstance(node, L.Join):
            rl, rr = self._rows(node.left), self._rows(node.right)
            denom = 1.0
            for lc, rc in node.on:
                ndv_l = self.reg.col(lc).ndv if self.reg.col(lc) else 100
                ndv_r = self.reg.col(rc).ndv if self.reg.col(rc) else 100
                denom *= max(ndv_l, ndv_r)
            return max(1.0, rl * rr / max(denom, 1.0))
        if isinstance(node, L.Aggregate):
            child = self._rows(node.child)
            groups = 1.0
            for g in node.group_by:
                cs = self.reg.col(g)
                groups *= cs.ndv if cs else 100
            return max(1.0, min(child, groups))
        if isinstance(node, L.Union):
            return self._rows(node.left) + self._rows(node.right)
        raise TypeError(type(node))

    def output_bytes(self, node: L.Node) -> int:
        return int(self.output_rows(node) * node.schema.row_mem_bytes)

    # ---- execution cost C_E ------------------------------------------------
    def execution_cost(self, node: L.Node) -> float:
        req = required_columns(node)
        return self._cost(node, req)

    def _cost(self, node: L.Node, req: Dict[int, FrozenSet[str]]) -> float:
        c = self.c
        rows = self._rows(node)
        if isinstance(node, L.Scan):
            n = float(self.reg.scan_rows(node))
            needed = req.get(id(node), frozenset(node.schema.names))
            if node.fmt == "csv":
                # CSV must read whole rows, then parse the needed fields.
                read = n * node.schema.row_csv_bytes * c.io_csv
                parse = n * sum(node.schema.coltype(x).csv_width
                                for x in needed) * c.parse
                return read + parse
            col_bytes = n * sum(node.schema.coltype(x).mem_bytes
                                for x in needed)
            return col_bytes * c.io_col
        if isinstance(node, L.CachedScan):
            return 0.0
        if isinstance(node, FusedPipeline):
            # one pass over the source: every residual term is priced at
            # the fused rate, plus the gather of the projected output.
            # Partition pruning shrinks both the scan and the per-row
            # predicate work to the surviving partitions' rows (the
            # executor scans only those ranges), which is what gives
            # selective fused pipelines over partitioned tables their
            # tighter C_E — the OUTPUT estimate (`rows`) is unchanged,
            # since pruning only removes rows the predicate rejects.
            terms = max(_n_terms(node.pred), 1)
            src_cost = self._cost(node.source, req)
            src_rows = self._rows(node.source)
            if (self.prune and isinstance(node.source, L.Scan)
                    and node.source.parts is None):
                pruned = self.reg.pruned_rows(node.source.table, node.pred)
                if pruned is not None and src_rows > 0:
                    frac = min(1.0, pruned / src_rows)
                    src_cost *= frac
                    src_rows = float(pruned)
            return (src_cost
                    + src_rows * terms * c.fused_cmp
                    + rows * node.schema.row_mem_bytes * c.cpu_copy)
        if isinstance(node, L.Filter):
            terms = _n_terms(node.pred)
            return (self._cost(node.child, req)
                    + self._rows(node.child) * terms * c.cpu_cmp)
        if isinstance(node, L.Project):
            return self._cost(node.child, req)  # metadata-only in our engine
        if isinstance(node, L.Join):
            rl, rr = self._rows(node.left), self._rows(node.right)
            lb = rl * node.left.schema.row_mem_bytes
            rb = rr * node.right.schema.row_mem_bytes
            sort_cost = (rl * math.log2(max(rl, 2))
                         + rr * math.log2(max(rr, 2))) * c.sort
            shuffle = (lb + rb) * c.net
            build_out = rows * node.schema.row_mem_bytes * c.cpu_copy
            return (self._cost(node.left, req) + self._cost(node.right, req)
                    + sort_cost + shuffle + build_out)
        if isinstance(node, L.Aggregate):
            rc = self._rows(node.child)
            return (self._cost(node.child, req)
                    + rc * math.log2(max(rc, 2)) * c.sort
                    + rows * node.schema.row_mem_bytes * c.net)
        if isinstance(node, L.Sort):
            rc = self._rows(node.child)
            bytes_ = rc * node.schema.row_mem_bytes
            return (self._cost(node.child, req)
                    + rc * math.log2(max(rc, 2)) * c.sort + bytes_ * c.net)
        if isinstance(node, (L.Limit, L.Cache)):
            return self._cost(node.child, req)
        if isinstance(node, L.Union):
            return (self._cost(node.left, req) + self._cost(node.right, req)
                    + rows * node.schema.row_mem_bytes * c.cpu_copy)
        raise TypeError(type(node))

    # ---- cache costs C_W / C_R ----------------------------------------------
    def write_cost(self, node: L.Node) -> float:
        return self.output_bytes(node) * self.c.cache_w

    def read_cost(self, node: L.Node) -> float:
        return self.output_bytes(node) * self.c.cache_r

    def extraction_cost(self, tree: L.Node, member: L.Node) -> float:
        """Per-consumer residual cost of deriving ``member`` from the
        cached covering relation (paper Eq. 2's C_R prices only the raw
        byte read; a *divergent* consumer also re-applies its own
        predicates — one fused pass over the CE output under the fused
        executor).  Syntactically equal members extract by identity and
        cost nothing."""
        terms = _residual_terms(tree, member)
        if terms == 0:
            return 0.0
        ce_rows = self._rows(tree)
        gather = self.output_bytes(member) * self.c.cpu_copy
        return ce_rows * terms * self.c.fused_cmp + gather

    # ---- operator cardinality estimates (deferred-sync capacities) -------
    def filter_estimate(self, pred: E.Expr, in_rows: int) -> int:
        return max(0, int(in_rows * selectivity(pred, self.reg)))

    def plan_selectivity(self, plan: L.Node) -> float:
        """Combined selectivity of every filter in a plan — used to
        CONDITION residual estimates over a cached covering relation:
        base-table selectivities applied to CE-output rows would
        systematically undershoot (the CE already filtered by the OR of
        member predicates), forcing the overflow re-dispatch on exactly
        the consumer hot path."""
        s = 1.0
        if isinstance(plan, (L.Filter, FusedPipeline)):
            s *= selectivity(plan.pred, self.reg)
        for c in plan.children:
            s *= self.plan_selectivity(c)
        return min(max(s, 1e-6), 1.0)

    def join_estimate(self, on: Tuple[str, str], l_rows: int,
                      r_rows: int) -> int:
        lc, rc = on
        ndv_l = self.reg.col(lc).ndv if self.reg.col(lc) else 100
        ndv_r = self.reg.col(rc).ndv if self.reg.col(rc) else 100
        denom = max(ndv_l, ndv_r, 1)
        return max(1, int(l_rows * r_rows / denom))

    def union_estimate(self, l_rows: int, r_rows: int) -> int:
        """Union output capacity = sum of the input cardinality
        estimates (exact — union is append-only), letting the operator
        dispatch one fused compaction instead of per-column eager
        sizing (ROADMAP open item: deferred sync for Union)."""
        return max(1, int(l_rows) + int(r_rows))

    def window_dispatch_cost(self, n_queries: int, batched: bool) -> float:
        """Dispatch-overhead price of executing ``n_queries`` same-shape
        fused pipelines: batched = one shared mask launch + one
        compaction per query; per-query = a mask launch AND a compaction
        per query.  Data movement is identical either way (same scan,
        same output rows), so only launch overheads differ."""
        if batched:
            return (1 + n_queries) * self.c.dispatch
        return 2 * n_queries * self.c.dispatch

    def sort_estimate(self, in_rows: int) -> int:
        """Sort preserves cardinality, so the estimate is exact; it
        exists so the fused sort path sizes its output from the input
        cardinality (like filter/join/aggregate/union) instead of
        carrying the child's full padded capacity forward."""
        return max(1, int(in_rows))

    def group_estimate(self, group_by: Tuple[str, ...],
                       in_rows: int) -> int:
        groups = 1.0
        for g in group_by:
            cs = self.reg.col(g)
            groups *= cs.ndv if cs else 100
        return max(1, int(min(in_rows, groups)))


def _n_terms(e: E.Expr) -> int:
    if isinstance(e, E.Cmp):
        return 1
    if isinstance(e, E.In):
        return max(1, len(e.values))
    if isinstance(e, (E.And, E.Or)):
        return sum(_n_terms(p) for p in e.parts)
    if isinstance(e, E.Not):
        return _n_terms(e.part)
    return 0


def _residual_terms(tree: L.Node, member: L.Node) -> int:
    """Predicate terms the member must re-apply over the CE output:
    lock-step walk counting member filters whose predicate is wider in
    the covering tree (cf. rewriter._collect_divergent; commutative
    child alignment is skipped — this is an estimate, not a rewrite)."""
    total = 0
    if (isinstance(tree, L.Filter) and isinstance(member, L.Filter)
            and E.canonical(member.pred) != E.canonical(tree.pred)):
        total += _n_terms(member.pred)
    for tc, mc in zip(tree.children, member.children):
        total += _residual_terms(tc, mc)
    return total
