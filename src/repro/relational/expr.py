"""Scalar predicate / expression trees over columns.

Expressions are immutable, canonicalizable (for strict fingerprints and
OR-merge dedup), evaluable against a dict of JAX column arrays, and
introspectable (column references) for projection augmentation and
selectivity estimation.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, FrozenSet, Tuple, Union

import jax.numpy as jnp
import numpy as np

Value = Union[int, float, str, bytes]

_OPS = ("<", "<=", ">", ">=", "==", "!=")


@dataclass(frozen=True)
class Col:
    name: str


@dataclass(frozen=True)
class Lit:
    value: Value


@dataclass(frozen=True)
class Cmp:
    """Binary comparison.  ``col`` is normally a :class:`Col`, but a
    reversed literal compare (``Lit op Col`` — "literal on the left",
    e.g. ``5 < price``) is representable too: canonicalization
    (relational.canonical) flips it to the column-on-left normal form,
    and every consumer that predates the flip (eval, kernel compile)
    normalizes on the fly via :func:`oriented`."""

    op: str
    col: Union[Col, Lit]
    rhs: Union[Lit, Col]

    def __post_init__(self):
        assert self.op in _OPS, self.op


# mirror the comparison when its operands are swapped (a < b ⟺ b > a)
MIRROR = {"<": ">", "<=": ">=", ">": "<", ">=": "<=",
          "==": "==", "!=": "!="}
# negate the comparison (¬(a < b) ⟺ a >= b)
NEGATE = {"<": ">=", "<=": ">", ">": "<=", ">=": "<",
          "==": "!=", "!=": "=="}


def oriented(e: Cmp) -> Cmp:
    """Normal orientation of a single compare: column-on-left for
    Lit/Col operands, and name-ordered sides for Col-Col compares (so
    ``a < b`` and ``b > a`` share one canonical form).  Identity for
    already-oriented compares; Lit-Lit compares are returned unchanged
    — constant folding handles them."""
    if isinstance(e.col, Lit) and isinstance(e.rhs, Col):
        return Cmp(MIRROR[e.op], e.rhs, e.col)
    if (isinstance(e.col, Col) and isinstance(e.rhs, Col)
            and e.rhs.name < e.col.name):
        return Cmp(MIRROR[e.op], e.rhs, e.col)
    return e


@dataclass(frozen=True)
class And:
    parts: Tuple["Expr", ...]


@dataclass(frozen=True)
class Or:
    parts: Tuple["Expr", ...]


@dataclass(frozen=True)
class Not:
    part: "Expr"


@dataclass(frozen=True)
class TrueExpr:
    pass


@dataclass(frozen=True)
class In:
    """List membership: ``col IN values`` (OR of equalities).

    A first-class node rather than a sugar expansion so the kernel
    compiler can emit one membership opcode instead of a (2k-1)-op
    OR chain, and so selectivity can price it as k/ndv directly.
    ``values`` is an ordered tuple of literals; canonicalization
    dedups and sorts it (empty → FALSE, singleton → ``==``)."""

    col: Col
    values: Tuple[Value, ...]


Expr = Union[Cmp, In, And, Or, Not, TrueExpr]
TRUE = TrueExpr()


# ---------------------------------------------------------------------------
# constructors
# ---------------------------------------------------------------------------
def col(name: str) -> Col:
    return Col(name)


def cmp(name: str, op: str, value: Value) -> Cmp:
    return Cmp(op, Col(name), Lit(value))


def col_cmp(left: str, op: str, right: str) -> Cmp:
    return Cmp(op, Col(left), Col(right))


def isin(name: str, values) -> In:
    return In(Col(name), tuple(values))


def and_(*parts: Expr) -> Expr:
    flat = []
    for p in parts:
        if isinstance(p, TrueExpr):
            continue
        flat.extend(p.parts if isinstance(p, And) else (p,))
    if not flat:
        return TRUE
    return flat[0] if len(flat) == 1 else And(tuple(flat))


def or_(*parts: Expr) -> Expr:
    flat = []
    for p in parts:
        if isinstance(p, TrueExpr):
            return TRUE
        flat.extend(p.parts if isinstance(p, Or) else (p,))
    # dedup by canonical form, preserving first-seen order
    seen, uniq = set(), []
    for p in flat:
        key = canonical(p)
        if key not in seen:
            seen.add(key)
            uniq.append(p)
    if not uniq:
        return Not(TRUE)   # empty disjunction is FALSE (canonical.FALSE)
    return uniq[0] if len(uniq) == 1 else Or(tuple(uniq))


def not_(part: Expr) -> Expr:
    return Not(part)


# ---------------------------------------------------------------------------
# introspection
# ---------------------------------------------------------------------------
def canonical(e: Expr) -> tuple:
    """Deterministic hashable form (commutative parts sorted)."""
    if isinstance(e, TrueExpr):
        return ("true",)
    if isinstance(e, Cmp):
        e = oriented(e)
        if isinstance(e.col, Lit):   # Lit-Lit: constant, key on values
            return ("cmp2", e.op, _lit_key(e.col.value),
                    _lit_key(e.rhs.value))
        rhs = (("col", e.rhs.name) if isinstance(e.rhs, Col)
               else ("lit", _lit_key(e.rhs.value)))
        return ("cmp", e.op, e.col.name, rhs)
    if isinstance(e, In):
        return ("in", e.col.name,
                tuple(sorted({_lit_key(v) for v in e.values})))
    if isinstance(e, And):
        return ("and",) + tuple(sorted(canonical(p) for p in e.parts))
    if isinstance(e, Or):
        return ("or",) + tuple(sorted(canonical(p) for p in e.parts))
    if isinstance(e, Not):
        return ("not", canonical(e.part))
    raise TypeError(type(e))


def _lit_key(v: Value):
    if isinstance(v, bytes):
        return ("b", v)
    if isinstance(v, str):
        return ("b", v.encode("utf-8"))
    if isinstance(v, bool):
        return ("i", int(v))
    if isinstance(v, int):
        return ("i", v)
    return ("f", float(v))


def columns_of(e: Expr) -> FrozenSet[str]:
    if isinstance(e, TrueExpr):
        return frozenset()
    if isinstance(e, Cmp):
        cols = set()
        if isinstance(e.col, Col):
            cols.add(e.col.name)
        if isinstance(e.rhs, Col):
            cols.add(e.rhs.name)
        return frozenset(cols)
    if isinstance(e, In):
        return frozenset((e.col.name,))
    if isinstance(e, (And, Or)):
        out: FrozenSet[str] = frozenset()
        for p in e.parts:
            out |= columns_of(p)
        return out
    if isinstance(e, Not):
        return columns_of(e.part)
    raise TypeError(type(e))


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------
def _encode_str(v: Value, width: int) -> np.ndarray:
    raw = v if isinstance(v, bytes) else str(v).encode("utf-8")
    buf = np.zeros((width,), np.uint8)
    raw = raw[:width]
    buf[: len(raw)] = np.frombuffer(raw, np.uint8)
    return buf


def eval_expr(e: Expr, columns: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Evaluate a predicate to a boolean row mask."""
    if isinstance(e, TrueExpr):
        n = next(iter(columns.values())).shape[0]
        return jnp.ones((n,), jnp.bool_)
    if isinstance(e, Cmp):
        e = oriented(e)
        if isinstance(e.col, Lit):   # Lit-Lit: constant boolean
            n = next(iter(columns.values())).shape[0]
            fill = jnp.ones if const_cmp(e) else jnp.zeros
            return fill((n,), jnp.bool_)
        lhs = columns[e.col.name]
        if isinstance(e.rhs, Col):
            rhs = columns[e.rhs.name]
        elif lhs.ndim == 2:  # string column: fixed-width byte compare
            rhs = jnp.asarray(_encode_str(e.rhs.value, lhs.shape[1]))
            eq = jnp.all(lhs == rhs[None, :], axis=1)
            if e.op == "==":
                return eq
            if e.op == "!=":
                return ~eq
            raise ValueError(f"op {e.op} unsupported for string columns")
        else:
            v = e.rhs.value
            if (isinstance(v, float) and not v.is_integer()
                    and jnp.issubdtype(lhs.dtype, jnp.integer)):
                # fractional threshold on an integer column: fold to an
                # exact integer compare (truncating the const would flip
                # <=/> at the edge; promoting to f32 is inexact > 2^24)
                folded = fold_int_cmp(
                    e.op, v, bits=jnp.iinfo(lhs.dtype).bits)
                if folded[0] == "all":
                    fill = jnp.ones if folded[1] else jnp.zeros
                    return fill((lhs.shape[0],), jnp.bool_)
                _, op2, b = folded
                e = Cmp(op2, e.col, Lit(b))
                rhs = jnp.asarray(b, dtype=lhs.dtype)
            else:
                rhs = jnp.asarray(v, dtype=lhs.dtype)
        if lhs.ndim == 2 and isinstance(e.rhs, Col):
            eq = jnp.all(lhs == rhs, axis=1)
            return eq if e.op == "==" else ~eq
        return {
            "<": lambda a, b: a < b, "<=": lambda a, b: a <= b,
            ">": lambda a, b: a > b, ">=": lambda a, b: a >= b,
            "==": lambda a, b: a == b, "!=": lambda a, b: a != b,
        }[e.op](lhs, rhs)
    if isinstance(e, In):
        # OR of equalities, routed through the Cmp path per value so
        # string encoding / fractional-on-int folding stay identical;
        # values unrepresentable in an integer column never match
        lhs = columns[e.col.name]
        m = jnp.zeros((lhs.shape[0],), jnp.bool_)
        is_int = lhs.ndim == 1 and jnp.issubdtype(lhs.dtype, jnp.integer)
        for v in e.values:
            if (is_int and isinstance(v, (int, float))
                    and not (isinstance(v, float) and not v.is_integer())):
                info = jnp.iinfo(lhs.dtype)
                if not info.min <= int(v) <= info.max:
                    continue
            m = m | eval_expr(Cmp("==", e.col, Lit(v)), columns)
        return m
    if isinstance(e, And):
        m = eval_expr(e.parts[0], columns)
        for p in e.parts[1:]:
            m = m & eval_expr(p, columns)
        return m
    if isinstance(e, Or):
        m = eval_expr(e.parts[0], columns)
        for p in e.parts[1:]:
            m = m | eval_expr(p, columns)
        return m
    if isinstance(e, Not):
        return ~eval_expr(e.part, columns)
    raise TypeError(type(e))


def const_cmp(e: Cmp) -> bool:
    """Evaluate a Lit-Lit compare to its constant truth value.

    Cross-category operands (a number vs a string/bytes) are ordered
    by a fixed category rank (numbers before byte strings) rather than
    special-cased per operator: a mere "incomparables are unequal"
    fallback would NOT be closed under the operator complement — both
    ``<`` and its negation ``>=`` would fold to False — and the
    canonicalization pass (which folds ``Not(Cmp)`` via NEGATE) would
    then disagree with the un-canonicalized eval path."""
    a, b = e.col.value, e.rhs.value
    if isinstance(a, str):
        a = a.encode("utf-8")
    if isinstance(b, str):
        b = b.encode("utf-8")
    a_num, b_num = isinstance(a, (int, float)), isinstance(b, (int, float))
    if a_num != b_num:
        a, b = (0, 1) if a_num else (1, 0)
    return {
        "<": lambda: a < b, "<=": lambda: a <= b,
        ">": lambda: a > b, ">=": lambda: a >= b,
        "==": lambda: a == b, "!=": lambda: a != b,
    }[e.op]()


def fold_int_cmp(op: str, v: float, bits: int = 32):
    """Fold a fractional-threshold compare over an INTEGER column into
    an exact integer compare (promoting the column to f32 would be
    wrong beyond 2^24, where f32 cannot represent every int).

    Returns ("all", bool) when the result is constant, else
    ("cmp", op, int_bound) with the bound saturated to the column's
    ``bits``-wide signed integer range.

    This is the ONE shared fold: :func:`eval_expr`'s compare lowering,
    partition pruning (``partition._part_maybe``), and interval
    normalization (``canonical._numeric_atom``) all route through it,
    so the three sites cannot drift apart — the shared case table in
    ``tests/test_subsumption.py`` pins each call site to this helper's
    semantics.
    """
    if op == "==":
        return ("all", False)   # an integer never equals a fraction
    if op == "!=":
        return ("all", True)
    # c < 10.5 ⟺ c < 11;  c <= 10.5 ⟺ c <= 10;  etc.
    b = math.ceil(v) if op in ("<", ">=") else math.floor(v)
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    if b < lo:
        return ("all", op in (">", ">="))
    if b > hi:
        return ("all", op in ("<", "<="))
    return ("cmp", op, int(b))


def pretty(e: Expr) -> str:
    if isinstance(e, TrueExpr):
        return "true"
    if isinstance(e, Cmp):
        lhs = e.col.name if isinstance(e.col, Col) else repr(e.col.value)
        rhs = e.rhs.name if isinstance(e.rhs, Col) else repr(e.rhs.value)
        return f"{lhs}{e.op}{rhs}"
    if isinstance(e, In):
        vals = ",".join(repr(v) for v in e.values)
        return f"{e.col.name} in [{vals}]"
    if isinstance(e, And):
        return "(" + " & ".join(pretty(p) for p in e.parts) + ")"
    if isinstance(e, Or):
        return "(" + " | ".join(pretty(p) for p in e.parts) + ")"
    if isinstance(e, Not):
        return f"!{pretty(e.part)}"
    raise TypeError(type(e))
