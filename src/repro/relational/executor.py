"""The "SparkSQL Server" (paper §5): a centralized session that
accumulates client queries, runs the multi-query optimizer over the
batch, and executes cache plans + rewritten queries on the cluster.

Memory (PR 2, see ROADMAP "Memory hierarchy"): the session owns ONE
budget-aware :class:`~repro.core.memory.MemoryManager`; the CE cache
and the device scan cache are pools of it, CEs are retained across
batches (``retain_across_batches``), and each window's MCKP re-prices
still-resident CEs as zero-weight already-paid items.

Entry points (PR 3, see ROADMAP "Query service"): the online front-end
is :class:`~repro.relational.service.QueryService` (continuous
``submit`` + micro-batch windows); ``run_batch`` here is the one-shot
path, routed through the same window machinery as a pre-closed window.
Configuration lives in one frozen
:class:`~repro.relational.service.SessionConfig`; the individual
keyword arguments of ``Session(...)`` are retained as deprecation
shims so existing call sites keep working.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from ..core.cache import CacheManager
from ..core.faults import (DegradationEvent, FaultInjector, InjectedFault)
from ..core.memory import DEVICE, MemoryManager, PidPool
from ..core.optimizer import OptimizedBatch
from . import expr as E
from . import logical as L
from .canonical import subsumption_residual
from .observe import Telemetry
from .partition import Partitioning, linear_scan_chain, partition_table
from .fuse import unfuse_plan
from .physical import ExecContext, ExecMetrics, TableStorage, execute
from .rules import optimize_single
from .schema import Table
from .service import QueryService, SessionConfig
from .stats import RelationalCostModel, StatsRegistry, build_table_stats

_UNSET = object()   # "kwarg not passed" sentinel (legacy-shim detection)


@dataclass
class QueryResult:
    table: Table
    seconds: float
    plan: L.Node


@dataclass
class BatchResult:
    # one slot per submitted query, submission order; a slot is None
    # when that query failed (its handle carries the QueryError) —
    # fault-free windows never contain None
    results: List[Optional[QueryResult]]
    total_seconds: float
    optimize_seconds: float = 0.0
    mqo: Optional[OptimizedBatch] = None
    cache_report: dict = field(default_factory=dict)
    metrics: Optional[ExecMetrics] = None
    # window resilience report (PR 6): degradation/retry events,
    # n_failed, fault-injector telemetry, post-window audit — empty
    # when the window saw no failures and no injector is configured
    resilience: dict = field(default_factory=dict)

    @property
    def per_query_seconds(self) -> List[Optional[float]]:
        return [r.seconds if r is not None else None
                for r in self.results]

    @property
    def n_failed(self) -> int:
        return sum(1 for r in self.results if r is None)


@dataclass(frozen=True)
class SubsumptionMeta:
    """Semantic-reuse index entry for one resident CE (PR 8): the
    covering tree is a Filter*/Project* chain over one unrestricted
    Scan, summarized as (table, conjunction, retained columns) so a
    later query can be matched by PREDICATE IMPLICATION instead of an
    exact strict-fingerprint equality."""

    tree: L.Node                  # covering tree (eviction-recompute plan)
    table: str
    pred: "object"                # conjunction of the chain's filters
    cols: frozenset               # column names the CE output retains


def _spill_to_host(table: Table) -> Table:
    return Table(table.schema,
                 {n: np.asarray(a) for n, a in table.columns.items()},
                 table.nrows)


def _unspill(table: Table) -> Table:
    import jax.numpy as jnp

    return Table(table.schema,
                 {n: jnp.asarray(a) for n, a in table.columns.items()},
                 table.nrows)


def _apply_partitioning(storage: TableStorage, cols: Dict[str, np.ndarray],
                        spec: Partitioning):
    """Re-cluster a TableStorage so each partition is one contiguous
    row range (see relational.partition); returns the new storage and
    the reordered typed columns (for statistics)."""
    perm, reordered, info = partition_table(spec, storage.nrows, cols)
    csv_bytes = columnar = None
    if storage.fmt == "csv":
        csv_bytes = np.ascontiguousarray(
            storage.csv_bytes[: storage.nrows][perm])
    else:
        columnar = reordered
    return TableStorage(name=storage.name, schema=storage.schema,
                        nrows=storage.nrows, fmt=storage.fmt,
                        columnar=columnar, csv_bytes=csv_bytes,
                        partitions=info), reordered


class Session:
    """Catalog + stats + cache + MQO — the paper's prototype server.

    Prefer ``Session.from_config(SessionConfig(...))``; the individual
    keyword arguments below predate :class:`SessionConfig` and are kept
    as deprecation shims (they are folded into ``self.config``).
    """

    def __init__(self, budget_bytes=_UNSET,
                 sharding=_UNSET,
                 disk_latency_per_byte=_UNSET,
                 fuse=_UNSET,
                 defer_sync=_UNSET,
                 use_scan_cache=_UNSET,
                 policy=_UNSET,
                 host_budget_bytes=_UNSET,
                 retain_across_batches=_UNSET,
                 config: Optional[SessionConfig] = None):
        # sentinel defaults: "was this kwarg passed at all?" — the real
        # default values live in ONE place (the ExecutionConfig /
        # MemoryConfig dataclass fields; from_legacy_kwargs forwards
        # only what was passed), and an explicitly-passed default still
        # counts as a legacy kwarg (so mixing it with config= is caught
        # instead of silently dropped)
        passed = {k: v for k, v in dict(
            budget_bytes=budget_bytes, sharding=sharding,
            disk_latency_per_byte=disk_latency_per_byte, fuse=fuse,
            defer_sync=defer_sync, use_scan_cache=use_scan_cache,
            policy=policy, host_budget_bytes=host_budget_bytes,
            retain_across_batches=retain_across_batches).items()
            if v is not _UNSET}
        if config is not None and passed:
            # a config must be the WHOLE configuration — mixing it with
            # legacy knobs would silently drop whichever loses
            raise ValueError(
                f"pass either config= or the legacy keyword "
                f"arguments, not both (got {sorted(passed)})")
        if config is None:
            # deprecation shim: fold the legacy knob sprawl into the
            # unified config (execution / memory / mqo sub-configs)
            if passed:
                warnings.warn(
                    f"Session keyword arguments {sorted(passed)} are "
                    f"deprecated — build a SessionConfig and use "
                    f"Session.from_config(...)", DeprecationWarning,
                    stacklevel=2)
            config = SessionConfig.from_legacy_kwargs(**passed)
        self.config = config
        ex, mem = config.execution, config.memory

        self.catalog: Dict[str, TableStorage] = {}
        self.stats = StatsRegistry()
        self.budget = int(mem.budget_bytes)
        self.cost_model = RelationalCostModel(
            self.stats, prune=getattr(ex, "prune", True))
        # execution-path knobs, mirrored as mutable attributes (bench
        # harnesses tweak e.g. disk_latency_per_byte post-construction;
        # self.config stays the frozen construction-time record)
        self.sharding = ex.sharding
        self.disk_latency_per_byte = ex.disk_latency_per_byte
        self.fuse = ex.fuse
        self.defer_sync = ex.defer_sync
        self.use_scan_cache = ex.use_scan_cache
        self.use_pallas_filter = ex.use_pallas_filter
        self.prune = getattr(ex, "prune", True)
        self.window_batch = getattr(ex, "window_batch", True)
        self.shape_cache = getattr(ex, "shape_cache", True)
        self.pid_cache = getattr(ex, "pid_cache", True)
        # One budget-aware memory hierarchy for everything the session
        # materializes on device (see core.memory): the CE cache spills
        # device -> host -> drop; evicted scan columns just drop (their
        # source host arrays still live in the catalog).  The host tier
        # is bounded too (default 4x the device budget) so a long-lived
        # session with retention cannot grow host RAM without limit.
        self.retain_across_batches = mem.retain_across_batches
        host_budget = mem.host_budget_bytes
        if host_budget is None:
            host_budget = 4 * self.budget
        self.memory = MemoryManager(self.budget,
                                    host_budget=host_budget,
                                    policy=mem.policy)
        self._scan_pool = self.memory.pool("scan")
        self._ce_cache = CacheManager(
            self.budget, spill_fn=_spill_to_host, unspill_fn=_unspill,
            manager=self.memory, pool="ce")
        # strict content fingerprint -> loose psi, for every covering
        # relation materialized by an earlier window.  Strict keys are
        # the CACHE identity (several same-structure CEs with different
        # merged predicates stay resident side by side); the loose psi
        # is kept as the optimizer's cheap membership pre-filter.
        # Cache PLANS need no retention: rewrite_batch regenerates a
        # fresh, intra-window-consistent plan for every selected CE.
        self._resident_index: Dict[bytes, bytes] = {}
        # strict key -> SubsumptionMeta for resident CEs whose tree is
        # a Filter*/Project* chain over one Scan: the semantic-reuse
        # index (PR 8) — a later query whose predicate is IMPLIED by a
        # resident CE's weaker predicate resumes from the CE plus the
        # residual conjuncts, without an exact-fingerprint match.
        self._resident_meta: Dict[bytes, "SubsumptionMeta"] = {}
        # the fourth memory pool (PR 8): per-(table, canonical conjunct)
        # partition-ID bitsets, populated as a side effect of fused
        # execution and intersected to prune partitions by observed
        # history before any scan
        self._pid_pool = PidPool(self.memory) if self.pid_cache else None
        # lazily-created QueryService backing the one-shot run_batch
        self._oneshot: Optional[QueryService] = None
        # -- resilience (PR 6, ROADMAP "Failure semantics") ----------------
        # fault_injector is None unless config.resilience.faults enables
        # the harness; the memory manager shares it (spill_to_host
        # point) and ExecContext.from_exec_config picks it up from the
        # mirrored attribute below.  _sleep is the backoff clock,
        # injectable so retry tests never wall-sleep.
        self.resilience = config.resilience
        self.fault_injector = FaultInjector.from_config(
            config.resilience.faults)
        self.memory.faults = self.fault_injector
        self._sleep = time.sleep
        # -- telemetry (PR 9, ROADMAP "Observability") ---------------------
        # always-on metrics registry + cost-model calibration log; span
        # tracing stays the no-op singleton until enable_tracing().
        # The memory manager, fault injector, and cost model all feed
        # the same hub, so metrics_report() has ONE source of truth.
        self._telemetry = Telemetry()
        self.memory.telemetry = self._telemetry
        self.cost_model.calibration_log = self._telemetry.calibration
        if self.fault_injector is not None:
            self.fault_injector.registry = self._telemetry.registry

    def telemetry(self) -> Telemetry:
        """The session's observability hub: metrics registry, span
        tracer (``.enable_tracing()`` to collect spans), calibration
        log, and trace exporters (see relational.observe)."""
        return self._telemetry

    def enable_tracing(self, clock=None):
        """Turn on query-lifecycle span tracing; returns the tracer."""
        return self._telemetry.enable_tracing(clock)

    def metrics_report(self) -> dict:
        """The unified observability report (PR 9) — see
        :func:`~repro.relational.observe.build_metrics_report`."""
        from .observe import build_metrics_report

        return build_metrics_report(self)

    @classmethod
    def from_config(cls, config: SessionConfig) -> "Session":
        return cls(config=config)

    # -- catalog management -------------------------------------------------
    def register(self, storage: TableStorage,
                 columnar_for_stats: Optional[Dict[str, np.ndarray]] = None,
                 partitioning: Optional[Partitioning] = None):
        """Install (or replace) a table in the catalog.

        ``partitioning`` declares horizontal range/hash partitioning
        (relational.partition): the rows are physically RE-CLUSTERED so
        each partition is a contiguous range, per-partition min/max/NDV
        statistics are collected for pruning, scans go through
        per-partition device cache entries, and covering expressions
        over the table become partition-grained MCKP candidates.

        Re-registering a name invalidates everything derived from the
        old data: whole-table AND per-partition scan-pool entries (all
        scan keys lead with the table name), retained CE content
        including partition-grained ``(strict, pid)`` entries (the CE
        pool is cleared outright — CE plans can join across tables),
        and the old registration's table/partition statistics.
        """
        # re-registering a name must not serve the old table's device
        # buffers from the scan cache (keys lead with the table name) ...
        self._scan_pool.invalidate(lambda k: k[0] == storage.name)
        # ... and any retained CE content derived from the old data is
        # stale too (CE plans can join across tables — drop them all,
        # partition-grained entries included)
        if storage.name in self.catalog:
            self._ce_cache.clear()
            self._resident_index.clear()
            self._resident_meta.clear()
        # pid bitsets are per-table observations of the OLD rows: the
        # new data's partitions must not be pruned by them
        if self._pid_pool is not None:
            self._pid_pool.invalidate_table(storage.name)
        cols = storage.columnar if storage.columnar is not None \
            else columnar_for_stats
        assert cols is not None, "stats need typed columns (pre-processing)"
        if partitioning is not None:
            storage, cols = _apply_partitioning(storage, cols, partitioning)
        self.catalog[storage.name] = storage
        self.stats.register(
            storage.name,
            build_table_stats(cols, storage.nrows, storage.schema),
            partitions=storage.partitions)

    def table(self, name: str):
        """The catalog table as a fluent lazy :class:`Relation` — the
        root of the builder API (``.where(c.x > 5).select(...)...``).
        The Relation mirrors the legacy Node builder methods, so older
        ``.filter(E.cmp(...))``-style call sites keep working."""
        from .api import Relation

        return Relation(self.scan_node(name), session=self)

    def scan_node(self, name: str) -> L.Scan:
        """The raw logical Scan leaf (the pre-Relation ``table()``)."""
        st = self.catalog[name]
        return L.scan(name, st.schema, st.fmt)

    # -- execution ------------------------------------------------------------
    def _fresh_ctx(self, cache: Optional[CacheManager] = None) -> ExecContext:
        # the session itself quacks like an ExecutionConfig (the knobs
        # are mirrored as attributes above)
        return ExecContext.from_exec_config(
            self.catalog, self, cache=cache,
            cost_model=self.cost_model,
            scan_cache=self._scan_pool if self.use_scan_cache else None,
            pid_cache=self._pid_pool)

    def clear_scan_cache(self) -> None:
        """Drop memoized device scan buffers (e.g. after data changes)."""
        self._scan_pool.clear()

    def service(self, **kw) -> QueryService:
        """A new online front-end over this session (continuous
        ``submit`` + micro-batch MQO windows; see relational.service)."""
        return QueryService(self, **kw)

    def planning_capacity(self, budget: Optional[int] = None) -> int:
        """MCKP capacity for the next window: the device bytes new CE
        materializations can actually claim.  Bytes other pools hold
        (scan columns, serving prefix states) and bytes already pinned
        under retained resident CEs are subtracted from the device
        budget — planning with the full session budget would admit CEs
        the hierarchy immediately spills (ROADMAP open item)."""
        budget = self.budget if budget is None else int(budget)
        if budget <= 0 or not self.config.mqo.pressure_aware:
            return budget
        mm = self.memory
        ce_pool = mm.pools.get("ce")
        ce_dev = ce_pool.stats.used if ce_pool is not None else 0
        other = mm.device_used - ce_dev
        retained = 0
        if ce_pool is not None:
            # whole-CE residents tracked by the strict index, plus every
            # partition-grained (strict, pid) entry — both survive into
            # the next window, so their device bytes are not claimable
            retained = sum(
                e.nbytes for e in ce_pool.entries.values()
                if e.tier == DEVICE
                and (e.key in self._resident_index
                     if isinstance(e.key, bytes) else True))
        return max(0, min(budget, mm.device_budget - other - retained))

    def ce_resident_parts(self) -> Dict[bytes, frozenset]:
        """strict fingerprint -> resident partition ids, for every
        partition-grained CE entry still materialized (device or host
        tier) — the per-partition cross-window reuse set the optimizer
        re-prices as already-paid (rebuilt from live cache keys each
        window, so dropped entries disappear automatically)."""
        out: Dict[str, set] = {}
        for key in self._ce_cache.keys():
            if isinstance(key, tuple) and len(key) == 2:
                out.setdefault(key[0], set()).add(key[1])
        return {k: frozenset(v) for k, v in out.items()}

    # -- semantic subsumption (PR 8) ----------------------------------------
    def _note_subsumable(self, ce) -> None:
        """Index a retained CE for subsumption matching when its tree
        is a Filter*/Project* chain over one unrestricted Scan (the
        dominant CE shape after MQO rewriting)."""
        chain = linear_scan_chain(ce.tree)
        if chain is None:
            return
        scan, pred = chain
        if scan.parts is not None:
            return
        self._resident_meta[ce.strict_psi()] = SubsumptionMeta(
            tree=ce.tree, table=scan.table, pred=pred,
            cols=frozenset(ce.tree.schema.names))

    def find_subsumer(self, plan: L.Node):
        """A resident CE whose *weaker* predicate provably subsumes
        ``plan``'s — the semantic-reuse lookup (PR 8).  ``plan`` must
        be a canonical Filter*/Project* chain over one unrestricted
        Scan; candidates must still be materialized, retain every
        column the query outputs or its residual conjuncts read, and
        satisfy ``subsumes(resident pred, query pred)`` under the
        table schema.  Smallest resident entry wins (cheapest re-read).

        Returns ``(strict key, SubsumptionMeta, residual pred)`` or
        None.  The caller resumes from ``CachedScan(strict)`` plus the
        residual conjuncts instead of recomputing from the base table —
        reuse WITHOUT an exact-fingerprint match.
        """
        if not self._resident_meta:
            return None
        chain = linear_scan_chain(L.as_node(plan))
        if chain is None:
            return None
        scan, pred = chain
        if scan.parts is not None or scan.table not in self.catalog:
            return None
        schema = self.catalog[scan.table].schema
        out_cols = set(plan.schema.names)
        qkey = E.canonical(pred)
        best = None
        for strict, meta in self._resident_meta.items():
            if meta.table != scan.table:
                continue
            if E.canonical(meta.pred) == qkey:
                # exact predicate match: the optimizer's resident
                # re-pricing path owns it (ψ-structural matching +
                # explain's cache_hit accounting) — subsumption only
                # claims STRICTLY weaker residents
                continue
            if not self._ce_cache.contains(strict):
                continue
            resid = subsumption_residual(meta.pred, pred, schema)
            if resid is None:
                continue
            if not (out_cols | E.columns_of(resid)) <= meta.cols:
                continue
            entry = self._ce_cache.entry(strict)
            nbytes = entry.nbytes if entry is not None else 0
            if best is None or nbytes < best[0]:
                best = (nbytes, strict, meta, resid)
        if best is None:
            return None
        return best[1], best[2], best[3]

    def run_one(self, plan: L.Node,
                ctx: Optional[ExecContext] = None) -> QueryResult:
        plan = L.as_node(plan)
        ctx = ctx or self._fresh_ctx()
        t0 = time.perf_counter()
        table = execute(plan, ctx)
        jax.block_until_ready(list(table.columns.values()))
        return QueryResult(table, time.perf_counter() - t0, plan)

    # -- graceful degradation (PR 6) ----------------------------------------
    # route overrides per ladder rung: Pallas kernel → fused-XLA →
    # eager per-operator.  The eager rung also turns off deferred sync,
    # so estimate-overflow/OOM pressure ends at per-operator exact
    # sizing; the kernel_launch fault point is only checked on fused
    # dispatch, so the bottom rung cannot re-fire it.
    _LADDER = (
        ("pallas", {}),
        ("fused-xla", dict(use_pallas_filter=False)),
        ("eager", dict(use_pallas_filter=False, fuse=False,
                       defer_sync=False)),
    )

    def _backoff(self, attempt: int) -> None:
        """Exponential backoff before retry ``attempt+1`` (attempt is
        1-based); base 0 disables sleeping, ``self._sleep`` is
        injectable for deterministic tests."""
        res = self.resilience
        base = float(res.backoff_base_s)
        if base > 0.0:
            self._sleep(base * res.backoff_multiplier ** (attempt - 1))

    def run_one_resilient(self, plan: L.Node, ctx: ExecContext, *,
                          query: int = 0,
                          events: Optional[list] = None) -> QueryResult:
        """``run_one`` under the degradation ladder: transient faults
        retry in place (a fresh draw from the seeded stream), kernel
        dispatch failures step the route down one rung, attempts are
        bounded by ``resilience.max_attempts`` with exponential backoff
        between them.  Every step is logged into ``events`` (the window
        report / failed-handle explain).  ``CEMaterializationError``
        propagates untouched — the service handles it by rerunning the
        consumer on its unshared residual plan."""
        from dataclasses import replace as _dc_replace

        from .physical import CEMaterializationError

        res = self.resilience
        if res is None or not res.degrade:
            return self.run_one(plan, ctx)
        events = events if events is not None else []
        # start at the rung the context is actually configured for, so
        # "degrade one level" always changes something
        level = 0
        if not ctx.use_pallas_filter:
            level = 1
            if not ctx.fuse and not ctx.defer_sync:
                level = 2
        max_attempts = max(1, int(res.max_attempts))
        last_exc: Optional[BaseException] = None
        for attempt in range(1, max_attempts + 1):
            name, over = self._LADDER[level]
            cur = _dc_replace(ctx, **over) if over else ctx
            # the bottom rung must run per-operator even when the plan
            # arrived pre-fused (the rewriter fuses residuals itself)
            cur_plan = unfuse_plan(plan) if name == "eager" else plan
            try:
                return self.run_one(cur_plan, cur)
            except CEMaterializationError:
                raise
            except Exception as exc:
                last_exc = exc
                transient = (isinstance(exc, InjectedFault)
                             and exc.point != "kernel_launch")
                if transient:
                    # e.g. a failed H2D transfer: the operation is
                    # expected to succeed on a later attempt — same rung
                    action = "retry"
                elif level + 1 < len(self._LADDER):
                    action = "degrade"
                    level += 1
                else:
                    # eager bottom rung failed on a real error: done
                    events.append(DegradationEvent(
                        query=query, attempt=attempt, action="give-up",
                        level=name, error=repr(exc)))
                    raise
                events.append(DegradationEvent(
                    query=query, attempt=attempt, action=action,
                    level=self._LADDER[level][0], error=repr(exc)))
                if attempt < max_attempts:
                    self._backoff(attempt)
        raise last_exc

    def run_batch(
        self,
        plans: Sequence[L.Node],
        *,
        mqo: Optional[bool] = None,
        k: Optional[int] = None,
        budget_bytes: Optional[int] = None,
        locally_optimize: Optional[bool] = None,
    ) -> BatchResult:
        """Execute a batch of queries, with or without worksharing.

        The one-shot path is a *pre-closed* QueryService window, so it
        shares the online front-end's machinery bit for bit.  ``mqo`` /
        ``k`` / ``locally_optimize`` default to ``config.mqo``
        (``enabled`` / ``k`` / ``locally_optimize``); pass a value to
        override for this batch only.

        ``budget_bytes`` overrides the *planning* budget (MCKP
        capacity) for this batch only; actual admission is always
        enforced by the session-lifetime MemoryManager at the session
        budget.  A zero planning budget also disables cross-batch
        resident reuse — it is the "no caching at all" baseline.
        """
        if self._oneshot is None:
            self._oneshot = QueryService(self)
        return self._oneshot.run_closed(
            plans, mqo=mqo, k=k, budget_bytes=budget_bytes,
            locally_optimize=locally_optimize)

    # -- naive full-input caching (the paper's "FC" baseline) --------------
    def run_batch_fullcache(self, plans: Sequence[L.Node],
                            budget_bytes: Optional[int] = None
                            ) -> BatchResult:
        """Cache the entire input relations on first touch (§6.3 'FC')."""
        from ..core.fingerprint import fingerprint
        from .canonical import canonicalize_plan

        plans = [optimize_single(canonicalize_plan(p)) for p in plans]
        budget = budget_bytes if budget_bytes is not None else self.budget
        cache = CacheManager(budget, spill_fn=_spill_to_host,
                             unspill_fn=_unspill)
        ctx = self._fresh_ctx(cache)

        # rewrite every Scan into CachedScan of the full relation
        def rewrite(node: L.Node) -> L.Node:
            if isinstance(node, L.Scan):
                psi = fingerprint(node)
                if psi not in ctx.cache_plans:
                    ctx.cache_plans[psi] = L.Cache(child=node, psi=psi)
                return L.CachedScan(psi=psi, _schema=node.schema,
                                    source_label=node.label)
            if not node.children:
                return node
            return node.with_children(
                tuple(rewrite(c) for c in node.children))

        rewritten = [rewrite(p) for p in plans]
        t0 = time.perf_counter()
        results = [self.run_one(p, ctx) for p in rewritten]
        return BatchResult(results, time.perf_counter() - t0,
                           cache_report=cache.report(), metrics=ctx.metrics)
