"""The "SparkSQL Server" (paper §5): a centralized session that
accumulates client queries, runs the multi-query optimizer over the
batch, and executes cache plans + rewritten queries on the cluster.

Memory (PR 2, see ROADMAP "Memory hierarchy"): the session owns ONE
budget-aware :class:`~repro.core.memory.MemoryManager`; the CE cache
and the device scan cache are pools of it, CEs are retained across
batches (``retain_across_batches``), and the next batch's MCKP
re-prices still-resident CEs as zero-weight already-paid items.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from ..core.cache import CacheManager
from ..core.memory import MemoryManager
from ..core.optimizer import MultiQueryOptimizer, OptimizedBatch
from . import logical as L
from .physical import ExecContext, ExecMetrics, TableStorage, execute
from .rewriter import RelationalRewriter, make_ce_transform
from .rules import optimize_single
from .schema import Table
from .stats import RelationalCostModel, StatsRegistry, build_table_stats


@dataclass
class QueryResult:
    table: Table
    seconds: float
    plan: L.Node


@dataclass
class BatchResult:
    results: List[QueryResult]
    total_seconds: float
    optimize_seconds: float = 0.0
    mqo: Optional[OptimizedBatch] = None
    cache_report: dict = field(default_factory=dict)
    metrics: Optional[ExecMetrics] = None

    @property
    def per_query_seconds(self) -> List[float]:
        return [r.seconds for r in self.results]


def _spill_to_host(table: Table) -> Table:
    return Table(table.schema,
                 {n: np.asarray(a) for n, a in table.columns.items()},
                 table.nrows)


def _unspill(table: Table) -> Table:
    import jax.numpy as jnp

    return Table(table.schema,
                 {n: jnp.asarray(a) for n, a in table.columns.items()},
                 table.nrows)


class Session:
    """Catalog + stats + cache + MQO — the paper's prototype server."""

    def __init__(self, budget_bytes: int = 1 << 30,
                 sharding: Optional[jax.sharding.Sharding] = None,
                 disk_latency_per_byte: float = 0.0,
                 fuse: bool = True,
                 defer_sync: bool = True,
                 use_scan_cache: bool = True,
                 policy: str = "lru",
                 host_budget_bytes: Optional[int] = None,
                 retain_across_batches: bool = True):
        self.catalog: Dict[str, TableStorage] = {}
        self.stats = StatsRegistry()
        self.budget = int(budget_bytes)
        self.sharding = sharding
        self.disk_latency_per_byte = disk_latency_per_byte
        self.cost_model = RelationalCostModel(self.stats)
        # execution-path knobs (fuse=False, defer_sync=False,
        # use_scan_cache=False reproduces the seed eager executor)
        self.fuse = fuse
        self.defer_sync = defer_sync
        self.use_scan_cache = use_scan_cache
        # One budget-aware memory hierarchy for everything the session
        # materializes on device (see core.memory): the CE cache spills
        # device -> host -> drop; evicted scan columns just drop (their
        # source host arrays still live in the catalog).  The host tier
        # is bounded too (default 4x the device budget) so a long-lived
        # session with retention cannot grow host RAM without limit.
        self.retain_across_batches = retain_across_batches
        if host_budget_bytes is None:
            host_budget_bytes = 4 * self.budget
        self.memory = MemoryManager(self.budget,
                                    host_budget=host_budget_bytes,
                                    policy=policy)
        self._scan_pool = self.memory.pool("scan")
        self._ce_cache = CacheManager(
            self.budget, spill_fn=_spill_to_host, unspill_fn=_unspill,
            manager=self.memory, pool="ce")
        # psi -> strict content fingerprint of the covering tree that
        # was materialized, retained so stale residents (same loose psi,
        # different covering content) are detected across batches.
        # Cache PLANS need no retention: rewrite_batch regenerates a
        # fresh, intra-batch-consistent plan for every selected CE.
        self._resident_strict: Dict[bytes, bytes] = {}

    # -- catalog management -------------------------------------------------
    def register(self, storage: TableStorage,
                 columnar_for_stats: Optional[Dict[str, np.ndarray]] = None):
        # re-registering a name must not serve the old table's device
        # buffers from the scan cache (keys lead with the table name) ...
        self._scan_pool.invalidate(lambda k: k[0] == storage.name)
        # ... and any retained CE content derived from the old data is
        # stale too (CE plans can join across tables — drop them all)
        if storage.name in self.catalog:
            self._ce_cache.clear()
            self._resident_strict.clear()
        self.catalog[storage.name] = storage
        cols = storage.columnar if storage.columnar is not None \
            else columnar_for_stats
        assert cols is not None, "stats need typed columns (pre-processing)"
        self.stats.register(
            storage.name,
            build_table_stats(cols, storage.nrows, storage.schema))

    def table(self, name: str) -> L.Scan:
        st = self.catalog[name]
        return L.scan(name, st.schema, st.fmt)

    # -- execution ------------------------------------------------------------
    def _fresh_ctx(self, cache: Optional[CacheManager] = None) -> ExecContext:
        return ExecContext(
            catalog=self.catalog, cache=cache,
            sharding=self.sharding,
            disk_latency_per_byte=self.disk_latency_per_byte,
            fuse=self.fuse,
            defer_sync=self.defer_sync,
            cost_model=self.cost_model,
            scan_cache=self._scan_pool if self.use_scan_cache else None)

    def clear_scan_cache(self) -> None:
        """Drop memoized device scan buffers (e.g. after data changes)."""
        self._scan_pool.clear()

    def run_one(self, plan: L.Node,
                ctx: Optional[ExecContext] = None) -> QueryResult:
        ctx = ctx or self._fresh_ctx()
        t0 = time.perf_counter()
        table = execute(plan, ctx)
        jax.block_until_ready(list(table.columns.values()))
        return QueryResult(table, time.perf_counter() - t0, plan)

    def run_batch(
        self,
        plans: Sequence[L.Node],
        *,
        mqo: bool = True,
        k: int = 2,
        budget_bytes: Optional[int] = None,
        locally_optimize: bool = True,
    ) -> BatchResult:
        """Execute a batch of queries, with or without worksharing.

        ``budget_bytes`` overrides the *planning* budget (MCKP
        capacity) for this batch only; actual admission is always
        enforced by the session-lifetime MemoryManager at the session
        budget.  A zero planning budget also disables cross-batch
        resident reuse — it is the "no caching at all" baseline.
        """
        if locally_optimize:
            plans = [optimize_single(p) for p in plans]

        if not mqo:
            ctx = self._fresh_ctx()
            t0 = time.perf_counter()
            results = [self.run_one(p, ctx) for p in plans]
            return BatchResult(results, time.perf_counter() - t0,
                               metrics=ctx.metrics)

        budget = budget_bytes if budget_bytes is not None else self.budget
        optimizer = MultiQueryOptimizer(
            cost_model=self.cost_model,
            rewriter=RelationalRewriter(fuse_residuals=self.fuse),
            budget_bytes=budget,
            k=k,
            ce_transform=make_ce_transform(),
        )
        if not self.retain_across_batches:
            self._ce_cache.clear()
            self._resident_strict.clear()
        else:
            # prune metadata for entries the hierarchy has dropped —
            # this dict must not grow with the workload's history
            for psi in [psi for psi in self._resident_strict
                        if not self._ce_cache.contains(psi)]:
                del self._resident_strict[psi]
        resident = {} if budget <= 0 else dict(self._resident_strict)
        optimized = optimizer.optimize(list(plans), resident=resident)

        cache = self._ce_cache
        # a selected CE whose loose psi collides with a retained entry
        # of DIFFERENT covering content must not read the stale bytes
        for ce in optimized.rewritten.ces:
            sfp = ce.strict_psi()        # memoized on the CE
            if self._resident_strict.get(ce.psi, sfp) != sfp:
                cache.evict(ce.psi)
            self._resident_strict[ce.psi] = sfp
        ctx = self._fresh_ctx(cache)
        ctx.cache_plans = dict(optimized.rewritten.cache_plans)
        # benefit-per-byte eviction ranks entries by the cost model's
        # savings estimate (Eq. 3 value at admission time)
        ctx.cache_values = {ce.psi: max(float(ce.value), 0.0)
                            for ce in optimized.rewritten.ces}

        t0 = time.perf_counter()
        results = [self.run_one(p, ctx) for p in optimized.rewritten.plans]
        total = time.perf_counter() - t0
        return BatchResult(
            results, total,
            optimize_seconds=optimized.report.optimize_seconds,
            mqo=optimized,
            cache_report=cache.report(),
            metrics=ctx.metrics,
        )

    # -- naive full-input caching (the paper's "FC" baseline) --------------
    def run_batch_fullcache(self, plans: Sequence[L.Node],
                            budget_bytes: Optional[int] = None
                            ) -> BatchResult:
        """Cache the entire input relations on first touch (§6.3 'FC')."""
        from ..core.fingerprint import fingerprint

        plans = [optimize_single(p) for p in plans]
        budget = budget_bytes if budget_bytes is not None else self.budget
        cache = CacheManager(budget, spill_fn=_spill_to_host,
                             unspill_fn=_unspill)
        ctx = self._fresh_ctx(cache)

        # rewrite every Scan into CachedScan of the full relation
        def rewrite(node: L.Node) -> L.Node:
            if isinstance(node, L.Scan):
                psi = fingerprint(node)
                if psi not in ctx.cache_plans:
                    ctx.cache_plans[psi] = L.Cache(child=node, psi=psi)
                return L.CachedScan(psi=psi, _schema=node.schema,
                                    source_label=node.label)
            if not node.children:
                return node
            return node.with_children(
                tuple(rewrite(c) for c in node.children))

        rewritten = [rewrite(p) for p in plans]
        t0 = time.perf_counter()
        results = [self.run_one(p, ctx) for p in rewritten]
        return BatchResult(results, time.perf_counter() - t0,
                           cache_report=cache.report(), metrics=ctx.metrics)
