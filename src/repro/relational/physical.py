"""Physical execution of logical plans.

The Spark analog: every operator materializes a fixed-shape distributed
columnar relation (padded to a power-of-two capacity so jit caches hit
across queries).  Orchestration is host-side Python — exactly like a
Spark driver launching stages — while each operator body is a jitted
JAX function that runs SPMD when the arrays carry a NamedSharding.

Two execution paths (see ROADMAP.md "Execution paths"):

  * **eager** — one jitted call per operator, host-synchronized row
    counts after every data-dependent-shape operator (seed behavior;
    ``ExecContext(fuse=False, defer_sync=False, scan_cache=None)``);
  * **fused** (default) — ``relational.fuse`` collapses leaf→Filter*→
    Project chains into single-dispatch :class:`FusedPipeline` nodes, a
    device scan cache memoizes padded device columns across queries,
    and cardinality-estimate-driven output capacities defer the host
    sync (``int(count)``) until after the pipeline has dispatched,
    recompacting only on estimate overflow.

Storage formats (the paper's CSV vs Parquet axis):
  * ``csv``      — the table lives on "disk" (host memory) as one
    fixed-width UTF-8 byte matrix; a scan must move the WHOLE row bytes
    to the device and parse the needed fields with vectorized digit
    arithmetic (reproducing CSV parse/typecast cost).
  * ``columnar`` — typed host arrays per column; a scan moves only the
    needed columns (Parquet-analog column pruning).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.cache import CacheManager
from ..core.costmodel import CalibrationSample
from ..core.faults import DegradationEvent
from ..core.memory import MemoryPool
from ..core.telemetry import NOOP_SPAN
from . import expr as E
from . import logical as L
from .canonical import subsumes as _subsumes
from .fuse import FusedPipeline, fuse_plan
from .partition import (PartitionInfo, PartitionedCePlan,
                        pid_presence_from_mask, prune_parts,
                        restrict_to_parts)
from .schema import Schema, Table, empty_like, next_pow2

I32_SENTINEL = np.int32(2**31 - 1)


class CEMaterializationError(RuntimeError):
    """A shared covering relation failed to materialize.  Raised to
    every consumer of the poisoned ψ (the first failure marks it in
    ``ctx.failed_ces``) so the service can rerun each consumer on its
    unshared residual plan instead of letting one bad CE take down the
    whole window."""

    def __init__(self, psi: bytes, cause: Optional[BaseException] = None):
        self.psi = psi
        self.cause = cause
        why = f": {cause!r}" if cause is not None else ""
        super().__init__(
            f"covering relation ψ={psi.hex()[:12]} failed to "
            f"materialize{why}")

# deferred-sync capacity estimates get this much slack before the
# overflow-recompact path triggers (estimation error is one-sided cheap:
# undershoot costs a recompact, overshoot only pads the output)
EST_HEADROOM = 1.25


# ---------------------------------------------------------------------------
# host-side storage ("disk")
# ---------------------------------------------------------------------------
@dataclass
class TableStorage:
    name: str
    schema: Schema
    nrows: int
    fmt: str                      # "csv" | "columnar"
    columnar: Optional[Dict[str, np.ndarray]] = None
    csv_bytes: Optional[np.ndarray] = None        # (nrows, row_csv_bytes) u8
    # horizontal partition layout (relational.partition): when set, rows
    # are re-clustered so each partition is a contiguous range, scans go
    # through per-partition device cache entries, and filter predicates
    # prune partitions before scanning
    partitions: Optional[PartitionInfo] = None

    @property
    def disk_bytes(self) -> int:
        if self.fmt == "csv":
            return int(self.csv_bytes.size)
        return int(sum(a.nbytes for a in self.columnar.values()))


@dataclass
class ExecMetrics:
    bytes_read_disk: int = 0
    bytes_parsed: int = 0
    bytes_cached_read: int = 0
    bytes_scan_cache_read: int = 0
    rows_processed: int = 0
    # plan-shape compile cache: hits reuse a jitted fused pipeline keyed
    # by canonical plan shape (literals slotted out); misses traced one
    trace_hits: int = 0
    trace_misses: int = 0
    # window batching: shared dispatches and the queries they covered
    batched_dispatches: int = 0
    batched_queries: int = 0
    # pid bitset pool (PR 8): resident bitsets used by lookups, the
    # partitions they pruned beyond statistics, and new recordings
    pid_hits: int = 0
    pid_pruned_parts: int = 0
    pid_records: int = 0
    op_seconds: Dict[str, float] = field(default_factory=dict)

    def add_time(self, op: str, dt: float):
        self.op_seconds[op] = self.op_seconds.get(op, 0.0) + dt


@dataclass
class ExecContext:
    catalog: Dict[str, TableStorage]
    cache: Optional[CacheManager] = None
    cache_plans: Dict[bytes, L.Node] = field(default_factory=dict)
    # psi -> cost-model savings estimate (Eq. 3 value), forwarded to the
    # memory manager at materialization time so benefit-per-byte
    # eviction can rank CE entries
    cache_values: Dict[bytes, float] = field(default_factory=dict)
    metrics: ExecMetrics = field(default_factory=ExecMetrics)
    # Optional sharding applied to row-dim of loaded columns.
    sharding: Optional[jax.sharding.Sharding] = None
    # emulate slow disk: per-byte sleep (used by benchmarks to model I/O)
    disk_latency_per_byte: float = 0.0
    # route numeric predicates through the Pallas filter-scan kernel
    # (TPU target; interpret mode on CPU — used by tests)
    use_pallas_filter: bool = False
    # collapse Scan→Filter*→Project chains into single-dispatch
    # FusedPipeline nodes (see relational.fuse)
    fuse: bool = True
    # device scan cache: (table, column, capacity, sharding) -> padded
    # device array, shared across queries/batches.  Either a budgeted
    # MemoryPool (Session default — evictable under the session-wide
    # device budget) or a raw dict (unbounded; kept for tests and
    # standalone ExecContexts).
    scan_cache: Optional[object] = None
    # cardinality estimator (duck-typed RelationalCostModel) enabling
    # deferred host synchronization: output capacities are picked from
    # estimates so operator pipelines dispatch without a blocking
    # int(count) per operator; the count validates afterwards and a
    # recompact runs only on estimate overflow
    cost_model: Optional[object] = None
    defer_sync: bool = True
    # partition pruning: fused pipelines over partitioned tables skip
    # partitions whose statistics refute the predicate (conservative —
    # disable to force the unpruned path, e.g. for bit-identity tests)
    prune: bool = True
    # plan-shape compile cache: route fused filters through SLOTTED
    # predicate programs (literals hoisted into operand arrays) so
    # recurring templates with fresh constants never re-trace; disable
    # to force the legacy literal-keyed jit path
    shape_cache: bool = True
    # strict cache key -> PartitionedCePlan for every partition-grained
    # CE this window selected: reads compose resident partitions from
    # the cache with per-partition recomputation of the cold ones
    partitioned_ces: Dict[bytes, PartitionedCePlan] = \
        field(default_factory=dict)
    # window-scoped memo of recomputed NON-admitted partitions: like a
    # whole-CE materialization, a cold partition is computed once per
    # window and shared by every consumer — but unlike admitted
    # entries it dies with the window's context instead of occupying
    # the budgeted cache.  Pinning is bounded by ONE device budget
    # (see _memo_put) — the same order as any operator's transient
    # output; beyond that the memo degrades to recompute-per-read
    # instead of holding unbounded device bytes the MCKP rejected.
    ce_part_memo: Dict[tuple, "Table"] = field(default_factory=dict)
    ce_part_memo_bytes: int = 0
    # optional core.faults.FaultInjector — the scan_h2d / kernel_launch /
    # ce_admission points fire through ctx.check_fault(...)
    faults: Optional[object] = None
    # strict keys of CEs whose materialization failed this window:
    # consumers of a poisoned CE fail fast (CEMaterializationError) so
    # the service can rerun them on their unshared residual plans
    failed_ces: set = field(default_factory=set)
    # core.memory.PidPool (or None): partition-ID bitsets recorded as a
    # side effect of fused execution and intersected on later lookups
    # to prune by observed history on top of the stats pruner
    pid_cache: Optional[object] = None
    # (table, canonical pred) -> partitions the pid intersection pruned
    # BEYOND statistics this window (read by service explain())
    pid_prune_log: Dict[tuple, int] = field(default_factory=dict)
    # DegradationEvents raised below the service layer (a failed pid
    # bitset read degrades to stats-only pruning here instead of
    # surfacing — a pid hit is an optimization, never a failure domain)
    degradations: list = field(default_factory=list)
    # optional relational.observe.Telemetry (PR 9): calibration samples
    # on CE materializations / cached reads, spans on H2D + dispatch
    # when tracing is enabled.  None for standalone contexts.
    telemetry: Optional[object] = None

    def check_fault(self, point: str, key=None) -> None:
        if self.faults is not None:
            self.faults.check(point, key=key)

    def span(self, name: str, **attrs):
        """A lifecycle span when tracing is on; the shared no-op
        context manager otherwise (zero allocations)."""
        tel = self.telemetry
        if tel is not None and tel.tracer.enabled:
            return tel.tracer.span(name, **attrs)
        return NOOP_SPAN

    def _memo_put(self, key: tuple, table: "Table") -> bool:
        allowance = float("inf")
        manager = getattr(self.cache, "manager", None) \
            if self.cache is not None else None
        if manager is not None:
            allowance = manager.device_budget
        if self.ce_part_memo_bytes + table.nbytes <= allowance:
            self.ce_part_memo[key] = table
            self.ce_part_memo_bytes += table.nbytes
            return True
        return False

    def _memo_drop(self, key: tuple) -> None:
        t = self.ce_part_memo.pop(key, None)
        if t is not None:
            self.ce_part_memo_bytes -= t.nbytes

    def estimate(self, kind: str, *args) -> Optional[int]:
        """Cardinality estimate for deferred sync; None -> eager sync."""
        if not self.defer_sync or self.cost_model is None:
            return None
        fn = getattr(self.cost_model, f"{kind}_estimate", None)
        if fn is None:
            return None
        return int(fn(*args))

    @classmethod
    def from_exec_config(cls, catalog: Dict[str, "TableStorage"], cfg,
                         *, cache: Optional[CacheManager] = None,
                         cost_model: Optional[object] = None,
                         scan_cache: Optional[object] = None,
                         pid_cache: Optional[object] = None
                         ) -> "ExecContext":
        """Build a context from anything shaped like an
        ``relational.service.ExecutionConfig`` (a Session mirrors the
        same attributes) — the single place execution-path knobs are
        translated into a context."""
        return cls(
            catalog=catalog, cache=cache,
            sharding=getattr(cfg, "sharding", None),
            disk_latency_per_byte=getattr(cfg, "disk_latency_per_byte",
                                          0.0),
            use_pallas_filter=getattr(cfg, "use_pallas_filter", False),
            fuse=cfg.fuse,
            defer_sync=cfg.defer_sync,
            prune=getattr(cfg, "prune", True),
            shape_cache=getattr(cfg, "shape_cache", True),
            cost_model=cost_model,
            scan_cache=scan_cache,
            pid_cache=pid_cache,
            faults=getattr(cfg, "fault_injector", None),
            telemetry=getattr(cfg, "_telemetry", None))


# ---------------------------------------------------------------------------
# jitted primitives (cached per static signature)
# ---------------------------------------------------------------------------
_POW10_I = jnp.asarray([10**k for k in range(9, -1, -1)], jnp.int32)
_POW10_F = jnp.asarray([10.0**k for k in range(7, -1, -1)], jnp.float32)


@jax.jit
def _parse_i32(digits: jnp.ndarray) -> jnp.ndarray:
    """(n, 10) uint8 zero-padded decimal digits -> int32."""
    d = digits.astype(jnp.int32) - 48
    return jnp.einsum("nd,d->n", d, _POW10_I,
                      preferred_element_type=jnp.int32)


@jax.jit
def _parse_f32(digits: jnp.ndarray) -> jnp.ndarray:
    """(n, 8) uint8 fractional digits -> float32 in [0, 1)."""
    d = digits.astype(jnp.float32)
    return jnp.einsum("nd,d->n", d - 48.0, _POW10_F) * jnp.float32(1e-8)


def _pred_mask_fn(pred_key, pred: E.Expr, names: Tuple[str, ...]):
    def f(nrows, *cols):
        columns = dict(zip(names, cols))
        mask = E.eval_expr(pred, columns)
        n = cols[0].shape[0]
        mask = mask & (jnp.arange(n) < nrows)
        return mask, jnp.sum(mask.astype(jnp.int32))
    return jax.jit(f)


_FN_CACHE: Dict[tuple, Callable] = {}


def _cached(key, builder):
    fn = _FN_CACHE.get(key)
    if fn is None:
        fn = _FN_CACHE[key] = builder()
    return fn


def _shape_cached(ctx: "ExecContext", key, builder):
    """``_cached`` variant for plan-SHAPE keys (literals slotted out),
    with hit/miss accounting: a miss here is a fresh trace of a fused
    pipeline; a hit means a recurring template reused the jitted fn."""
    fn = _FN_CACHE.get(key)
    if fn is None:
        ctx.metrics.trace_misses += 1
        fn = _FN_CACHE[key] = builder()
    else:
        ctx.metrics.trace_hits += 1
    return fn


@partial(jax.jit, static_argnames=("new_cap",))
def _compact(mask: jnp.ndarray, new_cap: int, *cols):
    """Bring mask-selected rows to the front; slice to new_cap."""
    order = jnp.argsort(~mask, stable=True)
    sel = order[:new_cap]
    return tuple(jnp.take(c, sel, axis=0) for c in cols)


def _compact_nz_impl(mask: jnp.ndarray, new_cap: int, *cols):
    """O(n) compaction via nonzero (vs the argsort in ``_compact``).

    ``nonzero`` returns selected row indices in ascending order — the
    same live rows, in the same order, as the stable argsort of ~mask;
    fill rows (beyond the selected count) simply repeat row 0, which is
    compaction slack every operator already tolerates.  Used on the
    fused/deferred paths; the plain ``_compact`` is kept as the seed
    eager behavior.
    """
    (sel,) = jnp.nonzero(mask, size=new_cap, fill_value=0)
    return tuple(jnp.take(c, sel, axis=0) for c in cols)


_compact_nz = partial(jax.jit, static_argnames=("new_cap",))(
    _compact_nz_impl)
# overflow-recompact variant: donates the mask buffer so the re-dispatch
# can reuse its device memory (meaningful on tpu/gpu; no-op on cpu,
# where jax warns, so the call site gates on backend)
_compact_nz_donated = partial(jax.jit, static_argnames=("new_cap",),
                              donate_argnums=(0,))(_compact_nz_impl)

_DONATE_OK: Optional[bool] = None


def _donate_ok() -> bool:
    global _DONATE_OK
    if _DONATE_OK is None:
        _DONATE_OK = jax.default_backend() in ("tpu", "gpu")
    return _DONATE_OK


def _sort_sentinel(k: jnp.ndarray):
    """Dtype-matched +inf analog for masking padding rows before a sort
    (int32 AND int64 keys get their exact integer max, not a float)."""
    if jnp.issubdtype(k.dtype, jnp.integer):
        return jnp.asarray(jnp.iinfo(k.dtype).max, k.dtype)
    return jnp.asarray(jnp.inf, k.dtype)


@partial(jax.jit, static_argnames=("asc_sentinel",))
def _sort_order(key: jnp.ndarray, nrows, asc_sentinel: bool):
    valid = jnp.arange(key.shape[0]) < nrows
    k = jnp.where(valid, key, _sort_sentinel(key))
    return jnp.argsort(k, stable=True)


@jax.jit
def _join_build(rk: jnp.ndarray, r_nrows):
    masked = jnp.where(jnp.arange(rk.shape[0]) < r_nrows, rk, I32_SENTINEL)
    order = jnp.argsort(masked, stable=True)
    return order, jnp.take(masked, order)


@jax.jit
def _join_probe(lk: jnp.ndarray, rk_sorted: jnp.ndarray, l_nrows):
    valid = jnp.arange(lk.shape[0]) < l_nrows
    keys = jnp.where(valid, lk, I32_SENTINEL)
    lo = jnp.searchsorted(rk_sorted, keys, side="left")
    hi = jnp.searchsorted(rk_sorted, keys, side="right")
    m = jnp.where(valid & (keys != I32_SENTINEL), hi - lo, 0)
    return lo, m, jnp.sum(m)


@partial(jax.jit, static_argnames=("out_cap",))
def _join_expand(lo, m, out_cap):
    starts = jnp.cumsum(m) - m            # exclusive prefix
    li = jnp.repeat(jnp.arange(m.shape[0]), m,
                    total_repeat_length=out_cap)
    inner = jnp.arange(out_cap) - jnp.take(starts, li)
    ri = jnp.take(lo, li) + inner
    return li, ri


@jax.jit
def _agg_seg_ids(nrows, *keys):
    n = keys[0].shape[0]
    valid = jnp.arange(n) < nrows
    sk = [jnp.where(valid, k, _sort_sentinel(k)) for k in keys]
    order = jnp.lexsort(tuple(reversed(sk)))
    sorted_valid = jnp.take(valid, order)
    sorted_keys = [jnp.take(k, order) for k in sk]
    newgrp = jnp.zeros((n,), jnp.bool_).at[0].set(True)
    for k in sorted_keys:
        newgrp = newgrp | (k != jnp.roll(k, 1))
    newgrp = newgrp & sorted_valid
    gid = jnp.cumsum(newgrp.astype(jnp.int32)) - 1
    n_groups = jnp.sum(newgrp)
    return order, gid, sorted_valid, n_groups


# ---------------------------------------------------------------------------
# operator implementations
# ---------------------------------------------------------------------------
def _device_put(arr: np.ndarray, ctx: ExecContext) -> jnp.ndarray:
    ctx.check_fault("scan_h2d")
    with ctx.span("scan.h2d", nbytes=int(arr.nbytes)):
        if ctx.disk_latency_per_byte:
            time.sleep(arr.nbytes * ctx.disk_latency_per_byte)
        if ctx.sharding is not None and arr.ndim >= 1:
            try:
                return jax.device_put(arr, ctx.sharding)
            except ValueError:
                pass
        return jnp.asarray(arr)


def _pad_rows(arr: np.ndarray, cap: int) -> np.ndarray:
    """Zero-pad the row dim to ``cap`` (no copy when already there)."""
    if cap == arr.shape[0]:
        return arr
    pad_shape = (cap - arr.shape[0],) + arr.shape[1:]
    return np.concatenate([arr, np.zeros(pad_shape, arr.dtype)], 0)


def _scan_pool_put(ctx: ExecContext, key: tuple, dev: jnp.ndarray,
                   benefit: float) -> None:
    """Single admission point for the scan pool (whole-table,
    per-partition, and assembled entries all rank under one benefit
    unit system); raw-dict caches (tests) just store."""
    sc = ctx.scan_cache
    if isinstance(sc, MemoryPool):
        nbytes = int(dev.size) * dev.dtype.itemsize
        sc.put(key, dev, nbytes=nbytes, benefit=benefit)
    elif sc is not None:
        sc[key] = dev


def _reread_benefit(ctx: ExecContext, host_nbytes: int) -> float:
    """Benefit of a scan entry: the re-read cost it saves per hit, in
    the SAME units as the CostModel's Eq. 3 values that CE entries
    carry (per-byte columnar io + modeled disk latency), so
    benefit-per-byte eviction ranks the two pools consistently."""
    io = getattr(getattr(ctx.cost_model, "c", None), "io_col", 1e-9)
    return host_nbytes * (io + ctx.disk_latency_per_byte)


def _scan_cached(ctx: ExecContext, key: tuple, host, cap: int,
                 host_nbytes: Optional[int] = None) -> jnp.ndarray:
    """Padded device column, memoized per (table, col, cap, sharding).

    Repeated scans across a batch (and across batches of the same
    Session) skip both the host-side pad copy and the host→device
    transfer — the dominant per-scan cost once plans are compiled.
    ``host`` may be a zero-arg callable building the host array lazily
    (with ``host_nbytes`` supplied for metrics): an expensive host-side
    assembly then only runs on a cache miss.
    """
    sc = ctx.scan_cache
    lazy = callable(host)
    nbytes = host_nbytes if lazy else host.nbytes
    if sc is not None:
        key = key + (cap, str(ctx.sharding))
        hit = sc.get(key)
        if hit is not None:
            ctx.metrics.bytes_scan_cache_read += nbytes
            return hit
    host_arr = host() if lazy else host
    dev = _device_put(_pad_rows(host_arr, cap), ctx)
    ctx.metrics.bytes_read_disk += host_arr.nbytes
    _scan_pool_put(ctx, key, dev, _reread_benefit(ctx, host_arr.nbytes))
    return dev


def _scan_part_cached(ctx: ExecContext, key: tuple,
                      host_slice: np.ndarray) -> jnp.ndarray:
    """UNPADDED device copy of one partition's rows, memoized per
    (table, column/"__csv__", "part", pid).  Partition-grained entries
    are what different prune sets share: a scan pruned to {1, 3} and a
    later one pruned to {3, 5} both reuse partition 3's bytes."""
    sc = ctx.scan_cache
    if sc is not None:
        hit = sc.get(key)
        if hit is not None:
            ctx.metrics.bytes_scan_cache_read += host_slice.nbytes
            return hit
    dev = _device_put(host_slice, ctx)
    ctx.metrics.bytes_read_disk += host_slice.nbytes
    _scan_pool_put(ctx, key, dev, _reread_benefit(ctx, host_slice.nbytes))
    return dev


def _assemble(pieces: list, cap: int, like: jnp.ndarray) -> jnp.ndarray:
    """Concatenate partition arrays and zero-pad the row dim to cap."""
    total = sum(int(p.shape[0]) for p in pieces)
    pad = cap - total
    if pad:
        pieces = pieces + [jnp.zeros((pad,) + like.shape[1:], like.dtype)]
    return pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces, 0)


def _parts_assembled(ctx: ExecContext, st: "TableStorage", colname: str,
                     host_arr: np.ndarray, parts, ranges,
                     cap: int) -> jnp.ndarray:
    """Padded device column assembled from per-partition cache entries,
    with the ASSEMBLY itself memoized per (table, col, parts, cap) —
    repeat scans with the same prune set skip the device concat (the
    PR 1 warm-scan fast path), while the per-partition entries remain
    the shareable source tier for other prune sets.  Assembled entries
    carry a low benefit (rebuilding one is just a concat over resident
    pieces), so benefit-ranked eviction drops them before the pieces."""
    sc = ctx.scan_cache
    akey = (st.name, colname, "asm", tuple(parts), cap)
    if sc is not None:
        hit = sc.get(akey)
        if hit is not None:
            row_bytes = host_arr.nbytes // max(host_arr.shape[0], 1)
            live = sum(hi - lo for lo, hi in ranges)
            ctx.metrics.bytes_scan_cache_read += row_bytes * live
            return hit
    pieces = [_scan_part_cached(ctx, (st.name, colname, "part", p),
                                host_arr[lo:hi])
              for p, (lo, hi) in zip(parts, ranges) if hi > lo]
    arr = _assemble(pieces, cap, pieces[0] if pieces
                    else jnp.asarray(host_arr[:1]))
    if pieces and arr is pieces[0]:
        return arr      # identity assembly: already cached per-part
    # low benefit: rebuilding is one device concat over resident pieces
    nbytes = int(arr.size) * arr.dtype.itemsize
    _scan_pool_put(ctx, akey, arr, benefit=nbytes * 3e-10)
    return arr


def _exec_scan_partitioned(node: L.Scan, st: TableStorage,
                           info: PartitionInfo, ctx: ExecContext,
                           needed: Tuple[str, ...]) -> Table:
    """Scan a partitioned table: only the selected contiguous partition
    ranges are read, through per-partition device cache entries
    (ascending partition id, so the result is the unpruned relation
    with non-selected partitions' rows deleted, order preserved).

    With a multi-device ``ctx.sharding`` the selected ranges are
    assembled host-side and placed with the NamedSharding (rows — and
    hence partitions — spread across the mesh's devices); the assembled
    array is memoized per partition SET, trading cross-prune-set reuse
    for single-placement scans (ROADMAP: sharded-scan caveats).
    """
    parts = node.parts if node.parts is not None else info.all_parts()
    nrows = info.rows_of(parts)
    cap = next_pow2(max(nrows, 1))
    schema = st.schema.select(needed)
    if nrows == 0:       # every partition pruned (or restricted) away
        return Table(schema, empty_like(schema, cap), 0)
    ranges = [info.part_range(p) for p in parts]
    cols: Dict[str, jnp.ndarray] = {}

    def host_assembly(arr: np.ndarray):
        """Lazy host-side concat of the selected ranges (runs only on
        a scan-cache miss — warm sharded scans skip the memcpy) plus
        the live byte count for hit metrics."""
        if len(parts) == info.n_partitions:
            return (lambda: arr), arr.nbytes
        row_bytes = arr.nbytes // max(arr.shape[0], 1)
        live = sum(hi - lo for lo, hi in ranges)
        build = lambda: np.concatenate(
            [arr[lo:hi] for lo, hi in ranges if hi > lo], 0)
        return build, row_bytes * live

    sharded = ctx.sharding is not None
    if st.fmt == "csv":
        if sharded:
            build, live_bytes = host_assembly(st.csv_bytes)
            raw = _scan_cached(ctx, (st.name, "__csv__", parts),
                               build, cap, host_nbytes=live_bytes)
        else:
            raw = _parts_assembled(ctx, st, "__csv__", st.csv_bytes,
                                   parts, ranges, cap)
        offsets = st.schema.csv_offsets()
        for name in needed:
            off, w = offsets[name]
            fieldb = jax.lax.slice_in_dim(raw, off, off + w, axis=1)
            t = st.schema.coltype(name)
            ctx.metrics.bytes_parsed += nrows * w
            if t.kind == "i32":
                cols[name] = _parse_i32(fieldb)
            elif t.kind == "f32":
                cols[name] = _parse_f32(fieldb)
            else:
                cols[name] = fieldb
    else:
        for name in needed:
            src = st.columnar[name]
            if sharded:
                build, live_bytes = host_assembly(src)
                cols[name] = _scan_cached(ctx, (st.name, name, parts),
                                          build, cap,
                                          host_nbytes=live_bytes)
            else:
                cols[name] = _parts_assembled(ctx, st, name, src,
                                              parts, ranges, cap)
    return Table(schema, cols, nrows)


def _exec_scan(node: L.Scan, ctx: ExecContext,
               needed: Tuple[str, ...]) -> Table:
    st = ctx.catalog[node.table]
    if st.partitions is not None and st.partitions.n_partitions > 1:
        return _exec_scan_partitioned(node, st, st.partitions, ctx, needed)
    cap = next_pow2(st.nrows)
    cols: Dict[str, jnp.ndarray] = {}
    if st.fmt == "csv":
        # must read the WHOLE row bytes (CSV is row-oriented); only the
        # raw byte matrix is memoized — the parse/typecast still runs
        # per scan (it is the CSV format's intrinsic cost, and what the
        # paper's covering-expression cache exists to avoid)
        raw = _scan_cached(ctx, (st.name, "__csv__"), st.csv_bytes, cap)
        offsets = st.schema.csv_offsets()
        for name in needed:
            off, w = offsets[name]
            fieldb = jax.lax.slice_in_dim(raw, off, off + w, axis=1)
            t = st.schema.coltype(name)
            ctx.metrics.bytes_parsed += st.nrows * w
            if t.kind == "i32":
                cols[name] = _parse_i32(fieldb)
            elif t.kind == "f32":
                cols[name] = _parse_f32(fieldb)
            else:
                cols[name] = fieldb
    else:
        for name in needed:
            cols[name] = _scan_cached(ctx, (st.name, name),
                                      st.columnar[name], cap)
    schema = st.schema.select(needed)
    return Table(schema, cols, st.nrows)


def _est_cap(est: int, upper: int) -> int:
    """Power-of-two output capacity from a cardinality estimate."""
    cap = next_pow2(max(int(est * EST_HEADROOM), 1))
    return max(1, min(cap, next_pow2(max(upper, 1))))


def _deferred_dispatch(dispatch, est: int, upper: int, count,
                       final_dispatch=None):
    """The deferred-sync pattern, shared by filter/join/aggregate and
    the fused pipeline: dispatch at the estimate-sized capacity BEFORE
    the host reads the true count, validate, and re-dispatch at the
    exact size only on estimate overflow.  ``upper`` bounds the
    *speculative* allocation (an overestimate must never allocate more
    than the operator could legitimately produce — or, for joins, a
    sane multiple of its inputs); the overflow re-dispatch uses the
    true count, which by then is known to be a real requirement.

    A large OVERestimate is also re-dispatched at the tight size (one
    pow2 step of slack is tolerated): the padded buffer would otherwise
    outlive the operator — returned as a query result or, worse,
    admitted to the CE cache at its padded nbytes, evicting entries the
    knapsack believed would fit.

    ``final_dispatch``, when given, runs the overflow/tighten re-dispatch
    instead of ``dispatch`` — the fused path passes a buffer-DONATING
    compaction there, since at that point the speculative output and the
    mask are dead and their device memory can be reused.

    Returns (dispatch result, int count).
    """
    cap = _est_cap(est, upper)
    out = dispatch(cap)
    n = int(count)
    tight = next_pow2(max(n, 1))
    if n > cap or cap > 2 * tight:
        out = (final_dispatch or dispatch)(tight)
    return out, n


def _exec_filter(pred: E.Expr, child: Table, ctx: ExecContext) -> Table:
    names = child.schema.names
    mask = count = None
    if ctx.use_pallas_filter:
        mask, count = _try_pallas_filter(pred, child)
    if mask is None:
        key = ("mask", E.canonical(pred), names, child.capacity)
        fn = _cached(key, lambda: _pred_mask_fn(key, pred, names))
        mask, count = fn(jnp.int32(child.nrows),
                         *[child.columns[n] for n in names])
    cols = [child.columns[n] for n in names]
    est = ctx.estimate("filter", pred, child.nrows)
    if est is not None:
        out, count = _deferred_dispatch(
            lambda cap: _compact_nz(mask, cap, *cols),
            est, child.capacity, count)
    else:
        count = int(count)
        out = _compact(mask, next_pow2(max(count, 1)), *cols)
    ctx.metrics.rows_processed += child.nrows
    return Table(child.schema, dict(zip(names, out)), count)


def _exec_join(node: L.Join, left: Table, right: Table,
               ctx: ExecContext) -> Table:
    assert len(node.on) == 1, "single-key equi-joins (engine restriction)"
    lc, rc = node.on[0]
    if not left.schema.has(lc):
        lc, rc = rc, lc
    lk, rk = left.columns[lc], right.columns[rc]
    assert lk.dtype == jnp.int32, "join keys must be int32"

    # build side = right (sorted); probe = left.  Padding rows beyond
    # nrows hold stale values (compaction slack) — mask them to the
    # sentinel BEFORE sorting so rk_sorted is genuinely ascending and
    # searchsorted never matches padding.
    order, rk_sorted = _join_build(rk, jnp.int32(right.nrows))
    lo, m, total = _join_probe(lk, rk_sorted, jnp.int32(left.nrows))

    def gather(out_cap: int) -> Dict[str, jnp.ndarray]:
        li, ri = _join_expand(lo, m, out_cap)
        out: Dict[str, jnp.ndarray] = {}
        for n in left.schema.names:
            out[n] = jnp.take(left.columns[n], li, axis=0)
        for n in right.schema.names:
            src = jnp.take(right.columns[n], order, axis=0)
            out[n] = jnp.take(src, ri, axis=0)
        return out

    est = ctx.estimate("join", (lc, rc), left.nrows, right.nrows)
    if est is not None:
        # bound the speculative gather at a small multiple of the
        # larger input — a runaway NDV-based estimate (e.g. join keys
        # with no stats) must not allocate |L|x|R|-sized arrays; a true
        # output beyond the bound just takes the overflow re-gather
        upper = 4 * max(left.nrows, right.nrows, 1)
        cols, total = _deferred_dispatch(gather, est, upper, total)
    else:
        total = int(total)
        cols = gather(next_pow2(max(total, 1)))
    ctx.metrics.rows_processed += left.nrows + right.nrows
    return Table(left.schema.concat(right.schema), cols, total)


_SEG_FNS = {
    "sum": jax.ops.segment_sum,
    "min": jax.ops.segment_min,
    "max": jax.ops.segment_max,
}


def _exec_aggregate(node: L.Aggregate, child: Table,
                    ctx: ExecContext) -> Table:
    n = child.capacity
    keys = [child.columns[g] for g in node.group_by]
    assert all(k.ndim == 1 for k in keys), "group keys must be scalar cols"

    order, gid, sorted_valid, n_groups = _agg_seg_ids(
        jnp.int32(child.nrows), *keys)

    est = ctx.estimate("group", node.group_by, child.nrows)
    cap = 1  # rebound by run_reduce before any trace reads it

    fns = tuple(fn for _, fn, _ in node.aggs)

    def make_reduce():
        def reduce_all(order, gid, sorted_valid, *vals):
            gid_c = jnp.where(sorted_valid, gid, cap)  # padding -> dropped
            outs = []
            for fn_name, v in zip(fns, vals):
                sv = jnp.take(v, order, axis=0)
                if fn_name == "count":
                    o = jax.ops.segment_sum(
                        sorted_valid.astype(jnp.int32), gid_c,
                        num_segments=cap)
                elif fn_name == "mean":
                    s = jax.ops.segment_sum(
                        jnp.where(sorted_valid, sv.astype(jnp.float32), 0.0),
                        gid_c, num_segments=cap)
                    c = jax.ops.segment_sum(
                        sorted_valid.astype(jnp.float32), gid_c,
                        num_segments=cap)
                    o = s / jnp.maximum(c, 1.0)
                elif fn_name in ("min", "max"):
                    big = jnp.asarray(
                        I32_SENTINEL if sv.dtype == jnp.int32 else jnp.inf,
                        sv.dtype)
                    fill = big if fn_name == "min" else (
                        -big if sv.dtype != jnp.int32 else -big - 1)
                    o = _SEG_FNS[fn_name](jnp.where(sorted_valid, sv, fill),
                                          gid_c, num_segments=cap)
                else:
                    o = jax.ops.segment_sum(
                        jnp.where(sorted_valid, sv,
                                  jnp.zeros((), sv.dtype)), gid_c,
                        num_segments=cap)
                outs.append(o)
            # first sorted row index of each group -> representative keys
            first = jax.ops.segment_min(
                jnp.where(sorted_valid, jnp.arange(n), n), gid_c,
                num_segments=cap)
            return tuple(outs), first

        return jax.jit(reduce_all)

    vals = tuple(child.columns[c if c else node.group_by[0]]
                 for _, fn, c in node.aggs)

    def run_reduce(cap_: int):
        nonlocal cap
        cap = cap_   # read by make_reduce's trace below
        reduce_key = ("agg_reduce", fns, cap_, n,
                      tuple(str(v.dtype) for v in vals))
        reduce_all = _cached(reduce_key, make_reduce)
        return reduce_all(order, gid, sorted_valid, *vals)

    if est is not None:
        # deferred sync: size the segment reduction from the NDV
        # estimate and dispatch it before reading the true group count;
        # group ids beyond the capacity are scatter-dropped, so an
        # underestimate only triggers the overflow re-reduce
        (outs, first), n_groups = _deferred_dispatch(
            run_reduce, est, child.nrows, n_groups)
    else:
        n_groups = int(n_groups)
        outs, first = run_reduce(next_pow2(max(n_groups, 1)))

    cols: Dict[str, jnp.ndarray] = {}
    safe_first = jnp.minimum(first, n - 1)
    for g in node.group_by:
        sorted_col = jnp.take(child.columns[g], order, axis=0)
        cols[g] = jnp.take(sorted_col, safe_first, axis=0)
    for (out_name, fn, c), o in zip(node.aggs, outs):
        cols[out_name] = o
    ctx.metrics.rows_processed += child.nrows
    return Table(node.schema, cols, n_groups)


def _sort_fn(key, by_idx: int, in_cap: int, new_cap: int, desc: bool):
    """All sort output columns in ONE jitted call: sentinel-mask the
    key, stable argsort, gather every column through the same order,
    slice to ``new_cap``.  Valid rows sort ahead of the sentinel
    padding, so a slice of ``new_cap >= nrows`` keeps every live row
    (matching the eager path's live-row order bit for bit)."""
    def f(nrows, *cols):
        k = cols[by_idx]
        valid = jnp.arange(in_cap) < nrows
        if desc:
            k = -k
        k = jnp.where(valid, k, _sort_sentinel(k))
        sel = jnp.argsort(k, stable=True)[:new_cap]
        return tuple(jnp.take(c, sel, axis=0) for c in cols)

    return jax.jit(f)


def _exec_sort(node: L.Sort, child: Table, ctx: ExecContext) -> Table:
    names = child.schema.names
    est = ctx.estimate("sort", child.nrows)
    if est is not None:
        # deferred-sync path: the output capacity comes from the cost
        # model's cardinality estimate (exact for sort — cardinality is
        # preserved) instead of carrying the child's full padded
        # capacity forward, and every column is gathered inside one
        # jitted dispatch; the usual overflow guard recompacts if the
        # estimate ever lied
        by_idx = names.index(node.by)

        def dispatch(new_cap: int):
            fkey = ("sort", names, node.by, bool(node.desc),
                    child.capacity, new_cap,
                    str(child.columns[node.by].dtype))
            fn = _cached(fkey, lambda: _sort_fn(
                fkey, by_idx, child.capacity, new_cap, bool(node.desc)))
            return fn(jnp.int32(child.nrows),
                      *[child.columns[n] for n in names])

        outs, _ = _deferred_dispatch(dispatch, est, child.capacity,
                                     child.nrows)
        return Table(child.schema, dict(zip(names, outs)), child.nrows)

    # seed eager path: full-capacity order, one gather per column
    key = child.columns[node.by]
    if node.desc:
        key = jnp.where(jnp.arange(child.capacity) < child.nrows,
                        -key, _sort_sentinel(key))
        order = jnp.argsort(key, stable=True)
    else:
        order = _sort_order(key, jnp.int32(child.nrows), True)
    cols = {n: jnp.take(child.columns[n], order, axis=0)
            for n in child.schema.names}
    return Table(child.schema, cols, child.nrows)


def _union_fn(key, names: Tuple[str, ...], l_cap: int, r_cap: int,
              new_cap: int):
    """All union output columns in ONE jitted call: concat live-row
    masks, O(n) nonzero compaction, every column gathered through the
    same selection (vs the seed's per-column argsort dispatches)."""
    k = len(names)

    def f(l_nrows, r_nrows, *cols):
        mask = jnp.concatenate([jnp.arange(l_cap) < l_nrows,
                                jnp.arange(r_cap) < r_nrows])
        (sel,) = jnp.nonzero(mask, size=new_cap, fill_value=0)
        outs = []
        for lc, rc in zip(cols[:k], cols[k:]):
            merged = jnp.concatenate([lc, rc], axis=0)
            outs.append(jnp.take(merged, sel, axis=0))
        return tuple(outs)

    return jax.jit(f)


def _exec_union(left: Table, right: Table, ctx: ExecContext) -> Table:
    total = left.nrows + right.nrows
    names = left.schema.names
    est = ctx.estimate("union", left.nrows, right.nrows)
    if est is not None:
        # deferred-sync path: output capacity from the sum of the input
        # cardinality estimates, one fused dispatch for every column;
        # the usual overflow guard recompacts if the estimate lied
        def dispatch(new_cap: int):
            key = ("union", names, left.capacity, right.capacity, new_cap)
            fn = _cached(key, lambda: _union_fn(key, names, left.capacity,
                                                right.capacity, new_cap))
            return fn(jnp.int32(left.nrows), jnp.int32(right.nrows),
                      *[left.columns[n] for n in names],
                      *[right.columns[n] for n in names])

        outs, total = _deferred_dispatch(
            dispatch, est, left.capacity + right.capacity, total)
        return Table(left.schema, dict(zip(names, outs)), total)

    # seed eager path: exact-sized per-column argsort compaction
    cap = next_pow2(max(total, 1))
    cols = {}
    for name in names:
        a = left.columns[name][: left.capacity]
        b = right.columns[name][: right.capacity]
        mask = jnp.concatenate([
            jnp.arange(left.capacity) < left.nrows,
            jnp.arange(right.capacity) < right.nrows])
        merged = jnp.concatenate([a, b], axis=0)
        (compacted,) = _compact(mask, cap, merged)
        cols[name] = compacted
    return Table(left.schema, cols, total)


def _try_pallas_filter(pred: E.Expr, child: Table):
    """Route a numeric predicate through the fused filter-scan kernel.
    Returns (mask, count) or (None, None) when unsupported (string
    predicates stay on the XLA path; numeric col-col compares and
    fractional thresholds on integer columns compile — see
    kernels.filter_project.ops.compile_predicate)."""
    from ..kernels.filter_project.ops import compile_predicate, filter_mask

    numeric = tuple(n for n, t in child.schema.fields
                    if t.kind in ("i32", "i64", "f32"))
    try:
        program = compile_predicate(pred, numeric)
    except (ValueError, KeyError):
        return None, None
    cols = tuple(child.columns[n] for n in numeric)
    block = min(2048, child.capacity)
    mask, counts = filter_mask(cols, program, child.nrows, block=block)
    return mask, jnp.sum(counts)


# ---------------------------------------------------------------------------
# multi-device sharded scans: per-shard predicate evaluation
# ---------------------------------------------------------------------------
def _sharded_mask_fn(key, pred: E.Expr, names: Tuple[str, ...],
                     ndims: Tuple[int, ...], mesh, axis: str):
    """Predicate mask per shard under shard_map: each device evaluates
    its local rows (embarrassingly parallel — the fused filter's row
    scan runs on every device at once), the count is one psum, and the
    mask comes back row-sharded for the global compaction that follows
    (compaction is data-dependent-shape and stays in XLA/GSPMD)."""
    from jax.sharding import PartitionSpec as P

    try:
        from jax import shard_map
    except ImportError:  # pragma: no cover - older jax
        from jax.experimental.shard_map import shard_map

    def local(nrows, *cols):
        n_local = cols[0].shape[0]
        base = jax.lax.axis_index(axis) * n_local
        columns = dict(zip(names, cols))
        live = (base + jnp.arange(n_local)) < nrows
        mask = E.eval_expr(pred, columns) & live
        count = jax.lax.psum(jnp.sum(mask.astype(jnp.int32)), axis)
        return mask, count

    in_specs = (P(),) + tuple(
        P(axis) if nd == 1 else P(axis, None) for nd in ndims)
    try:
        fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                       out_specs=(P(axis), P()), check_vma=False)
    except TypeError:  # pragma: no cover - pre-check_vma jax
        fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                       out_specs=(P(axis), P()), check_rep=False)
    return jax.jit(fn)


def _try_shard_map_mask(pred: E.Expr, child: Table, ctx: ExecContext):
    """(mask, count) via per-shard evaluation, or (None, None) when the
    context is not multi-device row-sharded (single-axis NamedSharding
    with the row capacity divisible by the axis size)."""
    sh = ctx.sharding
    if not isinstance(sh, jax.sharding.NamedSharding):
        return None, None
    spec = tuple(sh.spec)
    if not spec or not isinstance(spec[0], str):
        return None, None
    axis = spec[0]
    mesh = sh.mesh
    n_sh = dict(zip(mesh.axis_names, mesh.devices.shape)).get(axis, 1)
    if n_sh <= 1 or child.capacity % n_sh:
        return None, None
    names = child.schema.names
    ndims = tuple(child.columns[n].ndim for n in names)
    key = ("smask", E.canonical(pred), names, child.capacity,
           axis, n_sh, str(sh))
    fn = _cached(key, lambda: _sharded_mask_fn(key, pred, names, ndims,
                                               mesh, axis))
    return fn(jnp.int32(child.nrows), *[child.columns[n] for n in names])


# ---------------------------------------------------------------------------
# fused pipelines (relational.fuse): leaf → Filter* → Project in ONE call
# ---------------------------------------------------------------------------
def _fused_fn(key, pred: E.Expr, in_names: Tuple[str, ...],
              out_cols: Tuple[str, ...], new_cap: int):
    """mask + count + compact + project as a single jitted function."""
    def f(nrows, *cols):
        columns = dict(zip(in_names, cols))
        n = cols[0].shape[0]
        mask = E.eval_expr(pred, columns) & (jnp.arange(n) < nrows)
        count = jnp.sum(mask.astype(jnp.int32))
        (sel,) = jnp.nonzero(mask, size=new_cap, fill_value=0)
        outs = tuple(jnp.take(columns[c], sel, axis=0) for c in out_cols)
        return mask, count, outs
    return jax.jit(f)


def _slot_compile(pred: E.Expr, schema):
    """Slotted compile of ``pred`` over the schema's numeric predicate
    columns.  Returns (program, ivals, fvals, names) or None when the
    predicate falls off the slotted route (string compares, col-col over
    strings, out-of-range consts...)."""
    from ..kernels.filter_project.ops import compile_predicate_slots

    kinds = {n: t.kind for n, t in schema.fields}
    pcols = E.columns_of(pred)
    names = tuple(n for n in schema.names
                  if n in pcols and kinds[n] in ("i32", "i64", "f32"))
    if not names:
        return None
    try:
        program, ivals, fvals = compile_predicate_slots(pred, names, kinds)
    except (ValueError, KeyError):
        return None
    return program, ivals, fvals, names


def _slotted_mask(pred: E.Expr, child: Table, ctx: ExecContext,
                  use_pallas: bool):
    """Per-query mask+count through the SLOTTED program route: the
    jitted fn is keyed by plan shape (literals live in operand arrays),
    so recurring templates with fresh constants never re-trace.  This is
    exactly a batch of one — bit-identical to a window-batched dispatch
    of the same plan.  Returns (mask, count) or (None, None)."""
    from ..kernels.filter_project.ops import filter_mask_batch, pack_consts

    compiled = _slot_compile(pred, child.schema)
    if compiled is None:
        return None, None
    program, ivals, fvals, names = compiled
    ic, fc = pack_consts([ivals], [fvals])
    block = min(2048, child.capacity)
    key = ("slotmask", program, names, 1, child.capacity, block,
           use_pallas)
    fn = _shape_cached(ctx, key, lambda: partial(
        filter_mask_batch, block=block, use_pallas=use_pallas))
    cols = tuple(child.columns[n] for n in names)
    mask, counts = fn(cols, program, jnp.int32(child.nrows), ic, fc)
    return mask[0], jnp.sum(counts)


def _fused_est(src, pred: E.Expr, child: Table, est_rows: Optional[int],
               ctx: ExecContext) -> Optional[int]:
    """The fused pipeline's deferred-sync output-capacity estimate
    (shared verbatim by the per-query and window-batched routes, so a
    batched member sizes its compaction exactly like a solo run)."""
    est = ctx.estimate("filter", pred,
                       est_rows if est_rows is not None else child.nrows)
    if est is not None and est_rows is not None:
        est = min(est, child.nrows)
    if (est is not None and isinstance(src, L.Scan)
            and src.parts is not None):
        # partition-RESTRICTED scan (per-partition CE recompute): the
        # restriction exists because the covering predicate keeps these
        # partitions, so whole-table selectivity applied to partition
        # rows systematically undershoots (range partitioning on the
        # filter column is the worst case: every row passes) — forcing
        # the overflow re-dispatch on the warm recompute path.  Size at
        # the partition input; the overshoot guard recompacts the rare
        # genuinely-selective case.
        est = child.nrows
    if est is not None and isinstance(src, L.CachedScan):
        # residual over a covering relation: condition on the covering
        # plan's selectivity (the CE output already passed the OR of
        # member predicates, so base-table selectivities undershoot)
        cov = ctx.cache_plans.get(src.psi)
        sel_fn = getattr(ctx.cost_model, "plan_selectivity", None)
        if cov is not None and sel_fn is not None:
            est = min(child.nrows, int(est / sel_fn(cov)))
    return est


def _pruned_scan(ctx: ExecContext, src: L.Scan, st: "TableStorage",
                 pred: E.Expr):
    """Resolve the live partitions of a fused scan+filter: the
    conservative stats pruner first, then intersection with resident
    pid bitsets — observed history composes with, never overrides,
    statistics (PR 8).  The deferred-sync capacity estimate stays taken
    over the FULL table (the qualifying rows all live in surviving
    partitions — estimating over the pruned input would undershoot by
    exactly the pruned fraction and force the overflow recompact on the
    hot path), then capped at the pruned input size by the caller.

    This is also the ``pid_pool`` fault point: the bitset read is
    attempted for EVERY fused scan+filter (an unpartitioned table is
    just a one-partition layout whose read trivially finds nothing),
    and any failure in the pid path — injected or real — degrades to
    stats-only pruning with a :class:`DegradationEvent` instead of
    surfacing.  A pid hit is an optimization, never a failure domain.

    Returns ``(resolved src, est_rows, pid_scan)``; ``pid_scan`` is
    ``(table, PartitionInfo, scanned parts)`` when the row mask this
    scan produces is eligible for presence recording (the scan started
    unrestricted, so absent-from-mask == empty-for-pred over the whole
    table), else None.
    """
    info = st.partitions
    partitioned = (ctx.prune and info is not None
                   and info.n_partitions > 1)
    live = prune_parts(pred, info) if partitioned else None
    if ctx.pid_cache is not None:
        try:
            ctx.check_fault("pid_pool", key=src.table)
            if partitioned:
                key = E.canonical(pred)
                live2, hits = ctx.pid_cache.intersect(
                    src.table, key, pred, info.n_partitions, live,
                    implies=lambda p, q, _s=st.schema:
                        _subsumes(p, q, _s))
                ctx.metrics.pid_hits += hits
                dropped = len(live) - len(live2)
                if dropped > 0:
                    ctx.metrics.pid_pruned_parts += dropped
                    # per-(table, pred) the drop count is deterministic
                    # within a window: assign, don't accumulate
                    ctx.pid_prune_log[(src.table, key)] = dropped
                    live = live2
        except Exception as exc:
            ctx.degradations.append(DegradationEvent(
                query=-1, attempt=1, action="degrade",
                level="stats-prune", error=repr(exc),
                detail={"point": "pid_pool", "table": src.table}))
    if not partitioned:
        return src, None, None
    est_rows = None
    if len(live) < info.n_partitions:
        from dataclasses import replace as _dc_replace

        src = _dc_replace(src, parts=tuple(live))
        est_rows = st.nrows
    scanned = src.parts if src.parts is not None else info.all_parts()
    return src, est_rows, (src.table, info, scanned)


def _pid_record(ctx: ExecContext, pid_scan, pred: E.Expr, mask,
                nrows: int) -> None:
    """Record the observed presence bitset for ``(table, pred)`` as a
    side effect of an eligible fused execution.  Record-once: the host
    read of ``mask`` synchronizes the device, so a key already resident
    is skipped before touching the array — warm streams pay nothing
    here.  Failures degrade to not-recording (never to the query)."""
    pool = ctx.pid_cache
    if pool is None or pid_scan is None or mask is None:
        return
    table_name, info, parts = pid_scan
    try:
        key = E.canonical(pred)
        if pool.contains(table_name, key):
            return
        host = np.asarray(mask)[:nrows]
        present = pid_presence_from_mask(host, info, parts)
        pool.record(table_name, key, pred, info.n_partitions, present)
        ctx.metrics.pid_records += 1
    except Exception as exc:
        ctx.degradations.append(DegradationEvent(
            query=-1, attempt=1, action="degrade", level="no-record",
            error=repr(exc),
            detail={"point": "pid_pool", "table": table_name}))


def _exec_fused(node: FusedPipeline, ctx: ExecContext) -> Table:
    # covers the Pallas and fused-XLA routes; the eager per-operator
    # path (the degradation ladder's bottom rung) never dispatches here
    ctx.check_fault("kernel_launch")
    src, pred = node.source, node.pred
    need = set(node.cols) | E.columns_of(pred)
    est_rows = None
    pid_scan = None
    if isinstance(src, L.Scan):
        st = ctx.catalog[src.table]
        if src.parts is None and not isinstance(pred, E.TrueExpr):
            # partition pruning: statistics (then resident pid bitsets)
            # refute the predicate on the skipped partitions, so the
            # scan reads only the surviving contiguous ranges
            src, est_rows, pid_scan = _pruned_scan(ctx, src, st, pred)
        needed = tuple(n for n in src.schema.names if n in need)
        child = _exec_scan(src, ctx, needed)
    else:
        table = _cached_scan_table(src, ctx)
        child = table.select([n for n in src.schema.names
                              if n in need and table.schema.has(n)])

    if isinstance(pred, E.TrueExpr):
        return child.select(node.cols)

    in_names = child.schema.names
    in_cols = [child.columns[n] for n in in_names]
    est = _fused_est(src, pred, child, est_rows, ctx)
    out_schema = node.schema

    mask = count = None
    if ctx.use_pallas_filter:
        # kernel computes mask+count; only the data-dependent-shape
        # compaction stays in XLA (see kernels.filter_project.kernel).
        # Shape-cached slotted program first (no re-trace on fresh
        # literals), legacy literal program as fallback.
        if ctx.shape_cache:
            mask, count = _slotted_mask(pred, child, ctx, use_pallas=True)
        if mask is None:
            mask, count = _try_pallas_filter(pred, child)
    if mask is None:
        # multi-device row sharding: predicate evaluation per shard
        # under shard_map (no communication except the count psum)
        mask, count = _try_shard_map_mask(pred, child, ctx)
    if mask is None and ctx.shape_cache:
        # fused-XLA slotted route: same shape-keyed program, evaluated
        # by the jitted batch oracle instead of the Pallas kernel
        mask, count = _slotted_mask(pred, child, ctx, use_pallas=False)

    def project_compact(new_cap: int):
        return _compact_nz(mask, new_cap,
                           *[child.columns[c] for c in node.cols])

    def final_compact(new_cap: int):
        # overflow/tighten re-dispatch: the mask is dead afterwards, so
        # donate its buffer where the backend supports donation
        if _donate_ok():
            return _compact_nz_donated(
                mask, new_cap, *[child.columns[c] for c in node.cols])
        return project_compact(new_cap)

    if mask is not None:
        if est is not None:
            outs, count = _deferred_dispatch(
                project_compact, est, child.capacity, count,
                final_dispatch=final_compact)
        else:
            count = int(count)
            outs = project_compact(next_pow2(max(count, 1)))
    elif est is not None:
        # single dispatch: mask, count and the projected compaction all
        # come out of one jitted call sized by the estimate
        new_cap = _est_cap(est, child.capacity)
        key = ("fused", E.canonical(pred), in_names, node.cols,
               child.capacity, new_cap)
        fn = _cached(key, lambda: _fused_fn(key, pred, in_names,
                                            node.cols, new_cap))
        mask, count, outs = fn(jnp.int32(child.nrows), *in_cols)
        count = int(count)
        tight = next_pow2(max(count, 1))
        if count > new_cap or new_cap > 2 * tight:
            # estimate overflow (or gross overshoot): recompact exactly
            outs = final_compact(tight)
    else:
        # no estimator: two dispatches, but still no intermediate
        # relation — only the output columns are ever compacted
        key = ("mask", E.canonical(pred), in_names, child.capacity)
        fn = _cached(key, lambda: _pred_mask_fn(key, pred, in_names))
        mask, count = fn(jnp.int32(child.nrows), *in_cols)
        count = int(count)
        outs = project_compact(next_pow2(max(count, 1)))

    _pid_record(ctx, pid_scan, pred, mask, child.nrows)
    ctx.metrics.rows_processed += child.nrows
    return Table(out_schema, dict(zip(node.cols, outs)), count)


# ---------------------------------------------------------------------------
# window-batched execution: same-shape fused pipelines -> ONE dispatch
# ---------------------------------------------------------------------------
@dataclass
class _BatchMember:
    """One window query admitted to a batched dispatch group."""
    pos: int                      # caller's window position
    node: FusedPipeline
    src: L.Node                   # prune-resolved source leaf
    need: frozenset               # scan columns (output + predicate)
    est_rows: Optional[int]       # pre-prune row count for estimation
    program: tuple                # slotted postfix program (the shape)
    ivals: tuple
    fvals: tuple
    pred_names: Tuple[str, ...]   # numeric predicate columns, schema order
    # (table, PartitionInfo, scanned parts) when this member's row mask
    # is eligible for pid-bitset presence recording (see _pruned_scan)
    pid_scan: Optional[tuple] = None


def plan_window_batches(plans, ctx: ExecContext):
    """Group a closed window's plans for batched kernel execution.

    ``plans`` is a sequence of ``(pos, logical plan)`` pairs.  A plan is
    batch-capable when it fuses to a FusedPipeline whose predicate
    compiles to a slotted program; plans sharing (source leaf, program
    shape, predicate columns) — i.e. literal variants of one template
    over one table — land in the same group and will evaluate as ONE
    batched mask dispatch.  Returns ``(n_candidates, groups)`` where
    groups have >= 2 members (singletons stay on the per-query path) and
    the cost model has priced the shared dispatch below per-query ones.
    """
    if not ctx.fuse or not ctx.shape_cache:
        return 0, []
    from dataclasses import replace as _dc_replace

    buckets: Dict[tuple, list] = {}
    n_cand = 0
    for pos, plan in plans:
        node = fuse_plan(L.as_node(plan))
        if not isinstance(node, FusedPipeline):
            continue
        pred = node.pred
        if isinstance(pred, E.TrueExpr):
            continue
        src = node.source
        est_rows = None
        pid_scan = None
        if isinstance(src, L.Scan):
            st = ctx.catalog.get(src.table)
            if st is None:
                continue
            if src.parts is None:
                # resolve pruning (stats + pid bitsets) NOW so the
                # group key reflects the actual scanned ranges (members
                # with different live partition sets must not share a
                # mask dispatch)
                src, est_rows, pid_scan = _pruned_scan(ctx, src, st,
                                                       pred)
            leaf = ("scan", src.table, src.parts, st.fmt)
        elif isinstance(src, L.CachedScan):
            leaf = ("cs", src.psi)
        else:
            continue
        compiled = _slot_compile(pred, src.schema)
        if compiled is None:
            continue
        program, ivals, fvals, pred_names = compiled
        n_cand += 1
        key = (leaf, program, pred_names)
        buckets.setdefault(key, []).append(_BatchMember(
            pos=pos, node=node, src=src,
            need=frozenset(node.cols) | E.columns_of(pred),
            est_rows=est_rows, program=program, ivals=ivals,
            fvals=fvals, pred_names=pred_names, pid_scan=pid_scan))

    groups = []
    wd = getattr(ctx.cost_model, "window_dispatch_cost", None) \
        if ctx.cost_model is not None else None
    for ms in buckets.values():
        if len(ms) < 2:
            continue
        if wd is not None and wd(len(ms), batched=True) >= \
                wd(len(ms), batched=False):
            continue
        groups.append(ms)
    return n_cand, groups


def _prepare_group(members, ctx: ExecContext):
    """Phase one of a group: per-member scans + the ONE batched
    mask/count dispatch (async — nothing here blocks on the device)."""
    from ..kernels.filter_project.ops import filter_mask_batch, pack_consts

    children = []
    for m in members:
        src = m.src
        if isinstance(src, L.Scan):
            needed = tuple(n for n in src.schema.names if n in m.need)
            children.append(_exec_scan(src, ctx, needed))
        else:
            table = _cached_scan_table(src, ctx)
            children.append(table.select(
                [n for n in src.schema.names
                 if n in m.need and table.schema.has(n)]))
    base = children[0]
    for ch in children[1:]:
        if ch.capacity != base.capacity or ch.nrows != base.nrows:
            raise RuntimeError("window-batch group children diverge")
    names = members[0].pred_names
    # predicate columns come from the FIRST member's child — same leaf,
    # same device buffers (scan cache), so no member pays a second scan
    cols = tuple(base.columns[n] for n in names)
    # pad the member dimension to a power of two so realized group
    # sizes bucket into few compile shapes (a serving window closes
    # with whatever arrived — without padding every distinct size
    # recompiles the batch kernel).  Padded rows duplicate member 0's
    # literals; their mask/count rows are never read, and real members'
    # rows are computed independently of them (bit-identical).
    n_pad = next_pow2(len(members))
    fill = [members[0]] * (n_pad - len(members))
    ic, fc = pack_consts([m.ivals for m in members + fill],
                         [m.fvals for m in members + fill])
    block = min(2048, base.capacity)
    use_pallas = ctx.use_pallas_filter
    key = ("slotmask", members[0].program, names, n_pad,
           base.capacity, block, use_pallas)
    fn = _shape_cached(ctx, key, lambda: partial(
        filter_mask_batch, block=block, use_pallas=use_pallas))
    mask, counts = fn(cols, members[0].program, jnp.int32(base.nrows),
                      ic, fc)
    ctx.metrics.batched_dispatches += 1
    ctx.metrics.batched_queries += len(members)
    return children, mask, counts


def _finalize_group(members, prep, ctx: ExecContext):
    """Phase two: blocking count reads + per-member deferred-sync
    compactions (identical sizing to the solo ``_exec_fused`` path, so
    batched results are bit-identical to per-query dispatch)."""
    children, mask, counts = prep
    outs = []
    for q, (m, child) in enumerate(zip(members, children)):
        est = _fused_est(m.src, m.node.pred, child, m.est_rows, ctx)
        mrow = mask[q]
        crow = jnp.sum(counts[q])

        def project_compact(new_cap, mrow=mrow, child=child, m=m):
            return _compact_nz(mrow, new_cap,
                               *[child.columns[c] for c in m.node.cols])

        def final_compact(new_cap, mrow=mrow, child=child, m=m,
                          project_compact=project_compact):
            if _donate_ok():
                return _compact_nz_donated(
                    mrow, new_cap,
                    *[child.columns[c] for c in m.node.cols])
            return project_compact(new_cap)

        if est is not None:
            cols_out, count = _deferred_dispatch(
                project_compact, est, child.capacity, crow,
                final_dispatch=final_compact)
        else:
            count = int(crow)
            cols_out = project_compact(next_pow2(max(count, 1)))
        _pid_record(ctx, m.pid_scan, m.node.pred, mrow, child.nrows)
        ctx.metrics.rows_processed += child.nrows
        outs.append(Table(m.node.schema,
                          dict(zip(m.node.cols, cols_out)), count))
    return outs


def execute_window_batched(groups, ctx: ExecContext):
    """Run planned groups: phase one dispatches EVERY group's scans and
    batched mask kernels before phase two reads any count — JAX's async
    dispatch overlaps the remaining host-side pad/copy work with device
    compute already in flight.  A failing group degrades whole (its
    members return to the caller's per-query path); per-member results
    carry an even split of the group's wall time.

    Returns ``(results {pos: Table}, seconds {pos: float},
    failures {pos: Exception})``.
    """
    results: Dict[int, Table] = {}
    seconds: Dict[int, float] = {}
    failures: Dict[int, Exception] = {}
    with ctx.span("dispatch.batched", n_groups=len(groups),
                  n_queries=sum(len(g) for g in groups)):
        prepped = []
        for g in groups:
            t0 = time.perf_counter()
            try:
                prepped.append((g, _prepare_group(g, ctx),
                                time.perf_counter() - t0))
            except Exception as exc:
                for m in g:
                    failures[m.pos] = exc
        for g, prep, dt0 in prepped:
            t0 = time.perf_counter()
            try:
                with ctx.span("dispatch.batched.finalize",
                              n_members=len(g)):
                    outs = _finalize_group(g, prep, ctx)
                    for t in outs:
                        jax.block_until_ready(list(t.columns.values()))
            except Exception as exc:
                for m in g:
                    failures[m.pos] = exc
                continue
            dt = dt0 + (time.perf_counter() - t0)
            ctx.metrics.add_time("fused", dt)
            per = dt / len(g)
            for m, t in zip(g, outs):
                results[m.pos] = t
                seconds[m.pos] = per
    return results, seconds, failures


# ---------------------------------------------------------------------------
# the interpreter
# ---------------------------------------------------------------------------
def execute(node: L.Node, ctx: ExecContext) -> Table:
    from .stats import required_columns

    node = L.as_node(node)
    if ctx.fuse:
        node = fuse_plan(node)
    req = required_columns(node)
    return _exec(node, ctx, req)


def _exec(node: L.Node, ctx: ExecContext, req) -> Table:
    t0 = time.perf_counter()
    if isinstance(node, FusedPipeline):
        out = _exec_fused(node, ctx)
    elif isinstance(node, L.Scan):
        needed = req.get(id(node), frozenset(node.schema.names))
        ordered = tuple(n for n in node.schema.names if n in needed)
        out = _exec_scan(node, ctx, ordered)
    elif isinstance(node, L.CachedScan):
        out = _exec_cached_scan(node, ctx, req)
    elif isinstance(node, L.Filter):
        child = _exec(node.child, ctx, req)
        out = _exec_filter(node.pred, child, ctx)
    elif isinstance(node, L.Project):
        child = _exec(node.child, ctx, req)
        out = child.select([c for c in node.cols if child.schema.has(c)])
    elif isinstance(node, L.Join):
        left = _exec(node.left, ctx, req)
        right = _exec(node.right, ctx, req)
        out = _exec_join(node, left, right, ctx)
    elif isinstance(node, L.Aggregate):
        child = _exec(node.child, ctx, req)
        out = _exec_aggregate(node, child, ctx)
    elif isinstance(node, L.Sort):
        child = _exec(node.child, ctx, req)
        out = _exec_sort(node, child, ctx)
    elif isinstance(node, L.Limit):
        child = _exec(node.child, ctx, req)
        new_n = min(node.n, child.nrows)
        cap = next_pow2(max(new_n, 1))
        cols = {n: child.columns[n][:cap] for n in child.schema.names}
        out = Table(child.schema, cols, new_n)
    elif isinstance(node, L.Union):
        left = _exec(node.left, ctx, req)
        right = _exec(node.right, ctx, req)
        out = _exec_union(left, right, ctx)
    elif isinstance(node, L.Cache):
        out = _materialize_cache(node, ctx, req)
    else:
        raise TypeError(type(node))
    jax.block_until_ready(list(out.columns.values()))
    ctx.metrics.add_time(node.label.split(":")[0],
                         time.perf_counter() - t0)
    return out


def _concat_tables(schema: Schema, tables: list) -> Table:
    """Stack partition outputs (ascending partition id) into one
    relation: live rows of each piece, concatenated, padded to pow2."""
    total = sum(t.nrows for t in tables)
    cap = next_pow2(max(total, 1))
    if total == 0:
        return Table(schema, empty_like(schema, cap), 0)
    cols: Dict[str, jnp.ndarray] = {}
    for name in schema.names:
        pieces = [t.columns[name][: t.nrows] for t in tables if t.nrows]
        cols[name] = _assemble(pieces, cap, pieces[0])
    return Table(schema, cols, total)


def _partitioned_ce_table(psi: bytes, ctx: ExecContext) -> Table:
    """A partition-grained CE's full output: resident partitions come
    from the cache, cold partitions re-run the covering plan restricted
    to that partition (admitted ones are materialized as they compute).
    Composition order is ascending partition id — the same order an
    unpartitioned materialization would produce.  Admissions run inside
    one cache transaction: a failure part-way through the partition
    loop rolls back the partitions this call already admitted, so the
    pool budget never leaks on a partial multi-entry admission."""
    composed = ctx.ce_part_memo.get((psi, "composed"))
    if composed is not None:
        # one composition per window: every consumer reads the same
        # Table (matching the whole-CE path's materialize-once shape)
        return composed
    pp = ctx.partitioned_ces[psi]
    pieces = []
    txn = ctx.cache.transaction() if ctx.cache is not None else None
    try:
        for pid in pp.live:
            cached = ctx.cache.get((psi, pid)) if ctx.cache is not None \
                else None
            if cached is not None:
                ctx.metrics.bytes_cached_read += cached.nbytes
                pieces.append(cached)
                continue
            memo = ctx.ce_part_memo.get((psi, pid))
            if memo is not None:
                pieces.append(memo)
                continue
            plan = restrict_to_parts(pp.plan, (pid,))
            if ctx.fuse:
                plan = fuse_plan(plan)
            t = _exec(plan, ctx, required_columns_of(plan))
            if txn is not None and pid in pp.admitted:
                ctx.check_fault("ce_admission", key=(psi, pid))
                txn.put((psi, pid), t, nbytes=t.nbytes,
                        est_bytes=t.logical_nbytes,
                        benefit=pp.benefits.get(pid, 0.0))
            else:
                ctx._memo_put((psi, pid), t)
            pieces.append(t)
    except Exception:
        if txn is not None:
            txn.rollback()
        raise
    if txn is not None:
        txn.commit()
    out = _concat_tables(pp.plan.schema, pieces)
    # prefer memoizing the composed table (later reads are then free);
    # it subsumes the per-partition entries, so release those on
    # success.  Under a tight budget the composed copy may not fit the
    # memo allowance — keep the (smaller) cold pieces instead and let
    # later reads re-concat from cache + memo.
    for pid in pp.live:
        ctx._memo_drop((psi, pid))
    if not ctx._memo_put((psi, "composed"), out):
        for pid, t in zip(pp.live, pieces):
            if ctx.cache is None or not ctx.cache.contains((psi, pid)):
                ctx._memo_put((psi, pid), t)
    return out


def _record_calibration(ctx: ExecContext, kind: str, psi: bytes, plan,
                        seconds: float, table: Table) -> None:
    """Cost-model accuracy accounting: one predicted-vs-measured sample
    per CE materialization / cached read, fed to the session's
    :class:`~repro.core.costmodel.CalibrationLog` (PR 9).  Best-effort —
    a model that can't price the plan just skips the sample."""
    tel = ctx.telemetry
    cm = ctx.cost_model
    if tel is None or cm is None:
        return
    try:
        if kind == "materialize":
            predicted = cm.execution_cost(plan) + cm.write_cost(plan)
        else:
            predicted = cm.read_cost(plan)
        sample = CalibrationSample(
            kind=kind, key=psi.hex()[:12],
            predicted_cost=float(predicted),
            measured_seconds=float(seconds),
            predicted_bytes=int(cm.output_bytes(plan)),
            measured_bytes=int(table.nbytes),
            predicted_rows=int(cm.output_rows(plan)),
            measured_rows=int(table.nrows))
    except Exception:
        return
    tel.calibration.record(sample)


def _materialize_cache(node: L.Cache, ctx: ExecContext, req) -> Table:
    assert ctx.cache is not None, "cache plan requires a CacheManager"
    existing = ctx.cache.get(node.psi)
    if existing is not None:
        # a WHOLE resident entry serves even when this window treats
        # the CE as partition-grained: eligibility for partitioning
        # depends on the other CEs in the window, so the same content
        # can be admitted whole in one window and per-partition in the
        # next — the already-materialized bytes must not be recomputed
        return existing
    if node.psi in ctx.failed_ces:
        raise CEMaterializationError(node.psi)
    try:
        if node.psi in ctx.partitioned_ces:
            return _partitioned_ce_table(node.psi, ctx)
        t0 = time.perf_counter()
        with ctx.span("ce.materialize", psi=node.psi):
            table = _exec(node.child, ctx, req)
            ctx.check_fault("ce_admission", key=node.psi)
            ctx.cache.put(node.psi, table, nbytes=table.nbytes,
                          est_bytes=table.logical_nbytes,
                          benefit=ctx.cache_values.get(node.psi, 0.0))
        _record_calibration(ctx, "materialize", node.psi, node.child,
                            time.perf_counter() - t0, table)
    except CEMaterializationError:
        raise
    except Exception as exc:
        ctx.failed_ces.add(node.psi)
        raise CEMaterializationError(node.psi, exc) from exc
    return table


def _cached_scan_table(node: L.CachedScan, ctx: ExecContext) -> Table:
    """The full covering relation behind a CachedScan (materializing on
    first touch: Spark cache() is a transformation — §6.3 footnote 5)."""
    assert ctx.cache is not None
    t0 = time.perf_counter()
    table = ctx.cache.get(node.psi)
    if table is not None:
        # whole resident entry — serves even if this window re-planned
        # the CE as partition-grained (see _materialize_cache)
        ctx.metrics.bytes_cached_read += table.nbytes
        if ctx.telemetry is not None:
            plan = ctx.cache_plans.get(node.psi)
            if plan is not None:
                _record_calibration(ctx, "cached_read", node.psi, plan,
                                    time.perf_counter() - t0, table)
        return table
    if node.psi in ctx.failed_ces:
        # poisoned earlier this window: fail fast so the service reruns
        # this consumer on its residual plan instead of recomputing the
        # covering union inline
        raise CEMaterializationError(node.psi)
    try:
        if node.psi in ctx.partitioned_ces:
            return _partitioned_ce_table(node.psi, ctx)
        plan = ctx.cache_plans.get(node.psi)
        if plan is None:
            raise KeyError(f"no cache plan registered for ψ="
                           f"{node.psi.hex()[:12]}")
        if ctx.fuse:
            plan = fuse_plan(plan)
        return _exec(plan, ctx, required_columns_of(plan))
    except CEMaterializationError:
        raise
    except Exception as exc:
        ctx.failed_ces.add(node.psi)
        raise CEMaterializationError(node.psi, exc) from exc


def _exec_cached_scan(node: L.CachedScan, ctx: ExecContext, req) -> Table:
    table = _cached_scan_table(node, ctx)
    # present the cached covering relation under this node's schema
    return table.select([n for n in node.schema.names
                         if n in table.schema.names])


def required_columns_of(plan: L.Node):
    from .stats import required_columns

    return required_columns(plan)
