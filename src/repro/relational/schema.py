"""Columnar schema / table representation for the relational substrate.

Tables are structs-of-arrays (one JAX array per column) with an explicit
``nrows`` — arrays are padded to a power-of-two capacity so that eager
per-operator jit compilation caches aggressively (the Spark-stage
analog: each operator materializes a fixed-shape distributed relation).

Column types:
  * ``i32``  — int32 scalar column, shape (capacity,)
  * ``i64``  — int64 scalar column, shape (capacity,); columnar-only
    (the fixed-width CSV parser is 10-digit/i32) and requires JAX x64
  * ``f32``  — float32 scalar column, shape (capacity,)
  * ``str``  — fixed-width UTF-8 bytes, shape (capacity, width) uint8
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Tuple

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ColType:
    kind: str            # "i32" | "i64" | "f32" | "str"
    width: int = 0       # for "str": fixed byte width

    def __post_init__(self):
        assert self.kind in ("i32", "i64", "f32", "str")
        if self.kind == "str":
            assert self.width > 0

    @property
    def mem_bytes(self) -> int:
        """In-memory bytes per value (the cache-weight unit)."""
        return {"i32": 4, "i64": 8, "f32": 4,
                "str": self.width}[self.kind]

    @property
    def csv_width(self) -> int:
        """Fixed-width CSV-analog serialized byte width per value."""
        # i32: 10 zero-padded digits (values < 1e9); f32 in [0,1):
        # "0." + 8 digits -> we store just the 8 fractional digits.
        # i64 has no CSV encoding — int64 columns are columnar-only.
        if self.kind == "i64":
            raise ValueError("i64 columns have no CSV encoding")
        return {"i32": 10, "f32": 8, "str": self.width}[self.kind]


I32 = ColType("i32")
I64 = ColType("i64")
F32 = ColType("f32")


def STR(width: int) -> ColType:
    return ColType("str", width)


@dataclass(frozen=True)
class Schema:
    fields: Tuple[Tuple[str, ColType], ...]

    @staticmethod
    def of(*fields: Tuple[str, ColType]) -> "Schema":
        names = [n for n, _ in fields]
        assert len(set(names)) == len(names), "duplicate column names"
        return Schema(tuple(fields))

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.fields)

    def coltype(self, name: str) -> ColType:
        for n, t in self.fields:
            if n == name:
                return t
        raise KeyError(name)

    def has(self, name: str) -> bool:
        return any(n == name for n, _ in self.fields)

    def select(self, names: Iterable[str]) -> "Schema":
        names = tuple(names)
        return Schema(tuple((n, self.coltype(n)) for n in names))

    def concat(self, other: "Schema") -> "Schema":
        overlap = set(self.names) & set(other.names)
        assert not overlap, f"join column-name collision: {overlap}"
        return Schema(self.fields + other.fields)

    @property
    def row_mem_bytes(self) -> int:
        return sum(t.mem_bytes for _, t in self.fields)

    @property
    def row_csv_bytes(self) -> int:
        return sum(t.csv_width for _, t in self.fields)

    def csv_offsets(self) -> Dict[str, Tuple[int, int]]:
        """name -> (byte offset, byte width) in a fixed-width CSV row."""
        out, off = {}, 0
        for n, t in self.fields:
            out[n] = (off, t.csv_width)
            off += t.csv_width
        return out


def next_pow2(n: int) -> int:
    if n <= 1:
        return 1
    return 1 << (int(n - 1).bit_length())


@dataclass
class Table:
    """A (possibly distributed) relation: struct of arrays + row count."""

    schema: Schema
    columns: Dict[str, jnp.ndarray]
    nrows: int

    def __post_init__(self):
        for n, t in self.schema.fields:
            arr = self.columns[n]
            if t.kind == "str":
                assert arr.ndim == 2 and arr.shape[1] == t.width, \
                    (n, arr.shape)
            else:
                assert arr.ndim == 1, (n, arr.shape)

    @property
    def capacity(self) -> int:
        first = next(iter(self.columns.values()))
        return int(first.shape[0])

    @property
    def nbytes(self) -> int:
        """Actual device bytes held (capacity-based, what the cache pays)."""
        return int(sum(int(a.size) * a.dtype.itemsize
                       for a in self.columns.values()))

    @property
    def logical_nbytes(self) -> int:
        """Bytes of live rows only (what the cost model estimates)."""
        return self.nrows * self.schema.row_mem_bytes

    def select(self, names: Iterable[str]) -> "Table":
        names = tuple(names)
        return Table(self.schema.select(names),
                     {n: self.columns[n] for n in names}, self.nrows)

    # ---- host-side helpers (tests / benchmarks) --------------------------
    def to_numpy(self) -> Dict[str, np.ndarray]:
        return {n: np.asarray(self.columns[n])[: self.nrows]
                for n in self.schema.names}

    def row_multiset(self) -> List[tuple]:
        """Sorted list of row tuples — the relational-semantics equality
        view (SQL results are multisets; tie order is unspecified)."""
        cols = self.to_numpy()
        rows = []
        for i in range(self.nrows):
            row = []
            for n, t in self.schema.fields:
                v = cols[n][i]
                if t.kind == "str":
                    row.append(bytes(v.tobytes()))
                elif t.kind == "f32":
                    row.append(round(float(v), 4))
                else:
                    row.append(int(v))
            rows.append(tuple(row))
        rows.sort()
        return rows


def empty_like(schema: Schema, capacity: int) -> Dict[str, jnp.ndarray]:
    cols: Dict[str, jnp.ndarray] = {}
    for n, t in schema.fields:
        if t.kind == "i32":
            cols[n] = jnp.zeros((capacity,), jnp.int32)
        elif t.kind == "i64":
            cols[n] = jnp.zeros((capacity,), jnp.int64)
        elif t.kind == "f32":
            cols[n] = jnp.zeros((capacity,), jnp.float32)
        else:
            cols[n] = jnp.zeros((capacity, t.width), jnp.uint8)
    return cols
