"""Relational observability wiring (PR 9): the session telemetry hub,
the typed ``explain()`` report schema, and the unified metrics report.

``core.telemetry`` supplies the primitives (span tracer, metrics
registry); this module binds them to the query engine:

* :class:`Telemetry` — one per :class:`~repro.relational.executor.Session`
  (``sess.telemetry()``).  The metrics registry, the cost-model
  calibration log, and degradation/fault event counters are ALWAYS
  live (cheap dict increments on planning-path / rare events only);
  span tracing is opt-in via ``enable_tracing()`` — the default tracer
  is the no-op singleton, so the warm execution path pays nothing when
  tracing is off.
* :class:`ExplainReport` / :class:`ExplainCE` — the one typed schema
  behind ``handle.explain()``, replacing the ad-hoc dicts accreted
  across PRs 3–8.  ``as_dict()`` is the stable compat view: its key
  sets (:data:`EXPLAIN_DONE_KEYS` / :data:`EXPLAIN_FAILED_KEYS`) are
  pinned by tests.
* :func:`build_metrics_report` — the ``QueryService.metrics_report()``
  payload: registry snapshot, per-template-family latency percentiles,
  per-pool occupancy/hit rates from the memory hierarchy, fault-
  injector telemetry, and the predicted-vs-actual CE cost calibration
  table.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from ..core.costmodel import CalibrationLog
from ..core.telemetry import (MetricsRegistry, NOOP_TRACER, SpanTracer)

__all__ = [
    "Telemetry", "ExplainCE", "ExplainReport",
    "EXPLAIN_DONE_KEYS", "EXPLAIN_FAILED_KEYS",
    "build_metrics_report",
]


# ---------------------------------------------------------------------------
# the per-session telemetry hub
# ---------------------------------------------------------------------------
class Telemetry:
    """Session-scoped observability state.

    * ``registry`` — always-on :class:`MetricsRegistry` (query counts,
      inter-arrival EWMA, per-template latency histograms, degradation
      and fault event counters, absorbed per-window ``ExecMetrics``).
    * ``calibration`` — always-on :class:`CalibrationLog` fed by the
      executor's CE materializations and cached reads.
    * ``tracer`` — :data:`~repro.core.telemetry.NOOP_TRACER` until
      ``enable_tracing()`` swaps in a collecting
      :class:`~repro.core.telemetry.SpanTracer`.  Hot paths guard on
      ``tracer.enabled``, so disabled mode allocates nothing.
    """

    def __init__(self, clock=time.monotonic):
        self.clock = clock
        self.registry = MetricsRegistry()
        self.calibration = CalibrationLog()
        self.tracer = NOOP_TRACER

    @property
    def tracing(self) -> bool:
        return self.tracer.enabled

    def enable_tracing(self, clock=None) -> SpanTracer:
        """Install (or return the existing) collecting span tracer."""
        if not self.tracer.enabled:
            self.tracer = SpanTracer(clock=clock or self.clock)
        return self.tracer

    def disable_tracing(self) -> None:
        self.tracer = NOOP_TRACER

    def span(self, name: str, **attrs):
        return self.tracer.span(name, **attrs)

    # -- event / metric ingestion -------------------------------------------
    def record_event(self, ev: dict) -> None:
        """Fold one degradation/retry event dict (see
        :class:`~repro.core.faults.DegradationEvent`) into the registry
        — the ONE place window/soak tests count events from."""
        reg = self.registry
        reg.inc("events.total")
        reg.inc(f"events.action.{ev.get('action', 'unknown')}")
        reg.inc(f"events.level.{ev.get('level', 'unknown')}")

    def absorb_exec_metrics(self, m) -> None:
        """Accumulate one window's :class:`ExecMetrics` into session-
        lifetime registry counters (called once per closed window)."""
        if m is None:
            return
        reg = self.registry
        reg.inc("bytes.read_disk", m.bytes_read_disk)
        reg.inc("bytes.parsed", m.bytes_parsed)
        reg.inc("bytes.ce_cached_read", m.bytes_cached_read)
        reg.inc("bytes.scan_cache_read", m.bytes_scan_cache_read)
        reg.inc("rows.processed", m.rows_processed)
        reg.inc("trace.hits", m.trace_hits)
        reg.inc("trace.misses", m.trace_misses)
        reg.inc("dispatch.batched", m.batched_dispatches)
        reg.inc("dispatch.batched_queries", m.batched_queries)
        reg.inc("pid.hits", m.pid_hits)
        reg.inc("pid.pruned_parts", m.pid_pruned_parts)
        reg.inc("pid.records", m.pid_records)
        for op, dt in m.op_seconds.items():
            reg.inc(f"op_seconds.{op}", dt)

    # -- export conveniences -------------------------------------------------
    def export_chrome_trace(self, path: Optional[str] = None) -> dict:
        return self.tracer.export_chrome_trace(path)

    def export_jsonl(self, path: Optional[str] = None) -> str:
        return self.tracer.export_jsonl(path)


# ---------------------------------------------------------------------------
# the typed explain schema (one schema, PRs 3-8 consolidated)
# ---------------------------------------------------------------------------
EXPLAIN_DONE_KEYS = frozenset((
    "status", "window", "position", "window_size", "mqo", "seconds",
    "plan", "submitted", "ces", "resident_reuse", "subsumption_hit",
    "pid_pruned_parts",
))
# present in a done report only when applicable
EXPLAIN_DONE_OPTIONAL_KEYS = frozenset(("subsumption", "shared_dispatch"))
EXPLAIN_FAILED_KEYS = frozenset((
    "status", "window", "position", "window_size", "error", "events",
    "ces_salvaged", "ces_failed", "submitted",
))
EXPLAIN_CE_KEYS = frozenset((
    "psi", "strict_psi", "label", "m", "value", "weight",
    "resident_repriced", "cache_hit", "single_resume",
))


@dataclass
class ExplainCE:
    """One covering expression consumed by the executed plan."""

    psi: str                       # loose structural fingerprint (hex)
    strict_psi: str                # strict content fingerprint (hex)
    label: str
    m: int                         # consumer count
    value: float                   # Eq. 3 value at admission
    weight: int                    # MCKP weight (0 when resident)
    resident_repriced: bool
    cache_hit: bool
    single_resume: bool
    partitions: Optional[dict] = None   # {"live": [...], "admitted": [...]}

    def as_dict(self) -> dict:
        d = {
            "psi": self.psi, "strict_psi": self.strict_psi,
            "label": self.label, "m": self.m, "value": self.value,
            "weight": self.weight,
            "resident_repriced": self.resident_repriced,
            "cache_hit": self.cache_hit,
            "single_resume": self.single_resume,
        }
        if self.partitions is not None:
            d["partitions"] = dict(self.partitions)
        return d


@dataclass
class ExplainReport:
    """The post-resolution report behind ``handle.explain()``.

    ``status`` is ``"done"`` or ``"failed"``; ``as_dict()`` renders the
    status-appropriate stable key set (the thin dict compat view —
    exactly the keys callers of PRs 3-8 relied on)."""

    status: str
    window: int
    position: int
    window_size: int
    submitted: str = ""
    # -- success fields ------------------------------------------------------
    mqo: bool = False
    seconds: float = 0.0
    plan: str = ""
    ces: Tuple[ExplainCE, ...] = ()
    resident_reuse: bool = False
    subsumption_hit: bool = False
    pid_pruned_parts: int = 0
    subsumption: Optional[dict] = None       # {"strict_psi", "residual"}
    shared_dispatch: Optional[List[int]] = None
    # -- failure fields ------------------------------------------------------
    error: str = ""
    events: Tuple[dict, ...] = ()
    ces_salvaged: Tuple[str, ...] = ()
    ces_failed: Tuple[str, ...] = ()

    def as_dict(self) -> dict:
        if self.status == "failed":
            return {
                "status": self.status,
                "window": self.window,
                "position": self.position,
                "window_size": self.window_size,
                "error": self.error,
                "events": list(self.events),
                "ces_salvaged": list(self.ces_salvaged),
                "ces_failed": list(self.ces_failed),
                "submitted": self.submitted,
            }
        out: Dict[str, Any] = {
            "status": self.status,
            "window": self.window,
            "position": self.position,
            "window_size": self.window_size,
            "mqo": self.mqo,
            "seconds": self.seconds,
            "plan": self.plan,
            "submitted": self.submitted,
            "ces": [ce.as_dict() for ce in self.ces],
            "resident_reuse": self.resident_reuse,
            "subsumption_hit": self.subsumption_hit,
            "pid_pruned_parts": self.pid_pruned_parts,
        }
        if self.subsumption is not None:
            out["subsumption"] = dict(self.subsumption)
        if self.shared_dispatch:
            out["shared_dispatch"] = list(self.shared_dispatch)
        return out


# ---------------------------------------------------------------------------
# the unified metrics report
# ---------------------------------------------------------------------------
def _pool_view(stats: dict) -> dict:
    hits = stats.get("hits", 0)
    misses = stats.get("misses", 0)
    return {**stats, "hit_rate": hits / max(hits + misses, 1)}


_TENANT_COUNTERS = (
    "queries.submitted", "queries.succeeded", "queries.failed",
    "admission.admitted", "admission.queued", "admission.rejected",
)


def _tenant_sections(session, reg) -> dict:
    """Per-tenant occupancy + outcome + latency views (PR 10), built
    from the registry's labeled children (``...{tenant=...}``) and the
    memory manager's live owner attribution."""
    tenants: Dict[str, Dict[str, Any]] = {}
    mm = getattr(session, "memory", None)
    if mm is not None and hasattr(mm, "owner_usage"):
        for owner, by_pool in mm.owner_usage().items():
            t = tenants.setdefault(owner, {})
            t["pool_bytes"] = dict(by_pool)
            t["bytes_total"] = sum(by_pool.values())
    for base in _TENANT_COUNTERS:
        for labels, _key in reg.series(base):
            ten = labels.get("tenant")
            if ten is not None:
                tenants.setdefault(ten, {})[base] = reg.value(
                    base, labels=labels)
    for labels, key in reg.series("latency.tenant"):
        ten = labels.get("tenant")
        h = reg._histograms.get(key)
        if ten is not None and h is not None:
            tenants.setdefault(ten, {})["latency"] = h.as_dict()
    return tenants


def build_metrics_report(session) -> dict:
    """Everything observable about one session, in one dict: the
    registry snapshot, per-template-family latency percentiles, pool
    occupancy + hit rates per tier, per-tenant occupancy/latency
    sections, fault-injector telemetry, and the cost model's
    predicted-vs-actual calibration table."""
    tel: Telemetry = session.telemetry()
    snap = tel.registry.snapshot()
    latency = {"all": None, "families": {}}
    for name, h in snap["histograms"].items():
        if name == "latency.all":
            latency["all"] = h
        elif name.startswith("latency.family."):
            latency["families"][name[len("latency.family."):]] = h
    mem = session.memory.report()
    pools = {name: _pool_view(st)
             for name, st in mem.get("pools", {}).items()}
    injector = getattr(session, "fault_injector", None)
    calibration = tel.calibration.report()
    return {
        "registry": snap,
        "latency": latency,
        "arrival_interval_ewma_s": snap["ewmas"].get(
            "arrival.interval_s", {"value": 0.0, "n": 0}),
        "pools": pools,
        "memory": {k: v for k, v in mem.items() if k != "pools"},
        "tenants": _tenant_sections(session, tel.registry),
        "faults": injector.report() if injector is not None else None,
        "calibration": calibration,
    }
