# SparkSQL-analog relational substrate: columnar tables over JAX arrays,
# the fluent lazy Relation frontend compiled through a canonical plan
# IR, logical plans, Catalyst-like local optimization, cardinality
# stats, eager per-operator SPMD execution, the MQO integration, the
# online QueryService front-end (continuous submission + micro-batch
# MQO windows), and the asyncio serving front (background window
# closer, adaptive windows, per-tenant admission control).
from . import expr, logical
from .api import ColExpr, Pred, Relation, as_expr, c, col
from .async_service import (AdaptiveWindowPolicy, AdmissionController,
                            AdmissionError, AsyncConfig,
                            AsyncQueryHandle, AsyncQueryService,
                            TenantQuota, WindowParams)
from .canonical import (FALSE, canonicalize_expr, canonicalize_plan,
                        format_plan)
from .datagen import (generate_columns, make_storage, people_schema,
                      synthetic_schema)
from .executor import BatchResult, QueryResult, Session
from .fuse import FusedPipeline, fuse_plan, unfuse_plan
from .observe import (EXPLAIN_CE_KEYS, EXPLAIN_DONE_KEYS,
                      EXPLAIN_DONE_OPTIONAL_KEYS, EXPLAIN_FAILED_KEYS,
                      ExplainCE, ExplainReport, Telemetry,
                      build_metrics_report)
from .partition import (CePartition, PartitionInfo, PartitionedCePlan,
                        Partitioning, make_ce_partitioner, partition_table,
                        prune_parts)
from .physical import (CEMaterializationError, ExecContext, ExecMetrics,
                       TableStorage, execute)
from .rewriter import RelationalRewriter, make_ce_transform
from .rules import optimize_single
from .schema import F32, I32, I64, STR, ColType, Schema, Table, next_pow2
from .service import (ExecutionConfig, MemoryConfig, MqoConfig,
                      QueryError, QueryHandle, QueryService,
                      ResilienceConfig, SessionConfig, WindowState)
from .stats import (RelationalCostModel, StatsRegistry, build_table_stats,
                    required_columns, selectivity)
