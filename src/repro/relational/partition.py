"""Horizontal partitioning: declaration, statistics, pruning, CE slicing.

The paper's worksharing caches whole covering-expression outputs; this
module makes both caching and scanning *partition-grained* (cf.
PartitionCache's partition-keyed query cache, and the reuse/work-sharing
coordination of Sioulas et al. 2023):

  * **Declaration** — ``Session.register(storage, partitioning=
    Partitioning(column="n1", scheme="range", n_partitions=8))``
    physically re-clusters the table so each partition is a contiguous
    row range, and records per-partition min/max/NDV statistics.
  * **Pruning** — :func:`prune_parts` evaluates a filter predicate
    against the per-partition statistics and returns the partitions
    that MAY contain qualifying rows (conservative by construction:
    interval reasoning can only over-approximate the satisfying set).
    The executor scans only those ranges; the cost model scales scan
    cost by the pruned fraction.
  * **CE slicing** — :func:`make_ce_partitioner` splits a covering
    expression over a single partitioned table into per-partition
    knapsack items, so the MCKP can admit the *hot fraction* of a CE
    instead of rejecting it whole; :class:`PartitionedCePlan` is the
    execution-side record the executor uses to compose resident and
    recomputed partitions at read time.

Partition order is ascending partition id everywhere, and partitions
are contiguous row ranges of the (re-clustered) table — so a pruned
scan's live rows are exactly the unpruned scan's live rows with the
non-qualifying partitions' rows deleted, in the same relative order.
That is what makes pruned execution bit-identical on live rows
(property-tested in ``tests/test_partition.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import expr as E
from . import logical as L

# Knuth multiplicative hash (mod 2^32) — deterministic across runs and
# processes (Python's hash() is salted), cheap to mirror in tests.
_HASH_MULT = np.uint64(2654435761)
_HASH_MOD = np.uint64(1 << 32)


@dataclass(frozen=True)
class Partitioning:
    """Declared at ``register_table`` time.

    * ``range`` — split points at quantiles of ``column`` (numeric),
      partition p holds rows with ``bounds[p-1] < v <= bounds[p]``;
    * ``hash``  — ``knuth_hash(v) % n_partitions`` over an int32
      ``column`` (value clustering is irrelevant; equality predicates
      on the partition column prune to a single bucket).
    """

    column: str
    scheme: str = "range"          # "range" | "hash"
    n_partitions: int = 8

    def __post_init__(self):
        assert self.scheme in ("range", "hash"), self.scheme
        assert self.n_partitions >= 1


@dataclass
class PartColStats:
    """Per-partition, per-column summary used by the pruner."""

    count: int
    vmin: float
    vmax: float
    ndv: int
    is_int: bool = True     # column dtype (drives literal-cast semantics)
    has_nan: bool = False   # NaN poisons interval reasoning: unprunable


@dataclass
class PartitionInfo:
    """Partition layout + statistics of one re-clustered table."""

    spec: Partitioning
    offsets: np.ndarray                 # (n_partitions + 1,) row offsets
    col_stats: List[Dict[str, PartColStats]] = field(default_factory=list)

    @property
    def n_partitions(self) -> int:
        return len(self.offsets) - 1

    def part_rows(self, pid: int) -> int:
        return int(self.offsets[pid + 1] - self.offsets[pid])

    def part_range(self, pid: int) -> Tuple[int, int]:
        return int(self.offsets[pid]), int(self.offsets[pid + 1])

    def all_parts(self) -> Tuple[int, ...]:
        return tuple(range(self.n_partitions))

    def rows_of(self, parts) -> int:
        return sum(self.part_rows(p) for p in parts)


def hash_bucket(values: np.ndarray, n: int) -> np.ndarray:
    v = values.astype(np.int64).view(np.uint64) * _HASH_MULT
    return ((v % _HASH_MOD) % np.uint64(n)).astype(np.int64)


def assign_partitions(values: np.ndarray,
                      spec: Partitioning) -> np.ndarray:
    """Row -> partition id under ``spec`` (host-side, registration)."""
    n = spec.n_partitions
    if spec.scheme == "hash":
        assert np.issubdtype(values.dtype, np.integer), \
            "hash partitioning requires an integer column"
        return hash_bucket(values, n)
    qs = np.quantile(values.astype(np.float64),
                     np.linspace(0, 1, n + 1)[1:-1])
    return np.searchsorted(qs, values.astype(np.float64),
                           side="left").astype(np.int64)


def build_partition_info(spec: Partitioning, nrows: int,
                         cols: Dict[str, np.ndarray],
                         pids_sorted: np.ndarray) -> PartitionInfo:
    """Statistics over ALREADY RE-CLUSTERED columns (``pids_sorted`` is
    the per-row partition id of the reordered table, non-decreasing)."""
    n = spec.n_partitions
    counts = np.bincount(pids_sorted, minlength=n)
    offsets = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    stats: List[Dict[str, PartColStats]] = []
    for pid in range(n):
        lo, hi = int(offsets[pid]), int(offsets[pid + 1])
        per_col: Dict[str, PartColStats] = {}
        for name, arr in cols.items():
            if arr.ndim != 1:        # str columns: pruner treats unknown
                continue
            part = arr[lo:hi]
            is_int = bool(np.issubdtype(arr.dtype, np.integer))
            if part.size == 0:
                per_col[name] = PartColStats(0, 0.0, 0.0, 0, is_int)
            else:
                # NaN makes min/max (and every interval compare) NaN —
                # i.e. False — which would UNSOUNDLY prune a partition
                # that still holds qualifying non-NaN rows (and NaN
                # rows themselves satisfy !=).  Flag it; the pruner
                # treats such partitions as unprunable.
                has_nan = (not is_int
                           and bool(np.isnan(part).any()))
                finite = part[~np.isnan(part)] if has_nan else part
                if finite.size == 0:
                    per_col[name] = PartColStats(
                        count=int(part.size), vmin=0.0, vmax=0.0,
                        ndv=1, is_int=is_int, has_nan=True)
                else:
                    per_col[name] = PartColStats(
                        count=int(part.size),
                        vmin=float(finite.min()),
                        vmax=float(finite.max()),
                        ndv=int(len(np.unique(finite))),
                        is_int=is_int, has_nan=has_nan)
        stats.append(per_col)
    return PartitionInfo(spec=spec, offsets=offsets, col_stats=stats)


def partition_table(spec: Partitioning, nrows: int,
                    cols: Dict[str, np.ndarray]
                    ) -> Tuple[np.ndarray, Dict[str, np.ndarray],
                               PartitionInfo]:
    """Compute the re-clustering permutation + reordered columns + info.

    Applying ``perm`` to every column (and to the CSV byte matrix)
    groups each partition into one contiguous row range, ascending by
    partition id, ORDER-STABLE within a partition.
    """
    assert spec.column in cols, f"unknown partition column {spec.column}"
    pids = assign_partitions(np.asarray(cols[spec.column])[:nrows], spec)
    perm = np.argsort(pids, kind="stable")
    reordered = {n: np.ascontiguousarray(np.asarray(a)[:nrows][perm])
                 for n, a in cols.items()}
    info = build_partition_info(spec, nrows, reordered, pids[perm])
    return perm, reordered, info


# ---------------------------------------------------------------------------
# partition pruning
# ---------------------------------------------------------------------------
def _cast_lit(v, is_int: bool):
    """Literal under the EXECUTION's comparison semantics: frac consts
    on int columns are folded by expr.fold_int_cmp (handled by the
    caller); everything else is cast to the column dtype before the
    compare, which the interval test must mirror exactly."""
    if is_int:
        return float(int(v))
    return float(np.float32(v))


def _interval_cmp(op: str, vmin: float, vmax: float, v: float,
                  want_all: bool) -> bool:
    """``want_all=False``: may ANY value in [vmin, vmax] satisfy
    ``x op v``?  ``want_all=True``: do ALL values in the interval
    satisfy it?  (The ``all`` dual is what makes Not(...) prunable
    soundly: ANY over the interval over-approximates ANY over the
    actual value set, ALL under-approximates it.)"""
    if want_all:
        if op == "<":
            return vmax < v
        if op == "<=":
            return vmax <= v
        if op == ">":
            return vmin > v
        if op == ">=":
            return vmin >= v
        if op == "==":
            return vmin == v == vmax
        if op == "!=":
            return v < vmin or v > vmax
    else:
        if op == "<":
            return vmin < v
        if op == "<=":
            return vmin <= v
        if op == ">":
            return vmax > v
        if op == ">=":
            return vmax >= v
        if op == "==":
            return vmin <= v <= vmax
        if op == "!=":
            return not (vmin == v == vmax)
    raise ValueError(op)


def _part_maybe(e: E.Expr, stats: Dict[str, PartColStats],
                info: PartitionInfo, pid: int, want_all: bool) -> bool:
    """Conservative satisfiability of ``e`` over partition ``pid``.

    ``want_all=False`` OVER-approximates "some row satisfies e";
    ``want_all=True`` UNDER-approximates "every row satisfies e".
    Unknown sub-expressions (string compares, col-col compares, missing
    stats) return the safe default for the mode.
    """
    unknown = want_all is False   # maybe-mode default True, all-mode False
    if isinstance(e, E.TrueExpr):
        return True
    if isinstance(e, E.Cmp):
        e = E.oriented(e)
        if isinstance(e.col, E.Lit):       # Lit-Lit: exact constant
            return E.const_cmp(e)
        if isinstance(e.rhs, E.Col):
            return unknown
        cs = stats.get(e.col.name)
        if cs is None or cs.count == 0:
            # no stats (string column) — unprunable; empty partition —
            # vacuously prunable in maybe-mode, satisfiable in all-mode
            return unknown if cs is None else want_all
        if cs.has_nan:
            # NaN rows defeat interval reasoning (they satisfy != and
            # fail everything else, outside [vmin, vmax] semantics)
            return unknown
        v = e.rhs.value
        if isinstance(v, (str, bytes)):
            return unknown
        is_int = cs.is_int
        op = e.op
        spec = info.spec
        if (spec.scheme == "hash" and e.col.name == spec.column
                and op == "==" and not want_all
                and float(v).is_integer()):
            # hash partitioning: equality on the partition column lands
            # in exactly one bucket
            want = int(hash_bucket(np.asarray([int(v)], np.int64),
                                   spec.n_partitions)[0])
            if want != pid:
                return False
            # fall through: the bucket may still lack the exact value
        if is_int and isinstance(v, float) and not v.is_integer():
            folded = E.fold_int_cmp(op, v)
            if folded[0] == "all":
                return folded[1]
            _, op, v = folded
        return _interval_cmp(op, cs.vmin, cs.vmax,
                             _cast_lit(v, is_int), want_all)
    if isinstance(e, E.In):
        # membership = disjunction of equalities over the value list
        # (empty lists canonicalize away, but stay safe here anyway)
        if not e.values:
            return False    # no row can satisfy membership in ()
        ors = E.Or(tuple(E.Cmp("==", e.col, E.Lit(v)) for v in e.values)) \
            if len(e.values) > 1 else E.Cmp("==", e.col, E.Lit(e.values[0]))
        return _part_maybe(ors, stats, info, pid, want_all)
    if isinstance(e, E.And):
        # both modes distribute conjunction as ∀/∃-safe `all` / the
        # over-approximation "every conjunct may hold somewhere"
        return all(_part_maybe(p, stats, info, pid, want_all)
                   for p in e.parts)
    if isinstance(e, E.Or):
        return any(_part_maybe(p, stats, info, pid, want_all)
                   for p in e.parts)
    if isinstance(e, E.Not):
        # some row satisfies ¬p  ⟸  not (every row satisfies p)
        # every row satisfies ¬p ⟸  not (some row may satisfy p)
        return not _part_maybe(e.part, stats, info, pid, not want_all)
    raise TypeError(type(e))


def prune_parts(pred: E.Expr, info: PartitionInfo) -> Tuple[int, ...]:
    """Partition ids that may contain rows satisfying ``pred``
    (ascending; conservative — never drops a qualifying partition)."""
    return tuple(
        pid for pid in range(info.n_partitions)
        if info.part_rows(pid) > 0
        and _part_maybe(pred, info.col_stats[pid], info, pid, False))


def pid_presence_from_mask(mask: np.ndarray, info: PartitionInfo,
                           parts: Tuple[int, ...]) -> Tuple[int, ...]:
    """Partition ids among ``parts`` whose scanned rows contain any
    qualifying row, given the host-side boolean ``mask`` over the rows
    actually scanned (the concatenation of ``parts`` ranges, in order).

    This is the recording half of the pid bitset pool: partitions NOT
    in ``parts`` were pruned, and pruning is conservative, so they are
    exactly empty for the predicate — the returned presence set is a
    full-table fact whenever the scan started from ``parts = None``
    (i.e. pruning itself chose ``parts``)."""
    present: List[int] = []
    off = 0
    for pid in parts:
        n = info.part_rows(pid)
        if n and bool(np.any(mask[off:off + n])):
            present.append(int(pid))
        off += n
    return tuple(present)


# ---------------------------------------------------------------------------
# plan helpers
# ---------------------------------------------------------------------------
def linear_scan_chain(tree: L.Node
                      ) -> Optional[Tuple[L.Scan, E.Expr]]:
    """(scan leaf, conjunction of chain filters) for a Filter*/Project*
    chain over ONE Scan; None for any other shape (joins, aggregates,
    cached leaves).  This is the partitionable-CE eligibility test —
    the dominant CE shape after MQO rewriting (ROADMAP)."""
    preds: List[E.Expr] = []
    cur = L.as_node(tree)
    while isinstance(cur, (L.Filter, L.Project)):
        if isinstance(cur, L.Filter):
            preds.append(cur.pred)
        cur = cur.child
    if not isinstance(cur, L.Scan):
        return None
    return cur, E.and_(*preds)


def restrict_to_parts(tree: L.Node, parts: Tuple[int, ...]) -> L.Node:
    """The same plan with its Scan leaf restricted to ``parts``."""
    tree = L.as_node(tree)
    if isinstance(tree, L.Scan):
        from dataclasses import replace

        return replace(tree, parts=tuple(parts))
    if not tree.children:
        return tree
    return tree.with_children(tuple(restrict_to_parts(c, parts)
                                    for c in tree.children))


# ---------------------------------------------------------------------------
# CE partition slicing (MCKP group items)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class CePartition:
    """One partition's slice of a covering expression, priced.

    ``value`` / ``weight`` are the row-proportional shares of the CE's
    Eq. 3 value and byte weight (scan-dominated chains scale linearly
    with input rows, which is exactly the partitionable-CE shape);
    ``resident_value`` re-prices the slice when its bytes are already
    materialized from an earlier window (C_E and C_W sunk, only reads
    and extraction remain — the per-partition analog of
    ``core.costmodel.price_resident_ce``)."""

    pid: int
    rows: int
    weight: int
    value: float
    resident_value: float


@dataclass
class PartitionedCePlan:
    """Execution-side record of one partition-grained CE: which
    partitions are live (survive the covering predicate's pruning),
    which the MCKP admitted to the cache this window, and the covering
    plan to run per-partition for the rest."""

    plan: L.Node                      # covering tree (cache-plan child)
    table: str
    info: PartitionInfo
    live: Tuple[int, ...]
    admitted: frozenset = frozenset()
    benefits: Dict[int, float] = field(default_factory=dict)


def make_ce_partitioner(catalog, min_partitions: int = 2):
    """``partitioner`` hook for :class:`repro.core.optimizer
    .MultiQueryOptimizer`: split an eligible CE into per-partition
    MCKP items.

    Eligible: the covering tree is a Filter*/Project* chain over one
    Scan of a partitioned table (``catalog[name].partitions`` set) with
    at least ``min_partitions`` live partitions after pruning with the
    covering predicate.  Must run AFTER ``price_ce`` (consumes the
    ``cost_detail`` breakdown).
    """

    def partition_ce(ce) -> Optional[Tuple[PartitionedCePlan,
                                           List[CePartition]]]:
        chain = linear_scan_chain(ce.tree)
        if chain is None:
            return None
        scan, pred = chain
        st = catalog.get(scan.table)
        info = getattr(st, "partitions", None)
        if info is None or scan.parts is not None:
            return None
        live = prune_parts(pred, info)
        if len(live) < min_partitions:
            return None
        d = ce.cost_detail
        sunk_free = d.get("C_omega", 0.0) - (
            ce.m * d.get("C_R", 0.0) + d.get("C_X", 0.0))
        total_rows = max(1, info.rows_of(live))
        slices = []
        for pid in live:
            f = info.part_rows(pid) / total_rows
            slices.append(CePartition(
                pid=pid,
                rows=info.part_rows(pid),
                weight=max(1, int(ce.weight * f)),
                value=ce.value * f,
                resident_value=sunk_free * f,
            ))
        plan = PartitionedCePlan(plan=ce.tree, table=scan.table,
                                 info=info, live=live)
        return plan, slices

    return partition_ce
