"""Relational Rewriter: extraction plans, CE validation, augmentation.

Three plan-type-specific pieces the generic core delegates to:

1. **CE transform** (`make_ce_transform`): (a) reject CEs that cannot be
   re-extracted — a *divergent* merged filter sitting below a
   non-refilter-safe operator (Aggregate / Limit) would change that
   operator's semantics; (b) *augment* covering Project nodes with the
   columns each member's extraction filter will need (the paper's
   "several other optimizations … omitted for readability", §4.2 fn 2).

2. **Extraction plans** (`RelationalRewriter.make_extraction`): the
   member's own filter predicates re-applied to the cached covering
   relation, then the member's output columns projected (identity when
   the SE members were syntactically equal, §4.4).

3. **Cache plans**: the covering tree terminated by a Cache operator.
"""
from __future__ import annotations

from dataclasses import replace
from typing import List, Optional, Tuple

from ..core.covering import CoveringExpression
from ..core.fingerprint import fingerprint
from . import expr as E
from . import logical as L


# ---------------------------------------------------------------------------
# CE validation + augmentation
# ---------------------------------------------------------------------------
def _divergent_filter_below_unsafe(node: L.Node,
                                   unsafe_above: bool = False) -> bool:
    if isinstance(node, L.Filter) and node.divergent and unsafe_above:
        return True
    unsafe_here = unsafe_above or not node.refilter_safe
    return any(_divergent_filter_below_unsafe(c, unsafe_here)
               for c in node.children)


def _augment_projects(node: L.Node) -> Tuple[L.Node, frozenset]:
    """Bottom-up: make divergent-variant predicate columns survive every
    Project above them so extraction filters can evaluate."""
    if not node.children:
        return node, frozenset()
    new_children: List[L.Node] = []
    needs: frozenset = frozenset()
    for c in node.children:
        nc, n = _augment_projects(c)
        new_children.append(nc)
        needs |= n
    out: L.Node = node.with_children(tuple(new_children))
    if isinstance(out, L.Filter) and out.divergent:
        for p in out.variant_preds:
            needs |= E.columns_of(p)
    if isinstance(out, L.Project) and needs:
        child_names = out.child.schema.names
        extra = [c for c in child_names
                 if c in needs and c not in out.cols]
        if extra:
            cols = tuple(c for c in child_names
                         if c in set(out.cols) | set(extra))
            out = replace(out, cols=cols)
    return out, needs


def make_ce_transform():
    def transform(ce: CoveringExpression) -> Optional[CoveringExpression]:
        if _divergent_filter_below_unsafe(ce.tree):
            return None
        tree, _ = _augment_projects(ce.tree)
        if tree is not ce.tree:
            if fingerprint(tree) != ce.psi:  # augmentation is loose-only
                return None
            ce = CoveringExpression(se=ce.se, tree=tree, psi=ce.psi)
        return ce

    return transform


# ---------------------------------------------------------------------------
# lock-step divergence collection (member vs covering)
# ---------------------------------------------------------------------------
def _collect_divergent(covering: L.Node, member: L.Node,
                       preds: List[E.Expr]) -> bool:
    """Collect member filter predicates where the covering pred is wider.
    Returns True if member differs anywhere from the covering tree
    (so the extraction is not an identity)."""
    differs = False
    if isinstance(covering, L.Filter):
        if E.canonical(member.pred) != E.canonical(covering.pred):
            preds.append(member.pred)
            differs = True
    elif isinstance(covering, L.Project):
        if tuple(member.cols) != tuple(covering.cols):
            differs = True
    cc, mc = covering.children, member.children
    if len(cc) == 2 and covering.commutative:
        # align member children to covering children by fingerprint
        cf = [fingerprint(x) for x in cc]
        mf = [fingerprint(x) for x in mc]
        if cf != mf and cf == mf[::-1]:
            mc = mc[::-1]
    for c, m in zip(cc, mc):
        differs |= _collect_divergent(c, m, preds)
    return differs


class RelationalRewriter:
    """Implements repro.core.rewrite.Rewriter for relational plans.

    With ``fuse_residuals`` the extraction plan (CachedScan → Filter →
    Project, the CE-consumer hot path) is emitted pre-collapsed into a
    single FusedPipeline physical node, so every consumer re-reads the
    cached covering relation with ONE dispatch instead of one per
    residual operator.  Rewriting happens after fingerprinting, so the
    physical node never perturbs ψ identities.
    """

    def __init__(self, fuse_residuals: bool = False):
        self.fuse_residuals = fuse_residuals

    @staticmethod
    def cache_key(ce: CoveringExpression) -> bytes:
        """Runtime cache identity: the STRICT content fingerprint, so
        same-structure CEs with different merged predicates (recurring
        windows over a template family) coexist in the cache instead of
        colliding on the loose psi and evicting one another."""
        return ce.strict_psi()

    def make_cache_plan(self, ce: CoveringExpression) -> L.Node:
        return L.Cache(child=ce.tree, psi=self.cache_key(ce))

    def make_extraction(self, ce: CoveringExpression,
                        member: L.Node) -> L.Node:
        cached = L.CachedScan(psi=self.cache_key(ce),
                              _schema=ce.tree.schema,
                              source_label=ce.tree.label)
        preds: List[E.Expr] = []
        _collect_divergent(ce.tree, member, preds)
        plan: L.Node = cached
        if preds:
            plan = L.Filter(child=plan, pred=E.and_(*preds))
        if tuple(plan.schema.names) != tuple(member.schema.names):
            plan = L.Project(child=plan, cols=tuple(member.schema.names))
        if self.fuse_residuals:
            from .fuse import fuse_plan

            plan = fuse_plan(plan)
        return plan
