"""Asynchronous serving front: concurrent submission, a background
window closer, adaptive windows, per-tenant admission control (PR 10).

The sync :class:`~repro.relational.service.QueryService` is
cooperative: window deadlines fire only inside ``submit`` / ``poll`` /
``result`` calls, so a deadline window with no caller in flight sits
open, and thousands of concurrent clients would serialize on one
lock-step loop.  This module retires that caveat:

    svc = await AsyncQueryService(session, config=AsyncConfig(
        slo_p99_s=0.5, quotas={"acme": TenantQuota(max_bytes=1 << 24)},
    )).start()
    h = await svc.submit(plan, tenant="acme")   # enqueue, lock-free
    table = await h                             # or: await h.result()
    ...
    await svc.aclose()

**Architecture (single-writer).**  Submitters run on the asyncio event
loop and only append to the open :class:`WindowState` — plain
event-loop-thread mutation, no locks.  Closed windows (detached handle
lists) are pushed onto an ``asyncio.Queue`` and drained by ONE executor
task that runs each window via ``loop.run_in_executor`` on a dedicated
single-thread pool — so window MQO + execution stay strictly serialized
against the shared Session (the same ``QueryService._run_window`` the
sync front and ``run_batch`` use, hence bit-identical results on the
same plan set) while the event loop stays free to accept arrivals.

**Background closer.**  A closer task sleeps until the open window's
deadline and closes it with *no caller in flight* — ``flush_expired`` /
``poll`` survive only as thin compat shims that nudge the closer.  The
deadline close is the ``async_close`` fault point: an injected fault
crashes the closer task, the supervisor restarts it (counted in
``async.closer_restarts``), and the due window closes on the next pass
— every pending handle still resolves.

**Admission control.**  ``submit(..., tenant=...)`` charges the
tenant's live CE/scan-pool bytes (``MemoryManager.owner_bytes``,
stamped first-toucher-pays during execution) and in-flight query count
against its :class:`TenantQuota`; over-quota submissions queue (FIFO
per tenant, re-evaluated as queries finish) or fail fast with
:class:`AdmissionError`.  ``metrics_report()`` grows per-tenant
occupancy/latency sections.

**Adaptive windowing.**  Per-template-family arrival-rate EWMAs set
each window's effective ``max_batch`` / ``max_wait_s`` at open time to
maximize expected sharing — the cost model's
``window_dispatch_cost(n, batched)`` savings grow with batch size —
subject to the p99 latency SLO (``AsyncConfig.slo_p99_s``): the wait
budget is what remains of the SLO after the observed p99 window
execution time, and the batch target is how many arrivals of the
opening query's family fit in that budget.  Chosen parameters and
predicted-vs-realized sharing are logged as spans + metrics
(``window.adaptive.*``).
"""
from __future__ import annotations

import asyncio
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional

from . import logical as L  # noqa: F401  (type context for plans)
from .service import (QueryHandle, QueryService, WindowState,
                      _coerce_submission)

__all__ = [
    "AsyncConfig", "TenantQuota", "AdmissionError",
    "AdmissionController", "AdaptiveWindowPolicy", "WindowParams",
    "AsyncQueryHandle", "AsyncQueryService",
]


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TenantQuota:
    """Per-tenant admission limits.

    * ``max_bytes`` — cap on the tenant's attributed live pool bytes
      (CE + scan + everything stamped to it, first-toucher-pays); a
      submission while at/over the cap queues or fails.
    * ``max_inflight`` — cap on admitted-but-unresolved queries.
    * ``max_queued`` — cap on submissions waiting for admission
      (beyond it, ``submit`` raises even in ``"queue"`` mode).
    * ``on_over`` — ``"queue"`` (default: wait for headroom) or
      ``"fail"`` (raise :class:`AdmissionError` immediately).

    ``None`` on any limit disables that check."""

    max_bytes: Optional[int] = None
    max_inflight: Optional[int] = None
    max_queued: Optional[int] = None
    on_over: str = "queue"

    def __post_init__(self):
        assert self.on_over in ("queue", "fail"), self.on_over


@dataclass(frozen=True)
class AsyncConfig:
    """Knobs of the async front.

    With ``adaptive=False`` (or no ``slo_p99_s``) every window uses the
    fixed ``max_batch`` / ``max_wait_s`` — the sync service's contract.
    With ``adaptive=True`` and an SLO those become the *defaults* for
    families with no arrival history, and each window's effective
    parameters come from :class:`AdaptiveWindowPolicy`."""

    max_batch: int = 8
    max_wait_s: Optional[float] = None
    # -- adaptive windowing --------------------------------------------------
    adaptive: bool = False
    slo_p99_s: Optional[float] = None   # end-to-end p99 latency target
    min_batch: int = 1
    max_batch_cap: int = 64
    # fallback p99 window-execution estimate until windows.seconds has
    # real observations (conservative: first windows close fast)
    exec_default_s: float = 0.05
    # -- admission control ---------------------------------------------------
    quotas: Mapping[str, TenantQuota] = field(default_factory=dict)
    # applied to tenants without an explicit quota (None: unlimited)
    default_quota: Optional[TenantQuota] = None


class AdmissionError(RuntimeError):
    """A submission rejected by admission control (quota exceeded with
    ``on_over="fail"``, or the tenant's admission queue is full)."""


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------
class AdmissionController:
    """Per-tenant admission gate, event-loop-confined (no locks needed:
    counters only mutate on the loop thread).

    Byte usage is read from ``MemoryManager.owner_bytes`` — the live
    attribution the execution path stamps — so a tenant whose cached
    state was evicted automatically regains byte headroom.  Waiters are
    re-evaluated whenever one of the tenant's queries resolves (the
    moments in-flight slots and, typically, bytes are released)."""

    def __init__(self, session, config: AsyncConfig):
        self.session = session
        self.config = config
        self.inflight: Dict[str, int] = {}
        self.waiting: Dict[str, int] = {}
        self._conds: Dict[str, asyncio.Condition] = {}

    def quota_for(self, tenant: Optional[str]) -> Optional[TenantQuota]:
        if tenant is None:
            return None
        q = self.config.quotas.get(tenant)
        return q if q is not None else self.config.default_quota

    def _over(self, tenant: str, q: TenantQuota) -> Optional[str]:
        """The violated limit's name, or None when the tenant fits."""
        if (q.max_inflight is not None
                and self.inflight.get(tenant, 0) >= q.max_inflight):
            return "inflight"
        if q.max_bytes is not None:
            mm = getattr(self.session, "memory", None)
            if (mm is not None and hasattr(mm, "owner_bytes")
                    and mm.owner_bytes(tenant) >= q.max_bytes):
                return "bytes"
        return None

    def _tinc(self, name: str, tenant: str) -> None:
        tel = getattr(self.session, "_telemetry", None)
        if tel is not None:
            tel.registry.inc(name, labels={"tenant": tenant})

    async def acquire(self, tenant: Optional[str]) -> None:
        """Admit one submission for ``tenant`` (possibly after
        waiting); raises :class:`AdmissionError` on fail-fast quotas
        and full admission queues."""
        q = self.quota_for(tenant)
        if tenant is None or q is None:
            return
        reason = self._over(tenant, q)
        if reason is None:
            self.inflight[tenant] = self.inflight.get(tenant, 0) + 1
            self._tinc("admission.admitted", tenant)
            return
        if q.on_over == "fail":
            self._tinc("admission.rejected", tenant)
            raise AdmissionError(
                f"tenant {tenant!r} over quota ({reason})")
        if reason == "bytes" and self.inflight.get(tenant, 0) == 0:
            # nothing of this tenant is in flight, so no completion of
            # its own will ever free bytes — queueing would deadlock
            # (resident cached state alone exceeds the quota)
            self._tinc("admission.rejected", tenant)
            raise AdmissionError(
                f"tenant {tenant!r} resident bytes exceed max_bytes "
                f"with no queries in flight (would wait forever)")
        if (q.max_queued is not None
                and self.waiting.get(tenant, 0) >= q.max_queued):
            self._tinc("admission.rejected", tenant)
            raise AdmissionError(
                f"tenant {tenant!r} admission queue full "
                f"({self.waiting[tenant]} waiting)")
        cond = self._conds.setdefault(tenant, asyncio.Condition())
        self.waiting[tenant] = self.waiting.get(tenant, 0) + 1
        self._tinc("admission.queued", tenant)
        try:
            async with cond:
                await cond.wait_for(
                    lambda: self._over(tenant, q) is None)
        finally:
            self.waiting[tenant] -= 1
        self.inflight[tenant] = self.inflight.get(tenant, 0) + 1
        self._tinc("admission.admitted", tenant)

    def release(self, tenant: Optional[str]) -> None:
        """One of the tenant's queries resolved: free its in-flight
        slot and wake waiters to re-check their quotas."""
        if tenant is None:
            return
        if self.inflight.get(tenant, 0) > 0:
            self.inflight[tenant] -= 1
        cond = self._conds.get(tenant)
        if cond is not None and self.waiting.get(tenant, 0) > 0:
            asyncio.get_running_loop().create_task(self._notify(cond))

    @staticmethod
    async def _notify(cond: asyncio.Condition) -> None:
        async with cond:
            cond.notify_all()

    def report(self) -> Dict[str, Dict[str, int]]:
        tenants = set(self.inflight) | set(self.waiting)
        return {t: {"inflight": self.inflight.get(t, 0),
                    "waiting": self.waiting.get(t, 0)}
                for t in sorted(tenants)}


# ---------------------------------------------------------------------------
# adaptive windowing
# ---------------------------------------------------------------------------
@dataclass
class WindowParams:
    """One window's chosen parameters plus the prediction that chose
    them (logged to spans + metrics; realized sharing is recorded when
    the window resolves)."""

    max_batch: int
    max_wait_s: Optional[float]
    family: Optional[str] = None
    rate_hz: float = 0.0
    wait_budget_s: float = 0.0
    predicted_saving_s: float = 0.0


class AdaptiveWindowPolicy:
    """SLO-bounded window sizing from per-family arrival-rate EWMAs.

    Decision, made when a window OPENS (first arrival, family *f*):

        interval = EWMA inter-arrival of family f     (fallback: the
                   all-queries ``arrival.interval_s`` EWMA)
        rate     = 1 / interval
        exec99   = p99 of ``window.seconds``          (fallback:
                   ``exec_default_s``)
        budget   = max(0, slo_p99_s - exec99)         # wait we can afford
        n*       = clamp(1 + floor(rate * budget), min_batch,
                         max_batch_cap)
        wait     = min(budget, n* / rate)             # don't out-wait
                                                      # the batch target

    The opening query waits at most ``wait`` and then executes in
    ``exec99`` at the 99th percentile, so end-to-end p99 stays within
    the SLO by construction (given calibrated inputs).  A trickle
    family (rate → 0) degenerates to ``n* = min_batch`` closing
    immediately — latency-optimal; a bursty family fills large windows
    and harvests the ``(n-1) · dispatch`` sharing the cost model
    prices via ``window_dispatch_cost``."""

    def __init__(self, session, config: AsyncConfig, clock=None):
        self.session = session
        self.config = config
        self._clock = clock or time.monotonic
        self._last_arrival: Dict[str, float] = {}

    @property
    def _registry(self):
        tel = getattr(self.session, "_telemetry", None)
        return tel.registry if tel is not None else None

    def observe_arrival(self, family: Optional[str],
                        now: Optional[float] = None) -> None:
        """Feed one arrival of ``family`` into its inter-arrival EWMA
        (``arrival.family_interval_s{family=...}``)."""
        if family is None:
            return
        now = self._clock() if now is None else now
        reg = self._registry
        last = self._last_arrival.get(family)
        self._last_arrival[family] = now
        if last is not None and reg is not None:
            reg.ewma("arrival.family_interval_s",
                     labels={"family": family}).observe(max(now - last,
                                                            0.0))

    def _interval(self, family: Optional[str]) -> Optional[float]:
        reg = self._registry
        if reg is None:
            return None
        if family is not None:
            e = reg.ewma("arrival.family_interval_s",
                         labels={"family": family})
            if e.n > 0 and e.value > 0:
                return e.value
        e = reg.ewma("arrival.interval_s")
        if e.n > 0 and e.value > 0:
            return e.value
        return None

    def _exec_p99(self) -> float:
        reg = self._registry
        if reg is not None:
            h = reg.histogram("window.seconds")
            if h.count > 0:
                return float(h.percentile(0.99))
        return self.config.exec_default_s

    def predicted_saving(self, n: int) -> float:
        """Dispatch seconds a batched window of ``n`` saves over
        per-query dispatch (PR 7's ``window_dispatch_cost`` delta)."""
        cm = getattr(self.session, "cost_model", None)
        if cm is None or not hasattr(cm, "window_dispatch_cost"):
            return 0.0
        return max(cm.window_dispatch_cost(n, batched=False)
                   - cm.window_dispatch_cost(n, batched=True), 0.0)

    def realized_saving(self, metrics) -> float:
        """Dispatch seconds the window ACTUALLY saved, from its
        ExecMetrics: each batched group of k queries dispatched once
        instead of k times."""
        cm = getattr(self.session, "cost_model", None)
        if cm is None or not hasattr(cm, "c"):
            return 0.0
        bq = getattr(metrics, "batched_queries", 0)
        bd = getattr(metrics, "batched_dispatches", 0)
        return max(bq - bd, 0) * cm.c.dispatch

    def decide(self, family: Optional[str]) -> WindowParams:
        """The effective (max_batch, max_wait_s) for a window opened by
        a query of ``family``."""
        cfg = self.config
        if not cfg.adaptive or cfg.slo_p99_s is None:
            return WindowParams(cfg.max_batch, cfg.max_wait_s,
                                family=family)
        interval = self._interval(family)
        rate = (1.0 / interval) if interval else 0.0
        budget = max(0.0, cfg.slo_p99_s - self._exec_p99())
        n = int(1 + rate * budget)
        n = max(cfg.min_batch, min(n, cfg.max_batch_cap))
        wait = budget if rate <= 0 else min(budget, n / rate)
        params = WindowParams(
            max_batch=n, max_wait_s=wait, family=family,
            rate_hz=rate, wait_budget_s=budget,
            predicted_saving_s=self.predicted_saving(n))
        reg = self._registry
        if reg is not None:
            reg.observe("window.adaptive.batch", n)
            reg.observe("window.adaptive.wait_s", wait)
            reg.ewma("window.adaptive.predicted_saving_s").observe(
                params.predicted_saving_s)
        return params


# ---------------------------------------------------------------------------
# handles
# ---------------------------------------------------------------------------
class AsyncQueryHandle:
    """Awaitable view over a sync :class:`QueryHandle`.

    ``await handle`` (or ``await handle.result()``) yields the query's
    Table once its window has run; a failed query re-raises the
    exception that killed it (inspect ``failed`` / ``error`` to look
    without raising).  ``explain()`` / ``explain_report()`` delegate to
    the sync handle after resolution."""

    __slots__ = ("_inner", "_future", "tenant")

    def __init__(self, inner: QueryHandle, future: "asyncio.Future",
                 tenant: Optional[str] = None):
        self._inner = inner
        self._future = future
        self.tenant = tenant
        # inspect-without-awaiting (``h.failed``) is a supported use;
        # retrieving the exception here keeps asyncio from logging
        # "exception was never retrieved" for such handles
        future.add_done_callback(
            lambda f: f.exception() if not f.cancelled() else None)

    # -- awaiting ------------------------------------------------------------
    def __await__(self):
        return self._future.__await__()

    async def result(self):
        """The query's output Table (exceptions re-raised)."""
        return await self._future

    # -- delegated inspection ------------------------------------------------
    @property
    def seq(self) -> int:
        return self._inner.seq

    @property
    def done(self) -> bool:
        return self._future.done()

    @property
    def failed(self) -> bool:
        return self._inner.failed

    @property
    def error(self):
        return self._inner.error

    def explain(self) -> dict:
        return self._inner.explain()

    def explain_report(self):
        return self._inner.explain_report()

    def __repr__(self) -> str:   # pragma: no cover - debugging aid
        state = ("failed" if self.failed
                 else "done" if self.done else "pending")
        return f"AsyncQueryHandle(seq={self.seq}, {state})"


# ---------------------------------------------------------------------------
# the async service
# ---------------------------------------------------------------------------
class AsyncQueryService:
    """Concurrent-submission front over a shared :class:`Session`.

    Lifecycle: ``await start()`` (idempotent; ``submit`` lazily starts
    too), then ``await aclose()`` — or use it as an async context
    manager.  All state mutation happens on the event-loop thread
    except window execution, which one dedicated worker thread runs
    serially (single-writer against the Session)."""

    def __init__(self, session, *,
                 config: Optional[AsyncConfig] = None,
                 clock=None, **service_kw):
        cfg = config if config is not None else AsyncConfig()
        self.config = cfg
        # the sync core supplies _run_window (the ONE execution path),
        # submission bookkeeping, and the window/sequence counters
        self.core = QueryService(
            session, max_batch=cfg.max_batch, max_wait_s=cfg.max_wait_s,
            clock=clock if clock is not None else time.monotonic,
            **service_kw)
        self.policy = AdaptiveWindowPolicy(session, cfg,
                                           clock=self.core._clock)
        self.admission = AdmissionController(session, cfg)
        self._window = WindowState()
        self._resolvers: Dict[QueryHandle, "asyncio.Future"] = {}
        self._started = False
        self._closing = False
        self.closer_restarts = 0
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._queue: Optional[asyncio.Queue] = None
        self._wake: Optional[asyncio.Event] = None
        self._pool: Optional[ThreadPoolExecutor] = None
        self._closer_task: Optional[asyncio.Task] = None
        self._executor_task: Optional[asyncio.Task] = None

    @property
    def session(self):
        return self.core.session

    # -- lifecycle -----------------------------------------------------------
    async def start(self) -> "AsyncQueryService":
        """Bind to the running loop and launch the executor + closer
        tasks (idempotent)."""
        if self._started:
            return self
        self._loop = asyncio.get_running_loop()
        self._queue = asyncio.Queue()
        self._wake = asyncio.Event()
        self._pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="repro-window")
        self._closing = False
        self._executor_task = asyncio.create_task(
            self._executor_loop(), name="repro-executor")
        self._closer_task = asyncio.create_task(
            self._supervised_closer(), name="repro-closer")
        self._started = True
        return self

    async def aclose(self) -> None:
        """Flush the open window, drain queued windows, stop the
        background tasks."""
        if not self._started:
            return
        self._closing = True
        self._close_window()
        await self._queue.join()
        for task in (self._closer_task, self._executor_task):
            task.cancel()
        await asyncio.gather(self._closer_task, self._executor_task,
                             return_exceptions=True)
        self._pool.shutdown(wait=True)
        self._started = False

    async def __aenter__(self) -> "AsyncQueryService":
        return await self.start()

    async def __aexit__(self, exc_type, exc, tb) -> None:
        await self.aclose()

    # -- submission ----------------------------------------------------------
    async def submit(self, plan, *,
                     tenant: Optional[str] = None) -> AsyncQueryHandle:
        """Enqueue one query; returns an awaitable handle immediately
        (after admission control for ``tenant``).  Window accumulation
        is lock-free: this coroutine never blocks on window execution."""
        await self.start()
        await self.admission.acquire(tenant)
        node, hint = _coerce_submission(
            plan, "AsyncQueryService.submit")
        core = self.core
        handle = QueryHandle(core, plan, core._n_submitted, node=node,
                             hint_cache=hint, tenant=tenant)
        fut = self._loop.create_future()
        ah = AsyncQueryHandle(handle, fut, tenant=tenant)
        now = core._note_submit(handle)
        try:
            family = core._family_of(node)
        except Exception:
            family = None     # poisoned plan: the window will fail it
        handle._family = family
        self.policy.observe_arrival(family, now=now)
        if self._window.empty:
            params = self.policy.decide(family)
            self._window.open(now, params.max_batch, params.max_wait_s)
        self._window.append(handle)
        self._resolvers[handle] = fut
        if self._window.full():
            self._close_window()
        else:
            self._wake.set()    # closer re-arms on the new deadline
        return ah

    # -- window close / execution -------------------------------------------
    def _close_window(self) -> None:
        """Detach the open window (if any) and hand it to the executor
        task.  Loop-thread only."""
        handles = self._window.detach()
        if handles:
            self._queue.put_nowait(handles)
        if self._wake is not None:
            self._wake.set()

    async def _executor_loop(self) -> None:
        """The single writer: pops closed windows and runs each through
        the shared ``QueryService._run_window`` on the one-thread pool,
        then resolves the futures.  Serialization against the Session
        is by construction — one queue, one worker thread."""
        while True:
            handles = await self._queue.get()
            try:
                await self._loop.run_in_executor(
                    self._pool, self.core._run_window, handles)
            except Exception:
                # _run_window's safety net already resolved every
                # handle (to results or QueryErrors); with isolation
                # off the exception additionally escapes — the handles
                # carry it, nothing more to do here
                pass
            finally:
                reg = self._registry()
                if reg is not None:
                    reg.ewma(
                        "window.adaptive.realized_saving_s").observe(
                        self._realized_saving(handles))
                for h in handles:
                    self._finish(h)
                self._queue.task_done()

    def _realized_saving(self, handles) -> float:
        tel = getattr(self.session, "_telemetry", None)
        if tel is None:
            return 0.0
        # window-level ExecMetrics were absorbed into the registry; use
        # the policy's model on the per-window shared-dispatch explain
        # data instead: each resolved handle that shared a dispatch of
        # size k contributed (k-1)/k of a dispatch saved
        cm = getattr(self.session, "cost_model", None)
        if cm is None or not hasattr(cm, "c"):
            return 0.0
        saved = 0.0
        for h in handles:
            if h.failed or not h._done:
                continue
            # _LazyExplain and a rendered ExplainReport both expose the
            # shared-dispatch positions; reading the ingredient avoids
            # paying for a full explain render per query
            shared = getattr(h._explain, "shared_dispatch", None)
            if shared:
                k = len(shared)
                if k > 1:
                    saved += (k - 1) / k * cm.c.dispatch
        return saved

    def _registry(self):
        tel = getattr(self.session, "_telemetry", None)
        return tel.registry if tel is not None else None

    def _finish(self, handle: QueryHandle) -> None:
        """Resolve one async future from its (now resolved) sync
        handle; release the tenant's admission slot."""
        fut = self._resolvers.pop(handle, None)
        self.admission.release(handle.tenant)
        if fut is None or fut.done():
            return
        if handle.failed:
            fut.set_exception(handle.error.exception)
        elif handle._done:
            fut.set_result(handle._query_result.table)
        else:      # unreachable: _run_window guarantees resolution
            fut.set_exception(
                RuntimeError("window did not resolve handle"))

    # -- background closer ---------------------------------------------------
    async def _supervised_closer(self) -> None:
        """Restart the closer when it crashes (the ``async_close``
        fault point): pending windows still close, handles resolve."""
        while True:
            try:
                await self._closer()
                return
            except asyncio.CancelledError:
                raise
            except Exception as exc:
                self.closer_restarts += 1
                tel = getattr(self.session, "_telemetry", None)
                if tel is not None:
                    tel.registry.inc("async.closer_restarts")
                    tel.record_event({
                        "action": "closer_restart", "level": "closer",
                        "error": repr(exc)})

    async def _closer(self) -> None:
        """Sleep until the open window's deadline, then close it — no
        caller in flight required.  Woken early whenever the window
        changes (submit, flush) to re-arm on the new deadline."""
        while True:
            self._wake.clear()
            deadline = self._window.deadline()
            if deadline is None:
                await self._wake.wait()
                continue
            delay = deadline - self.core._clock()
            if delay <= 0:
                inj = getattr(self.session, "fault_injector", None)
                if inj is not None:
                    # the fault point: a fire crashes this task BEFORE
                    # the close; the supervisor restarts it and the
                    # still-due window closes on the next pass
                    inj.check("async_close")
                self._close_window()
                continue
            try:
                await asyncio.wait_for(self._wake.wait(), timeout=delay)
            except asyncio.TimeoutError:
                pass

    # -- compat shims --------------------------------------------------------
    def flush_expired(self):
        """Compat shim: the background closer owns deadlines now; this
        only nudges it.  Returns None (there is never a synchronously
        closed window to hand back)."""
        if self._wake is not None:
            self._wake.set()
        return None

    def poll(self) -> bool:
        """Compat shim: deadline checks are automatic; see
        ``flush_expired``."""
        self.flush_expired()
        return False

    async def flush(self) -> None:
        """Close the open window now (without waiting for execution —
        ``await drain()`` for that)."""
        await self.start()
        self._close_window()

    async def drain(self) -> None:
        """Wait until every closed window has executed and resolved."""
        if self._queue is not None:
            await self._queue.join()

    # -- observability -------------------------------------------------------
    @property
    def pending(self) -> int:
        """Queries accumulated in the open window (excludes windows
        already queued for execution)."""
        return self._window.size

    def telemetry(self):
        return self.core.telemetry()

    def metrics_report(self) -> dict:
        """The unified report, plus the admission controller's live
        per-tenant in-flight/waiting counts merged into ``tenants``."""
        report = self.core.metrics_report()
        tenants = report.setdefault("tenants", {})
        for t, counts in self.admission.report().items():
            tenants.setdefault(t, {})["admission"] = counts
        return report
