"""Online query service: continuous submission + micro-batch MQO windows.

The paper's §5 prototype is a *server*: clients submit queries at any
time, the server accumulates them, optimizes each accumulated batch
with the multi-query optimizer, executes, and returns results.  This
module is that front-end:

    svc = QueryService(session, max_batch=8, max_wait_s=0.05)
    h = svc.submit(plan)          # returns immediately: a lazy handle
    ...
    table = h.result()            # resolves (closing the window if open)
    h.explain()                   # chosen plan, matched CE/SE, reuse

**Window lifecycle.**  The first ``submit`` after a flush opens a
window (state held in one :class:`WindowState`, shared with the async
front).  The window *closes* (runs the MQO over its queries, executes,
and resolves every handle, in submission order) when any of:

  * it holds ``max_batch`` queries (count trigger, closes inside the
    submitting call);
  * ``max_wait_s`` has elapsed since the window opened — checked on
    every ``submit``/``poll``/``result`` (this sync front is
    cooperative: no background threads, so a deadline fires at the
    next call — ``result()`` on ANY handle, even an already-resolved
    one, runs the check, so an expired window is never stranded until
    the next unrelated ``submit``.  The async front retires the caveat
    entirely: its background closer task fires deadlines with no
    caller in flight — see ``relational.async_service``);
  * ``flush()`` is called explicitly, or ``result()`` is called on a
    handle still sitting in the open window.

The one-shot ``Session.run_batch`` is routed through this same
machinery as a *pre-closed* window (``run_closed``), so online and
batch execution share one code path — and are bit-identical on the
same plan set.

**Cross-window reuse.**  Each window's MCKP re-prices covering
expressions whose content is still resident from ANY earlier window as
zero-weight already-paid items.  CE cache entries are keyed by the
*strict* content fingerprint (not the loose structural ψ), so several
same-structure/different-predicate CEs — the signature of a recurring
windowed workload, where each window merges a different subset of a
template family — stay resident side by side instead of evicting one
another.  A window with a single matching query (fewer than ``k``
consumers) can still resume from a resident CE (single-query resident
resume; see ``core.optimizer``).

**SessionConfig.**  The session's former eight orthogonal constructor
knobs are grouped into one frozen :class:`SessionConfig` (``execution``
/ ``memory`` / ``mqo`` sub-configs); ``Session.from_config`` builds a
session from it and the legacy keyword arguments remain as deprecation
shims.
"""
from __future__ import annotations

import time
import warnings
from contextlib import nullcontext
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Set

from ..core.faults import DegradationEvent, InjectedFault
from ..core.fingerprint import fingerprint, fingerprint_set
from ..core.optimizer import MultiQueryOptimizer
from ..core.rewrite import attach_recompute_plan
from ..core.telemetry import NOOP_SPAN
from . import expr as E
from . import logical as L
from .canonical import canonicalize_plan
from .observe import ExplainCE, ExplainReport, build_metrics_report
from .rewriter import RelationalRewriter, make_ce_transform
from .rules import optimize_single

_UNSET = object()


def _coerce_submission(plan, entry: str, stacklevel: int = 3):
    """(logical node, cache hint) for a submitted query.

    :class:`~repro.relational.api.Relation` is the supported frontend;
    raw ``logical.Node`` trees still work as a compat shim but are on a
    deprecation path — they miss the builder's ergonomics, not its
    sharing (both are canonicalized identically downstream).
    ``stacklevel`` points the warning at the caller's call site (the
    run_batch path has more intermediate frames than submit)."""
    hook = getattr(plan, "__plan_node__", None)
    if hook is not None:
        return hook(), bool(getattr(plan, "hint_cache", False))
    node = L.as_node(plan)
    warnings.warn(
        f"passing raw logical.Node trees to {entry} is deprecated "
        f"and the shim will be REMOVED two releases after v0.8 — "
        f"build queries with the Relation API (session.table(...)"
        f".where(...)...)", DeprecationWarning, stacklevel=stacklevel)
    return node, False


# ---------------------------------------------------------------------------
# unified session configuration
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ExecutionConfig:
    """Physical execution-path knobs (ROADMAP "Execution paths").

    ``fuse=False, defer_sync=False, use_scan_cache=False`` reproduces
    the seed eager executor.
    """

    fuse: bool = True
    defer_sync: bool = True
    use_scan_cache: bool = True
    use_pallas_filter: bool = False
    # partition pruning over partitioned tables (relational.partition):
    # fused pipelines skip partitions whose statistics refute the
    # predicate.  False forces the unpruned path (bit-identity tests).
    prune: bool = True
    # window batching: execute a closed window's same-shape fused
    # pipelines as ONE batched mask dispatch (PR 7).  False keeps
    # per-query dispatch (the baseline the bench compares against).
    window_batch: bool = True
    # plan-shape compile cache: slotted predicate programs keyed by
    # plan SHAPE (literals hoisted to operand arrays) so recurring
    # templates never re-trace.  False forces literal-keyed jit.
    shape_cache: bool = True
    # partition-identifier bitset pool (PR 8): record, per canonical
    # conjunct, which partitions produced any row as a side effect of
    # fused execution, and intersect resident bitsets on later queries
    # to prune by observed history ON TOP of the stats pruner.  False
    # disables both recording and lookup (stats-only pruning).
    pid_cache: bool = True
    sharding: Optional[Any] = None          # jax.sharding.Sharding
    disk_latency_per_byte: float = 0.0


@dataclass(frozen=True)
class MemoryConfig:
    """Memory-hierarchy knobs (ROADMAP "Memory hierarchy")."""

    budget_bytes: int = 1 << 30
    host_budget_bytes: Optional[int] = None   # None -> 4x device budget
    policy: str = "lru"                       # lru | benefit | admission
    retain_across_batches: bool = True


@dataclass(frozen=True)
class MqoConfig:
    """Multi-query-optimizer defaults applied per window."""

    enabled: bool = True
    k: int = 2                      # SE consumer threshold (Algorithm 1)
    locally_optimize: bool = True   # Catalyst-like single-query pass first
    max_compound_size: int = 4      # Algorithm 2 compound bound
    chain_cache_plans: bool = True  # larger CEs read smaller CEs' caches
    # Feed MemoryManager headroom (budget minus bytes other pools and
    # retained residents already hold) into the MCKP instead of the full
    # session budget, so planning stops over-admitting CEs the hierarchy
    # would immediately spill.
    pressure_aware: bool = True
    # Semantic subsumption (PR 8): before the window optimizes, a query
    # whose predicate is IMPLIED by a retained resident CE's weaker
    # predicate resumes from that CE plus the residual conjuncts
    # (relational.canonical.subsumption_residual) — reuse without an
    # exact strict-fingerprint match.  False requires exact matches.
    subsumption: bool = True


@dataclass(frozen=True)
class ResilienceConfig:
    """Failure-handling knobs (ROADMAP "Failure semantics").

    * ``isolate`` — per-query fault isolation: a failing query resolves
      its own handle to a :class:`QueryError` while siblings in the
      window complete; off, the first failure aborts the window (every
      handle still resolves — to the same error).
    * ``degrade`` — the execution ladder: Pallas kernel route →
      fused-XLA → eager per-operator; transient faults retry in place.
    * ``max_attempts`` — bounded attempts per query across retries and
      ladder steps (the ladder never loops forever).
    * ``backoff_base_s`` / ``backoff_multiplier`` — exponential backoff
      between attempts: sleep ``base * multiplier**(attempt-1)`` before
      attempt ``attempt+1``.  The default base of 0 disables sleeping
      (deterministic tests); the session clock is injectable
      (``Session._sleep``) so backoff tests never wall-sleep.
    * ``window_close_retries`` — bounded retries of the window-close
      step itself when its fault point fires.
    * ``audit_windows`` — run ``MemoryManager.audit()`` after every
      window and ``reconcile()`` on violations (cheap: pure bookkeeping
      arithmetic over live entries).
    * ``faults`` — optional :class:`~repro.core.faults.FaultConfig`
      enabling the deterministic fault-injection harness.
    """

    isolate: bool = True
    degrade: bool = True
    max_attempts: int = 4
    backoff_base_s: float = 0.0
    backoff_multiplier: float = 2.0
    window_close_retries: int = 2
    audit_windows: bool = True
    faults: Optional[Any] = None      # core.faults.FaultConfig


@dataclass(frozen=True)
class SessionConfig:
    """Everything a Session needs, in one frozen value.

    Build variants with :func:`dataclasses.replace` on the sub-configs:

        cfg = SessionConfig(memory=MemoryConfig(budget_bytes=1 << 26))
        sess = Session.from_config(cfg)
    """

    execution: ExecutionConfig = field(default_factory=ExecutionConfig)
    memory: MemoryConfig = field(default_factory=MemoryConfig)
    mqo: MqoConfig = field(default_factory=MqoConfig)
    resilience: ResilienceConfig = field(default_factory=ResilienceConfig)

    def with_execution(self, **kw) -> "SessionConfig":
        return replace(self, execution=replace(self.execution, **kw))

    def with_memory(self, **kw) -> "SessionConfig":
        return replace(self, memory=replace(self.memory, **kw))

    def with_mqo(self, **kw) -> "SessionConfig":
        return replace(self, mqo=replace(self.mqo, **kw))

    def with_resilience(self, **kw) -> "SessionConfig":
        return replace(self, resilience=replace(self.resilience, **kw))

    def with_faults(self, faults) -> "SessionConfig":
        """Attach a :class:`~repro.core.faults.FaultConfig` (or None)."""
        return self.with_resilience(faults=faults)

    _LEGACY_EXECUTION_KEYS = frozenset(
        ("fuse", "defer_sync", "use_scan_cache", "sharding",
         "disk_latency_per_byte"))
    _LEGACY_MEMORY_KEYS = frozenset(
        ("budget_bytes", "host_budget_bytes", "policy",
         "retain_across_batches"))

    @classmethod
    def from_legacy_kwargs(cls, **kw) -> "SessionConfig":
        """Fold the pre-SessionConfig ``Session(...)`` keyword knobs
        into the unified config (the shared shim behind the legacy
        constructor path and helpers like ``build_tpcds_session``).
        Only keys actually passed are forwarded, so the sub-config
        dataclass field defaults stay the single source of truth."""
        unknown = set(kw) - cls._LEGACY_EXECUTION_KEYS \
            - cls._LEGACY_MEMORY_KEYS
        if unknown:
            raise TypeError(
                f"unknown legacy Session kwargs: {sorted(unknown)}")
        ex = {k: v for k, v in kw.items()
              if k in cls._LEGACY_EXECUTION_KEYS}
        mem = {k: v for k, v in kw.items()
               if k in cls._LEGACY_MEMORY_KEYS}
        if "budget_bytes" in mem:
            mem["budget_bytes"] = int(mem["budget_bytes"])
        return cls(execution=ExecutionConfig(**ex),
                   memory=MemoryConfig(**mem))


# ---------------------------------------------------------------------------
# window state
# ---------------------------------------------------------------------------
class WindowState:
    """One accumulating micro-batch window: the handles plus the
    *effective* close triggers for THIS window.

    Factored out of ``QueryService`` (PR 10) so the sync and async
    fronts share one lifecycle: both accumulate into a WindowState and
    hand the detached handle list to ``QueryService._run_window`` — the
    single execution path, so the two fronts are bit-identical on the
    same plan set.  The per-window ``max_batch`` / ``max_wait_s`` make
    adaptive windowing possible: the async policy sets them at open
    time from the arrival-rate EWMAs instead of fixed service knobs."""

    __slots__ = ("handles", "opened_at", "max_batch", "max_wait_s")

    def __init__(self):
        self.handles: List[QueryHandle] = []
        self.opened_at: Optional[float] = None
        self.max_batch: int = 1
        self.max_wait_s: Optional[float] = None

    @property
    def empty(self) -> bool:
        return not self.handles

    @property
    def size(self) -> int:
        return len(self.handles)

    def open(self, now: float, max_batch: int,
             max_wait_s: Optional[float]) -> None:
        """Arm the window for its first arrival with this window's
        effective close triggers."""
        assert not self.handles, "window already open"
        self.opened_at = now
        self.max_batch = max(1, int(max_batch))
        self.max_wait_s = max_wait_s

    def append(self, handle: "QueryHandle") -> None:
        self.handles.append(handle)

    def contains(self, handle: "QueryHandle") -> bool:
        return any(h is handle for h in self.handles)

    def full(self) -> bool:
        return len(self.handles) >= self.max_batch

    def due(self, now: float) -> bool:
        """True when the deadline trigger should close the window."""
        return (bool(self.handles) and self.max_wait_s is not None
                and now - self.opened_at >= self.max_wait_s)

    def deadline(self) -> Optional[float]:
        """Absolute clock time of the deadline trigger (None when the
        window is empty or has no wait bound) — what the async closer
        task sleeps until."""
        if not self.handles or self.max_wait_s is None:
            return None
        return self.opened_at + self.max_wait_s

    def detach(self) -> List["QueryHandle"]:
        """Close the window: take the handles, reset to empty."""
        handles, self.handles = self.handles, []
        self.opened_at = None
        return handles


# ---------------------------------------------------------------------------
# lazy handles
# ---------------------------------------------------------------------------
@dataclass
class QueryError:
    """Terminal failure state of a :class:`QueryHandle`: the exception
    that killed the query after the resilience machinery gave up, plus
    the degradation/retry history that led there.  Sibling queries in
    the window are unaffected (per-query fault isolation)."""

    exception: BaseException
    window: int = -1
    position: int = -1
    attempts: int = 0
    events: List[dict] = field(default_factory=list)
    # strict cache keys (hex) the query's plan consumed that ARE
    # materialized despite the failure — work salvaged for siblings
    # and later windows
    salvaged_ces: List[str] = field(default_factory=list)

    def __repr__(self) -> str:
        return (f"QueryError({type(self.exception).__name__}: "
                f"{self.exception}, window={self.window}, "
                f"position={self.position}, attempts={self.attempts})")


class QueryHandle:
    """A submitted query: resolves when its micro-batch window runs.

    ``plan`` is the object as submitted (a Relation or a legacy raw
    Node — provenance for ``explain()``); ``node`` is the underlying
    logical tree the window optimizes."""

    __slots__ = ("plan", "node", "hint_cache", "seq", "tenant",
                 "_service", "_query_result", "_explain", "_done",
                 "_error", "_t_submit", "_family")

    def __init__(self, service: "QueryService", plan, seq: int, *,
                 node: Optional[L.Node] = None, hint_cache: bool = False,
                 tenant: Optional[str] = None):
        self._service = service
        self.plan = plan
        self.node = node if node is not None else L.as_node(plan)
        self.hint_cache = hint_cache
        self.seq = seq                  # submission order, service-wide
        self.tenant = tenant            # quota / attribution key (PR 10)
        self._query_result = None
        self._explain = None
        self._done = False
        self._error: Optional[QueryError] = None
        self._t_submit: Optional[float] = None    # service clock time
        self._family: Optional[str] = None        # loose psi hex (12)

    @property
    def done(self) -> bool:
        return self._done

    @property
    def failed(self) -> bool:
        """True when the handle resolved to a :class:`QueryError`."""
        return self._done and self._error is not None

    @property
    def error(self) -> Optional["QueryError"]:
        """The terminal failure state (None while pending or on
        success); inspecting it never raises — use ``result()`` to
        re-raise."""
        return self._error

    def result(self):
        """The query's output Table, forcing the window closed if this
        handle is still sitting in it (laziness must not deadlock).
        A failed query re-raises the exception that killed it.

        Awaiting ANY handle also drives the cooperative deadline clock
        (PR 10 staleness fix): a different window whose ``max_wait_s``
        has expired closes here too, instead of sitting stranded until
        the next unrelated ``submit``."""
        if self._done:
            self._service.flush_expired()
        else:
            self._service._force(self)
        if not self._done:
            raise RuntimeError("handle was not resolved by its window")
        if self._error is not None:
            raise self._error.exception
        return self._query_result.table

    @property
    def query_result(self):
        """The full QueryResult (table + seconds + executed plan)."""
        if not self._done:
            self.result()
        if self._error is not None:
            raise self._error.exception
        return self._query_result

    def explain(self) -> dict:
        """Post-execution report: the chosen (rewritten) logical plan,
        every CE the plan consumes with its SE provenance, and whether
        each CE read hit an already-resident cache entry.  Rendered
        lazily — resolution stores only the ingredients, so windows
        (and run_batch) never pay for explains nobody asks for."""
        if not self._done:
            raise RuntimeError(
                "query still pending — call result(), flush() or poll()")
        if callable(self._explain):
            self._explain = self._explain()
        if isinstance(self._explain, ExplainReport):
            return self._explain.as_dict()
        return dict(self._explain)

    def explain_report(self) -> ExplainReport:
        """The typed report behind :meth:`explain` (PR 9): one stable
        :class:`~repro.relational.observe.ExplainReport` schema instead
        of the ad-hoc dicts of PRs 3-8.  ``explain()`` stays the thin
        dict compat view over this object."""
        if not self._done:
            raise RuntimeError(
                "query still pending — call result(), flush() or poll()")
        if callable(self._explain):
            self._explain = self._explain()
        assert isinstance(self._explain, ExplainReport)
        return self._explain

    def _resolve(self, query_result, explain) -> None:
        self._query_result = query_result
        self._explain = explain
        self._done = True

    def _resolve_error(self, error: "QueryError", explain) -> None:
        self._error = error
        self._explain = explain
        self._done = True

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = ("failed" if self.failed
                 else "done" if self._done else "pending")
        return f"QueryHandle(seq={self.seq}, {state})"


# ---------------------------------------------------------------------------
# the service
# ---------------------------------------------------------------------------
class QueryService:
    """Continuous-submission front-end over a :class:`Session`.

    Windows are cooperative (no threads): deadlines are checked on
    every ``submit`` / ``poll`` / ``result`` call.  ``clock`` is
    injectable for deterministic deadline tests.
    """

    def __init__(self, session, *,
                 max_batch: int = 8,
                 max_wait_s: Optional[float] = None,
                 mqo: Optional[bool] = None,
                 k: Optional[int] = None,
                 locally_optimize: Optional[bool] = None,
                 budget_bytes: Optional[int] = None,
                 clock=time.monotonic):
        mcfg = session.config.mqo
        self.session = session
        self.max_batch = max(1, int(max_batch))
        self.max_wait_s = max_wait_s
        self.mqo = mcfg.enabled if mqo is None else bool(mqo)
        self.k = mcfg.k if k is None else int(k)
        self.locally_optimize = (mcfg.locally_optimize
                                 if locally_optimize is None
                                 else bool(locally_optimize))
        self.budget_bytes = budget_bytes
        self._clock = clock
        self._window = WindowState()
        self._n_windows = 0
        self._n_submitted = 0
        self._last_submit: Optional[float] = None   # inter-arrival EWMA

    # -- observability -------------------------------------------------------
    def telemetry(self):
        """The owning session's
        :class:`~repro.relational.observe.Telemetry` hub."""
        return self.session.telemetry()

    def metrics_report(self) -> dict:
        """The unified observability report (PR 9): registry snapshot,
        per-template-family latency percentiles, pool occupancy + hit
        rates, fault-injector telemetry, and the cost model's
        predicted-vs-actual calibration table."""
        return build_metrics_report(self.session)

    def _span(self, name: str, **attrs):
        tel = getattr(self.session, "_telemetry", None)
        if tel is not None and tel.tracer.enabled:
            return tel.tracer.span(name, **attrs)
        return NOOP_SPAN

    # -- submission ----------------------------------------------------------
    def submit(self, plan, *, tenant: Optional[str] = None) -> QueryHandle:
        """Add one query to the open window (opening one if needed).

        ``plan`` is a :class:`~repro.relational.api.Relation` (raw
        ``logical.Node`` trees remain a deprecated compat shim).
        ``tenant`` labels the query for per-tenant metrics and pool-byte
        attribution (quota *enforcement* lives in the async front).
        Returns immediately with a lazy :class:`QueryHandle`.  If the
        previous window's deadline has passed, it is flushed first (its
        queries were due); if this arrival fills the window to
        ``max_batch``, the window closes inside this call.
        """
        self.flush_expired()
        node, hint = _coerce_submission(plan, "QueryService.submit")
        handle = QueryHandle(self, plan, self._n_submitted, node=node,
                             hint_cache=hint, tenant=tenant)
        now = self._note_submit(handle)
        with self._span("submit", seq=handle.seq):
            if self._window.empty:
                self._window.open(now, self.max_batch, self.max_wait_s)
            self._window.append(handle)
            if self._window.full():
                self.flush()
        return handle

    def _note_submit(self, handle: QueryHandle) -> float:
        """Shared submission bookkeeping (sync front and async front):
        stamp the handle's submit time, advance the submission counter,
        and record the arrival telemetry — ``queries.submitted`` (plus
        the per-tenant labeled child) and the inter-arrival EWMA the
        adaptive window policy feeds on.  Returns the clock reading."""
        now = self._clock()
        handle._t_submit = now
        tel = getattr(self.session, "_telemetry", None)
        if tel is not None:
            tel.registry.inc("queries.submitted")
            if handle.tenant is not None:
                tel.registry.inc("queries.submitted",
                                 labels={"tenant": handle.tenant})
            if self._last_submit is not None:
                tel.registry.ewma("arrival.interval_s").observe(
                    now - self._last_submit)
            self._last_submit = now
        self._n_submitted += 1
        return now

    def poll(self) -> bool:
        """Deadline check: closes the open window if ``max_wait_s`` has
        elapsed.  Returns True when a window ran."""
        return self.flush_expired() is not None

    def flush_expired(self):
        """Close the open window IFF its deadline has passed — the
        cooperative window-closing entry point for callers that are not
        submitting (a server event loop, a background ticker): unlike
        ``flush()`` it never cuts a still-filling window short, and
        unlike ``result()`` it does not block on any handle.  Returns
        the closed window's BatchResult, or None when no window was
        due (no deadline configured, nothing pending, or still within
        ``max_wait_s``)."""
        if self._window.due(self._clock()):
            return self.flush()
        return None

    @property
    def pending(self) -> int:
        return self._window.size

    def flush(self):
        """Close the open window now; resolves its handles.  Returns
        the window's BatchResult, or None when nothing was pending."""
        handles = self._window.detach()
        if not handles:
            return None
        return self._run_window(handles)

    def run_closed(self, plans: Sequence[L.Node], *,
                   mqo: Optional[bool] = None,
                   k: Optional[int] = None,
                   budget_bytes: Any = _UNSET,
                   locally_optimize: Optional[bool] = None):
        """The one-shot path: a pre-closed window over ``plans`` (no
        accumulation, independent of the open window).  This is what
        ``Session.run_batch`` routes through."""
        plans = list(plans)   # the input may be a one-shot iterator
        # plain loop, not a comprehension: comprehension frames differ
        # across Python versions (PEP 709), which would skew the
        # warning's stacklevel.  Frames above the warn: _coerce(1),
        # run_closed(2), run_batch(3), the user's call site(4).
        coerced = []
        for p in plans:
            coerced.append(
                _coerce_submission(p, "Session.run_batch", stacklevel=4))
        handles = [QueryHandle(self, p, -1, node=n, hint_cache=h)
                   for p, (n, h) in zip(plans, coerced)]
        now = self._clock()
        for h in handles:
            h._t_submit = now     # pre-closed: latency == window time
        tel = getattr(self.session, "_telemetry", None)
        if tel is not None:
            tel.registry.inc("queries.submitted", len(handles))
        return self._run_window(handles, mqo=mqo, k=k,
                                budget_bytes=budget_bytes,
                                locally_optimize=locally_optimize)

    # -- internals -----------------------------------------------------------
    def _force(self, handle: QueryHandle) -> None:
        self.flush_expired()
        if not handle._done and self._window.contains(handle):
            self.flush()

    def _family_of(self, node: L.Node) -> str:
        """Loose-ψ template family of one submission, computed exactly
        as ``_run_window_inner`` will (canonicalize, optionally locally
        optimize, loose fingerprint) — the async front's adaptive
        policy keys its arrival-rate EWMAs on this BEFORE the window
        runs."""
        p = canonicalize_plan(node)
        if self.locally_optimize:
            p = canonicalize_plan(optimize_single(p))
        return fingerprint(p).hex()[:12]

    def _run_window(self, handles: List[QueryHandle], *,
                    mqo: Optional[bool] = None,
                    k: Optional[int] = None,
                    budget_bytes: Any = _UNSET,
                    locally_optimize: Optional[bool] = None):
        """Close one window: optimize, execute, resolve every handle.

        Exception safety (PR 6): ``flush()`` detached the window's
        state BEFORE this runs, so the service itself can never be left
        with a half-closed window — the corruption an escaping
        exception used to cause was permanently-unresolved handles.
        The safety net here guarantees every handle resolves to a
        result or a :class:`QueryError` no matter where the window
        died; with isolation off (or on non-Exception unwinds like
        KeyboardInterrupt) the exception still propagates to the
        caller afterwards."""
        sess = self.session
        window = self._n_windows
        self._n_windows += 1
        res = getattr(sess, "resilience", None)
        with self._span("window", window=window,
                        n_queries=len(handles)) as wsp:
            try:
                batch = self._run_window_inner(
                    handles, window, mqo=mqo, k=k,
                    budget_bytes=budget_bytes,
                    locally_optimize=locally_optimize)
            except BaseException as exc:
                wsp.set(error=repr(exc))
                self._resolve_window_error(handles, exc, window)
                self._audit_after_window(sess, res, None)
                if (res is not None and res.isolate
                        and isinstance(exc, Exception)):
                    from .executor import BatchResult

                    batch = BatchResult([None] * len(handles), 0.0)
                    batch.resilience = {"window_error": repr(exc),
                                        "n_failed": len(handles)}
                    return batch
                raise
            self._audit_after_window(sess, res, batch)
            return batch

    def _run_window_inner(self, handles: List[QueryHandle], window: int,
                          *, mqo, k, budget_bytes, locally_optimize):
        from .executor import BatchResult
        from .physical import CEMaterializationError

        sess = self.session
        res = getattr(sess, "resilience", None)
        injector = getattr(sess, "fault_injector", None)
        isolate = res is not None and res.isolate
        mqo = self.mqo if mqo is None else mqo
        k = self.k if k is None else k
        local = (self.locally_optimize if locally_optimize is None
                 else locally_optimize)
        budget_req = (self.budget_bytes if budget_bytes is _UNSET
                      else budget_bytes)

        # the window-close step is itself a named fault point, retried
        # a bounded number of times with backoff (each retry draws a
        # fresh decision from the seeded stream)
        if injector is not None:
            retries = res.window_close_retries if res is not None else 0
            for attempt in range(retries + 1):
                try:
                    injector.check("window_close")
                    break
                except InjectedFault:
                    if attempt >= retries:
                        raise
                    sess._backoff(attempt + 1)

        # The canonicalization pass runs for EVERY plan — builder-made
        # or hand-made — before anything fingerprints, so syntactic
        # variants (shuffled conjuncts, pushed negations, flipped
        # compares, redundant projections) map to one ψ and one strict
        # fingerprint.  It brackets local optimization: equal canonical
        # inputs make the deterministic single-query pass emit equal
        # trees, and the trailing pass restores normal form on whatever
        # that pass rebuilt.  Per-query isolation starts here: one
        # poisoned plan fails only its own handle, and the window
        # optimizes the survivors.
        n = len(handles)
        plans: List[Optional[L.Node]] = [None] * n
        errors: Dict[int, BaseException] = {}
        events: Dict[int, List[DegradationEvent]] = {
            i: [] for i in range(n)}
        with self._span("canonicalize", n_queries=n):
            for i, h in enumerate(handles):
                try:
                    p = canonicalize_plan(h.node)
                    if local:
                        p = canonicalize_plan(optimize_single(p))
                    plans[i] = p
                except Exception as exc:
                    if not isolate:
                        raise
                    errors[i] = exc
        live = [i for i in range(n) if i not in errors]
        tel = getattr(sess, "_telemetry", None)
        if tel is not None:
            # template family = loose structural fingerprint of the
            # canonical plan (the recurring-template key): per-family
            # latency histograms are observed at resolve time
            for i in live:
                handles[i]._family = fingerprint(plans[i]).hex()[:12]

        optimized = None
        ces: list = []
        pre_resident: frozenset = frozenset()
        subsumed: Dict[int, dict] = {}
        executed: List[Optional[L.Node]] = list(plans)
        if not mqo or not live:
            ctx = sess._fresh_ctx()
        else:
            # cache_hint() submissions: every loose ψ under a hinted
            # plan is an SE candidate even with a single consumer,
            # re-priced with a phantom future consumer (see
            # MultiQueryOptimizer.optimize).  Computed only on the MQO
            # path — the Merkle walks would be wasted work otherwise.
            hinted = frozenset()
            for i in live:
                if handles[i].hint_cache:
                    hinted |= fingerprint_set(plans[i])

            budget = budget_req if budget_req is not None else sess.budget
            cache = sess._ce_cache
            if not sess.retain_across_batches:
                # clear BEFORE computing the planning capacity: the
                # freed CE bytes are available to this window's MCKP
                cache.clear()
                sess._resident_index.clear()
                sess._resident_meta.clear()
            else:
                # prune metadata for entries the hierarchy has dropped —
                # these dicts must not grow with the workload's history
                for sfp in [s for s in sess._resident_index
                            if not cache.contains(s)]:
                    del sess._resident_index[sfp]
                for sfp in [s for s in sess._resident_meta
                            if not cache.contains(s)]:
                    del sess._resident_meta[sfp]
            capacity = sess.planning_capacity(budget)
            partitioner = None
            # prune=False must force the UNPRUNED path end to end: CE
            # partitioning both prunes live partitions and executes
            # partition-restricted scans, so the debugging knob
            # disables it
            if sess.prune and any(st.partitions is not None
                                  for st in sess.catalog.values()):
                from .partition import make_ce_partitioner

                partitioner = make_ce_partitioner(sess.catalog)
            optimizer = MultiQueryOptimizer(
                cost_model=sess.cost_model,
                rewriter=RelationalRewriter(fuse_residuals=sess.fuse),
                budget_bytes=capacity,
                k=k,
                ce_transform=make_ce_transform(),
                max_compound_size=sess.config.mqo.max_compound_size,
                chain_cache_plans=sess.config.mqo.chain_cache_plans,
                partitioner=partitioner,
                tracer=(tel.tracer if tel is not None and tel.tracing
                        else None),
            )
            # loose psi -> strict fingerprints of every resident
            # covering relation with that structure (a zero planning
            # budget disables resident reuse — it is the "no caching at
            # all" baseline); partition-grained residents are keyed
            # (strict, pid) and re-priced per partition
            resident: Dict[bytes, Set[bytes]] = {}
            resident_parts: Dict[bytes, frozenset] = {}
            if budget > 0:
                for sfp, psi in sess._resident_index.items():
                    resident.setdefault(psi, set()).add(sfp)
                resident_parts = sess.ce_resident_parts()
            with self._span("mqo", window=window,
                            n_live=len(live)) as msp:
                optimized = optimizer.optimize(
                    [plans[i] for i in live], resident=resident,
                    resident_parts=resident_parts, hinted=hinted)
                msp.set(n_selected=optimized.report.n_selected,
                        selected_weight=optimized.report.selected_weight)

            ces = optimized.rewritten.ces
            # strict keys cannot collide across content, so no
            # stale-entry eviction is needed; record which selected CEs
            # are already materialized BEFORE this window executes
            # (handle.explain).  A partitioned CE counts as resident
            # when ANY of its partitions is (that is what partial
            # residency means).
            pre_resident = frozenset(
                ce.strict_psi() for ce in ces
                if (cache.contains(ce.strict_psi())
                    or (ce.partition_detail is not None
                        and resident_parts.get(ce.strict_psi()))))
            if sess.retain_across_batches:
                for ce in ces:
                    # partitioned CEs are retained per (strict, pid)
                    # cache entry; whole-CE re-pricing would be unsound
                    if ce.partition_detail is None:
                        sess._resident_index[ce.strict_psi()] = ce.psi
                        sess._note_subsumable(ce)
            # -- semantic subsumption (PR 8) ---------------------------
            # Backstop for queries the MQO left UNREWRITTEN (no
            # intra-window sharing, no exact-fingerprint resident): if
            # a retained resident CE's weaker predicate IMPLIES the
            # query's, the query resumes from CachedScan(strict) + the
            # residual conjuncts — reuse with ZERO exact-fingerprint
            # matches.  Running AFTER the optimizer keeps priorities
            # right: a window that can share intra-window or resume
            # exactly still materializes / consumes its own tighter CE
            # (recurring template families keep per-threshold residents
            # side by side), and subsumption picks up only the queries
            # that would otherwise go cold.  The original canonical
            # plan stays in ``plans`` as the CEMaterializationError
            # fallback; the subsumer's covering tree is attached as a
            # recompute plan so eviction mid-window means recompute,
            # not failure.
            sub_plans: Dict[int, L.Node] = {}
            if budget > 0 and getattr(sess.config.mqo, "subsumption",
                                      True):
                for j, i in enumerate(live):
                    if optimized.rewritten.plans[j] is not plans[i]:
                        continue    # MQO already gave it sharing
                    try:
                        hit = sess.find_subsumer(plans[i])
                    except Exception:
                        continue    # lookup is an optimization only
                    if hit is None:
                        continue
                    strict, meta, resid = hit
                    sub_plans[i] = _subsumption_plan(
                        plans[i], strict, meta, resid)
                    attach_recompute_plan(
                        optimized.rewritten, strict,
                        L.Cache(child=meta.tree, psi=strict))
                    subsumed[i] = {
                        "strict_psi": strict.hex()[:12],
                        "residual": repr(E.canonical(resid)),
                    }
            optimized.report.n_subsumed = len(sub_plans)
            ctx = sess._fresh_ctx(cache)
            ctx.cache_plans = dict(optimized.rewritten.cache_plans)
            # execution-side records for partition-grained CEs: which
            # partitions are live, which the MCKP admitted,
            # per-partition benefit shares for the eviction policy
            for ce in ces:
                if ce.partition_detail is None:
                    continue
                pplan, slices = ce.partition_detail
                pplan.admitted = ce.admitted_partitions or frozenset()
                pplan.benefits = {
                    sl.pid: max(float(sl.value), 0.0) for sl in slices}
                ctx.partitioned_ces[ce.strict_psi()] = pplan
            # benefit-per-byte eviction ranks entries by the cost
            # model's savings estimate (Eq. 3 value at admission time)
            ctx.cache_values = {ce.strict_psi(): max(float(ce.value), 0.0)
                                for ce in ces}
            for j, i in enumerate(live):
                executed[i] = optimized.rewritten.plans[j]
            for i, p in sub_plans.items():
                executed[i] = p

        t0 = time.perf_counter()
        results: List[Optional[Any]] = [None] * n
        # window batching (PR 7): same-shape fused pipelines in the
        # window execute as ONE batched mask dispatch; everything else
        # (and every batch failure) falls through to the per-query loop
        batched_done: Set[int] = set()
        shared_dispatch: Dict[int, List[int]] = {}
        with self._span("execute", window=window,
                        n_live=len(live)) as xsp:
            if getattr(sess, "window_batch", True) and len(live) >= 2:
                # the batched dispatch serves several queries at once;
                # attribute its admissions to the first live tenant
                # (first-toucher pays — same rule as shared CEs below)
                first_tenant = next(
                    (handles[i].tenant for i in live
                     if handles[i].tenant is not None), None)
                with _owning(sess, first_tenant):
                    batched_done, shared_dispatch = self._exec_batched(
                        sess, ctx, live, executed, results, events)
            xsp.set(n_batched=len(batched_done))
            for i in live:
                if i in batched_done:
                    continue
                try:
                    with _owning(sess, handles[i].tenant):
                        results[i] = sess.run_one_resilient(
                            executed[i], ctx, query=i, events=events[i])
                except CEMaterializationError as exc:
                    # a shared CE is poisoned: rerun THIS consumer on
                    # its unshared residual plan (the pre-rewrite
                    # canonical tree).  Sibling consumers fail fast on
                    # the poisoned ψ and fall back the same way,
                    # independently.
                    events[i].append(DegradationEvent(
                        query=i, attempt=len(events[i]) + 1,
                        action="fallback", level="residual",
                        error=repr(exc)))
                    try:
                        with _owning(sess, handles[i].tenant):
                            results[i] = sess.run_one_resilient(
                                plans[i], ctx, query=i, events=events[i])
                        executed[i] = plans[i]
                    except Exception as exc2:
                        if not isolate:
                            raise
                        errors[i] = exc2
                except Exception as exc:
                    if not isolate:
                        raise
                    errors[i] = exc
        total = time.perf_counter() - t0

        batch = BatchResult(
            results, total,
            optimize_seconds=(optimized.report.optimize_seconds
                              if optimized is not None else 0.0),
            mqo=optimized,
            cache_report=(sess._ce_cache.report()
                          if optimized is not None else {}),
            metrics=ctx.metrics,
        )
        all_events = [e.as_dict()
                      for i in range(n) for e in events[i]]
        # context-level degradations (e.g. a failed pid bitset read
        # falling back to stats-only pruning) are window-scoped, not
        # attributable to one handle — report them alongside
        all_events += [e.as_dict()
                       for e in getattr(ctx, "degradations", ())]
        rep: Dict[str, Any] = {}
        if all_events:
            rep["events"] = all_events
        if errors or not live:
            rep["n_failed"] = len(errors)
        if injector is not None:
            rep["faults"] = injector.report()
        batch.resilience = rep
        if tel is not None:
            # the ONE place window degradation/retry events and
            # per-window ExecMetrics enter the session-lifetime books
            for ev in all_events:
                tel.record_event(ev)
            tel.absorb_exec_metrics(ctx.metrics)
            tel.registry.inc("windows.closed")
            tel.registry.inc("queries.executed", len(live))
            tel.registry.histogram(
                "window.size",
                edges=tuple(float(x) for x in range(1, 65))).observe(n)
            tel.registry.observe("window.seconds", total)
        ce_by_key = {ce.strict_psi(): ce for ce in ces}
        with self._span("resolve", window=window):
            self._resolve(
                handles, batch, window, mqo=bool(mqo), k=k,
                executed_plans=executed, ce_by_key=ce_by_key,
                pre_resident=pre_resident, errors=errors,
                events=events, ctx=ctx,
                shared_dispatch=shared_dispatch,
                subsumed=subsumed,
                pid_log=dict(getattr(ctx, "pid_prune_log", {})))
        return batch

    @staticmethod
    def _exec_batched(sess, ctx, live, executed, results, events):
        """Window-batched execution step: plan same-shape dispatch
        groups over the window's live plans and run each group as ONE
        batched kernel call.  Returns ``(done positions, {position:
        sorted positions sharing its dispatch})``.  Any failure — the
        ``batched_launch`` fault point, a diverging group, a kernel
        error — degrades the affected queries back to the per-query
        loop (the PR 6 ladder handles them from there); results are
        bit-identical either way, so degradation is invisible to
        callers."""
        from .executor import QueryResult
        from .physical import (CEMaterializationError,
                               execute_window_batched,
                               plan_window_batches)

        done: Set[int] = set()
        shared: Dict[int, List[int]] = {}
        try:
            n_cand, groups = plan_window_batches(
                [(i, executed[i]) for i in live], ctx)
        except Exception:
            # planning must never take the window down — worst case
            # everything stays on the per-query path
            return done, shared
        if n_cand < 2:
            return done, shared
        # the shared dispatch is a named fault point: one check per
        # window with batchable candidates, BEFORE any group runs, so
        # an injected fault degrades the whole window to per-query
        # dispatch without consuming any per-query fault draws
        try:
            ctx.check_fault("batched_launch")
        except InjectedFault as exc:
            for g in groups:
                for m in g:
                    events[m.pos].append(DegradationEvent(
                        query=m.pos, attempt=1, action="degrade",
                        level="per-query", error=repr(exc)))
            return done, shared
        if not groups:
            return done, shared
        tables, seconds, failures = execute_window_batched(groups, ctx)
        for g in groups:
            poss = sorted(m.pos for m in g)
            for m in g:
                if m.pos not in tables:
                    continue
                results[m.pos] = QueryResult(
                    table=tables[m.pos], seconds=seconds[m.pos],
                    plan=executed[m.pos])
                done.add(m.pos)
                shared[m.pos] = poss
        for pos, exc in failures.items():
            if isinstance(exc, CEMaterializationError):
                # poisoned CE: the per-query loop's residual fallback
                # owns this case — not a batching degradation
                continue
            events[pos].append(DegradationEvent(
                query=pos, attempt=1, action="degrade",
                level="per-query", error=repr(exc)))
        return done, shared

    def _resolve(self, handles, batch, window, *, mqo, k,
                 executed_plans, ce_by_key, pre_resident,
                 errors=None, events=None, ctx=None,
                 shared_dispatch=None, subsumed=None,
                 pid_log=None) -> None:
        n = len(handles)
        errors = errors or {}
        events = events or {}
        shared_dispatch = shared_dispatch or {}
        subsumed = subsumed or {}
        pid_log = pid_log or {}
        tel = getattr(self.session, "_telemetry", None)
        now = self._clock() if tel is not None else 0.0
        for i, (h, qr) in enumerate(zip(handles, batch.results)):
            if h._done:
                continue
            failed = i in errors or qr is None
            if tel is not None:
                outcome = "queries.failed" if failed else "queries.succeeded"
                tel.registry.inc(outcome)
                if h.tenant is not None:
                    tel.registry.inc(outcome, labels={"tenant": h.tenant})
                if h._t_submit is not None:
                    lat = max(now - h._t_submit, 0.0)
                    tel.registry.observe("latency.all", lat)
                    if h.tenant is not None:
                        tel.registry.observe("latency.tenant", lat,
                                             labels={"tenant": h.tenant})
                    if h._family:
                        tel.registry.observe(
                            f"latency.family.{h._family}", lat)
            if failed:
                exc = errors.get(i, RuntimeError("query was not executed"))
                err, explain = self._failure_state(
                    h, exc, window, i, n, events.get(i, ()),
                    executed_plans[i], ctx)
                h._resolve_error(err, explain)
                continue
            h._resolve(qr, _LazyExplain(
                h, qr, window, i, n, bool(mqo), k,
                executed_plans[i], ce_by_key, pre_resident,
                shared_dispatch.get(i), subsumed.get(i), pid_log))

    @staticmethod
    def _failure_state(handle, exc, window, position, n, events, plan,
                       ctx):
        """The (QueryError, explain dict) pair for one failed handle:
        the triggering exception, the retry/degradation history, and
        which CEs of its rewritten plan were salvaged (materialized
        despite the failure — reusable by siblings and later windows)
        versus poisoned."""
        evs = [e.as_dict() for e in events]
        salvaged: List[str] = []
        failed_ces: List[str] = []
        cache = getattr(ctx, "cache", None) if ctx is not None else None
        if plan is not None and cache is not None:
            for key in _cached_scan_keys(plan):
                if key in getattr(ctx, "failed_ces", ()):
                    failed_ces.append(key.hex()[:12])
                elif cache.contains(key):
                    salvaged.append(key.hex()[:12])
        err = QueryError(
            exception=exc, window=window, position=position,
            attempts=max([e["attempt"] for e in evs], default=1),
            events=evs, salvaged_ces=salvaged)
        explain = ExplainReport(
            status="failed", window=window, position=position,
            window_size=n, error=repr(exc), events=tuple(evs),
            ces_salvaged=tuple(salvaged), ces_failed=tuple(failed_ces),
            submitted=L.explain(handle.node))
        return err, explain

    def _resolve_window_error(self, handles, exc, window) -> None:
        """Safety net: resolve every still-pending handle of a window
        that died outside the per-query execution loop."""
        n = len(handles)
        tel = getattr(self.session, "_telemetry", None)
        for i, h in enumerate(handles):
            if h._done:
                continue
            if tel is not None:
                tel.registry.inc("queries.failed")
                if h.tenant is not None:
                    tel.registry.inc("queries.failed",
                                     labels={"tenant": h.tenant})
            try:
                submitted = L.explain(h.node)
            except Exception:
                submitted = ""
            h._resolve_error(
                QueryError(exception=exc, window=window, position=i),
                ExplainReport(status="failed", window=window,
                              position=i, window_size=n,
                              error=repr(exc), submitted=submitted))

    @staticmethod
    def _audit_after_window(sess, res, batch) -> None:
        """Post-window pool self-audit: verify the memory invariants
        and repair (quarantine-then-drop) on violation, recording both
        in the window report."""
        if res is None or not res.audit_windows:
            return
        mm = getattr(sess, "memory", None)
        if mm is None or not hasattr(mm, "audit"):
            return
        violations = mm.audit()
        repair = mm.reconcile() if violations else None
        if batch is not None:
            batch.resilience["audit"] = {
                "violations": list(violations),
                "repair": repair,
            }


class _LazyExplain:
    """Deferred explain rendering: holds the window's ingredients and
    builds the report dict on first ``handle.explain()`` call."""

    __slots__ = ("handle", "qr", "window", "position", "window_size",
                 "mqo", "k", "executed_plan", "ce_by_key", "pre_resident",
                 "shared_dispatch", "subsumption", "pid_log")

    def __init__(self, handle, qr, window, position, window_size, mqo, k,
                 executed_plan, ce_by_key, pre_resident,
                 shared_dispatch=None, subsumption=None, pid_log=None):
        self.handle = handle
        self.qr = qr
        self.window = window
        self.position = position
        self.window_size = window_size
        self.mqo = mqo
        self.k = k
        self.executed_plan = executed_plan
        self.ce_by_key = ce_by_key
        self.pre_resident = pre_resident
        # window positions whose queries shared ONE batched mask
        # dispatch with this one (includes this position); None when
        # the query ran on the per-query path
        self.shared_dispatch = shared_dispatch
        # {"strict_psi", "residual"} when this query resumed from a
        # resident CE by predicate subsumption (PR 8); None otherwise
        self.subsumption = subsumption
        # window-level (table, canonical pred) -> partitions the pid
        # bitset intersection pruned beyond statistics
        self.pid_log = pid_log or {}

    def __call__(self) -> ExplainReport:
        ce_reports = []
        for key in _cached_scan_keys(self.executed_plan):
            ce = self.ce_by_key.get(key)
            if ce is None:
                continue           # e.g. full-relation keys (not a CE)
            resident_repriced = bool(ce.cost_detail.get("resident", False))
            entry = ExplainCE(
                psi=ce.psi.hex()[:12],
                strict_psi=key.hex()[:12],
                label=ce.tree.label,
                m=ce.m,
                value=float(ce.value),
                weight=int(ce.weight),
                resident_repriced=resident_repriced,
                cache_hit=key in self.pre_resident,
                single_resume=resident_repriced and ce.m < self.k,
            )
            if ce.partition_detail is not None:
                pplan, _ = ce.partition_detail
                entry.partitions = {
                    "live": list(pplan.live),
                    "admitted": sorted(ce.admitted_partitions or ()),
                }
            ce_reports.append(entry)
        return ExplainReport(
            status="done",
            window=self.window,
            position=self.position,
            window_size=self.window_size,
            mqo=self.mqo,
            seconds=self.qr.seconds,
            plan=L.explain(self.qr.plan),
            submitted=L.explain(self.handle.plan),
            ces=tuple(ce_reports),
            resident_reuse=any(c.cache_hit for c in ce_reports),
            subsumption_hit=self.subsumption is not None,
            pid_pruned_parts=_pid_pruned_for(self.executed_plan,
                                             self.pid_log),
            subsumption=(dict(self.subsumption)
                         if self.subsumption is not None else None),
            shared_dispatch=(list(self.shared_dispatch)
                             if self.shared_dispatch else None),
        )


def _owning(sess, tenant: Optional[str]):
    """Scope ``sess.memory`` admissions to ``tenant`` (no-op context
    when the session has no attribution-capable manager)."""
    mm = getattr(sess, "memory", None)
    if mm is None or not hasattr(mm, "owning"):
        return nullcontext()
    return mm.owning(tenant)


def _subsumption_plan(plan: L.Node, strict: bytes, meta,
                      resid) -> L.Node:
    """CachedScan(resident CE) → residual Filter → Project producing
    exactly ``plan``'s output columns — the subsumption-resume plan
    (mirrors RelationalRewriter.make_extraction; left logical, so
    execution fuses/batches it like any chain)."""
    from .canonical import is_true

    out: L.Node = L.CachedScan(psi=strict, _schema=meta.tree.schema,
                               source_label=meta.tree.label)
    if not is_true(resid):
        out = L.Filter(child=out, pred=resid)
    if tuple(out.schema.names) != tuple(plan.schema.names):
        out = L.Project(child=out, cols=tuple(plan.schema.names))
    return out


def _pid_pruned_for(plan, pid_log) -> int:
    """Partitions the pid-bitset intersection pruned (beyond stats) for
    this query's fused scan+filter, looked up by (table, canonical
    predicate) in the window's prune log; 0 for non-scan plans."""
    if not pid_log or plan is None:
        return 0
    from .fuse import FusedPipeline, fuse_plan

    try:
        node = L.as_node(plan)
        if not isinstance(node, FusedPipeline):
            node = fuse_plan(node)
        if (isinstance(node, FusedPipeline)
                and isinstance(node.source, L.Scan)):
            return int(pid_log.get(
                (node.source.table, E.canonical(node.pred)), 0))
    except Exception:
        pass
    return 0


def _cached_scan_keys(plan: L.Node) -> List[bytes]:
    """Cache keys of every CachedScan the executed plan reads (fused
    pipelines expose their source leaf through ``children``)."""
    keys: List[bytes] = []
    stack = [plan]
    while stack:
        node = stack.pop()
        if isinstance(node, L.CachedScan):
            keys.append(node.psi)
        stack.extend(node.children)
    return keys
