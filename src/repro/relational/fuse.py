"""Pipeline fusion: collapse linear Scan→Filter*→Project chains.

The layer between the logical plan and the physical executor.  The
eager executor materializes a full padded relation per operator and
synchronizes with the host (``int(count)``) after every filter — so a
CE consumer's residual plan (CachedScan → Filter → Project, the
dominant shape after MQO rewriting, and the shape of most TPC-DS leaf
subtrees) pays three dispatches and an intermediate relation for what
is one mask+gather.  ``fuse_plan`` rewrites every maximal such chain
into a single :class:`FusedPipeline` physical node that the executor
runs as ONE jitted call (mask → count → compact → project), routed
through the Pallas filter-scan kernel when the predicate compiles and
through a fused XLA function otherwise.

Fusion is semantics-preserving by construction:

  * filters compose by conjunction — rows surviving ``Filter(p2)`` over
    ``Filter(p1)``'s output are exactly the source rows satisfying
    ``p1 & p2`` (compaction is order-stable, so row order matches the
    eager pipeline too);
  * projections only narrow the column set, and column names are never
    renamed, so the topmost schema fully determines the output;
  * a chain is only fused when every referenced column exists on the
    source leaf (always true for plans built by this engine; checked
    anyway so hand-built plans degrade to the eager path instead of
    miscompiling).

An already-fused node composes: a Filter/Project stacked *above* a
FusedPipeline (e.g. by a later rewrite) folds into it.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple

from . import expr as E
from . import logical as L
from .schema import Schema


@dataclass(frozen=True)
class FusedPipeline(L.Node):
    """Physical node: leaf → Filter* → Project* chain, one jitted call.

    ``source`` is the Scan or CachedScan leaf, ``pred`` the conjunction
    of every filter predicate in the chain (TRUE when the chain was
    projection-only), ``cols`` the output columns in output order.
    """

    source: L.Node = None  # type: ignore[assignment]
    pred: E.Expr = E.TRUE
    cols: Tuple[str, ...] = ()
    n_filters: int = 0     # chain length metadata (cost model / explain)

    @property
    def children(self):
        return (self.source,)

    @property
    def label(self) -> str:
        return "fused"

    @property
    def strict_attrs(self):
        return (E.canonical(self.pred), self.cols)

    @property
    def schema(self) -> Schema:
        return self.source.schema.select(self.cols)

    def with_children(self, children):
        (c,) = children
        cols = tuple(x for x in self.cols if c.schema.has(x))
        return replace(self, source=c, cols=cols)


def _collapse_chain(node: L.Node) -> Optional[FusedPipeline]:
    """Walk Filter/Project links down to a leaf; None when not a chain."""
    out_cols = node.schema.names
    preds = []
    n_filters = 0
    cur = node
    while isinstance(cur, (L.Filter, L.Project)):
        if isinstance(cur, L.Filter):
            # scope check: the predicate must be valid where it stands
            # (a filter on a projected-away column would crash eagerly;
            # fusing it would silently "resolve" against the leaf)
            if not (E.columns_of(cur.pred)
                    <= set(cur.child.schema.names)):
                return None
            if not isinstance(cur.pred, E.TrueExpr):
                preds.append(cur.pred)
            n_filters += 1
        cur = cur.child
    if isinstance(cur, FusedPipeline):
        # absorb: outer filters apply to the fused output, which is an
        # order-preserving subset of the source rows — conjunction over
        # the source is equivalent
        if not isinstance(cur.pred, E.TrueExpr):
            preds.append(cur.pred)
        n_filters += cur.n_filters
        cur = cur.source
    if not isinstance(cur, (L.Scan, L.CachedScan)):
        return None
    if n_filters == 0:
        return None  # pure projection: the eager scan path is already minimal
    pred = E.and_(*preds)
    src_names = set(cur.schema.names)
    if not (set(out_cols) <= src_names
            and E.columns_of(pred) <= src_names):
        return None
    return FusedPipeline(source=cur, pred=pred, cols=tuple(out_cols),
                         n_filters=n_filters)


def fuse_plan(root: L.Node) -> L.Node:
    """Rewrite every maximal fusable chain in ``root`` (top-down)."""
    root = L.as_node(root)
    if isinstance(root, FusedPipeline):
        return root
    if isinstance(root, (L.Filter, L.Project)):
        fused = _collapse_chain(root)
        if fused is not None:
            return fused
    if not root.children:
        return root
    new_children = tuple(fuse_plan(c) for c in root.children)
    if all(nc is c for nc, c in zip(new_children, root.children)):
        return root
    return root.with_children(new_children)


def unfuse_plan(root: L.Node) -> L.Node:
    """Inverse of :func:`fuse_plan`: expand every FusedPipeline back
    into the equivalent eager Filter→Project chain.  The degradation
    ladder's bottom rung (``relational.executor``) runs pre-fused plans
    through this so single-dispatch kernel launches are genuinely off
    the path, not just disabled for future fusion."""
    root = L.as_node(root)
    if isinstance(root, FusedPipeline):
        node: L.Node = unfuse_plan(root.source)
        if not isinstance(root.pred, E.TrueExpr):
            node = L.Filter(child=node, pred=root.pred)
        return L.Project(child=node, cols=root.cols)
    if not root.children:
        return root
    new_children = tuple(unfuse_plan(c) for c in root.children)
    if all(nc is c for nc, c in zip(new_children, root.children)):
        return root
    return root.with_children(new_children)
