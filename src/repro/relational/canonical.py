"""Canonical plan IR: the normalization pass in front of fingerprinting.

The paper's sharing machinery (loose ψ for structure, strict content
fingerprints for cache identity) only pays off when *semantically*
equivalent queries reach it as *syntactically* equal trees.  Clients —
and the fluent :mod:`relational.api` builder — produce many spellings
of the same query: reordered conjuncts, ``Not(x >= 5)`` instead of
``x < 5``, literal-on-left compares, stacked filters, redundant
projections.  This module rewrites every plan into one normal form, so
all those spellings map to ONE ψ and ONE strict fingerprint — and the
MQO can actually share their work.

Expression normal form (:func:`canonicalize_expr`):

  * **negation normal form** — ``Not`` is pushed through ``And``/``Or``
    (De Morgan), double negations cancel, and ``Not(Cmp)`` folds into
    the complementary operator; the only surviving ``Not`` is
    ``Not(TRUE)`` (the engine's FALSE).
  * **orientation** — literal-on-left compares flip to column-on-left
    (``5 < price`` ⇒ ``price > 5``).
  * **constant folding** — Lit-Lit compares evaluate; a false conjunct
    collapses the ``And``, a true disjunct collapses the ``Or``;
    ``TRUE`` conjuncts / ``FALSE`` disjuncts are pruned.
  * **flatten + sort + dedup** — nested ``And``/``Or`` flatten into one
    n-ary node whose parts are deduplicated and sorted by their
    canonical key (commutativity).

Plan normal form (:func:`canonicalize_plan`):

  * every ``Filter`` predicate is canonicalized; ``Filter(TRUE)``
    disappears; adjacent Filters merge into one conjunction.
  * **projection normal form** — duplicate columns are dropped,
    ``Project(Project(x))`` collapses, and an identity projection
    (exactly the child's schema, in order) disappears.

The pass is applied by the service layer to *every* submitted plan —
builder-made or hand-made — before local optimization and
fingerprinting, so legacy ``logical.Node`` trees get the same identity
as their :class:`~repro.relational.api.Relation` equivalents.
"""
from __future__ import annotations

import math
from dataclasses import replace
from typing import List

from . import expr as E
from . import logical as L

#: The engine's FALSE: ``Not(TRUE)`` — representable everywhere ``Not``
#: and ``TrueExpr`` are (eval, pruning, stats), without a new IR node.
FALSE = E.Not(E.TRUE)


def is_true(e: E.Expr) -> bool:
    return isinstance(e, E.TrueExpr)


def is_false(e: E.Expr) -> bool:
    return isinstance(e, E.Not) and isinstance(e.part, E.TrueExpr)


# ---------------------------------------------------------------------------
# expression canonicalization
# ---------------------------------------------------------------------------
def canonicalize_expr(e: E.Expr) -> E.Expr:
    """Rewrite ``e`` into the canonical normal form described above.

    Semantics-preserving on every value the engine can hold: the
    canonical expression evaluates to the same row mask as the
    original (property-tested in tests/test_canonical.py).  The
    ordered-complement fold (``¬(x <= v)`` → ``x > v``) additionally
    assumes totally ordered column domains — IEEE NaN would satisfy
    neither side — which holds because ``build_table_stats`` rejects
    non-finite float columns at registration, the only catalog entry
    point."""
    return _canon(e, negate=False)


def _canon(e: E.Expr, negate: bool) -> E.Expr:
    if isinstance(e, E.TrueExpr):
        return FALSE if negate else E.TRUE
    if isinstance(e, E.Not):
        return _canon(e.part, not negate)      # ¬¬x = x
    if isinstance(e, E.Cmp):
        c = E.oriented(e)
        if negate:
            if _nonfinite_lit(c):
                # IEEE NaN/inf literal: the operator complement is NOT
                # the negation (NaN satisfies neither x>v nor x<=v), so
                # keep the Not node — correctness over normalization
                return E.Not(c)
            c = E.Cmp(E.NEGATE[c.op], c.col, c.rhs)
        if isinstance(c.col, E.Lit) and isinstance(c.rhs, E.Lit):
            return E.TRUE if E.const_cmp(c) else FALSE
        return c
    if isinstance(e, E.In):
        # dedup + sort values by literal key; empty membership is FALSE,
        # a singleton folds to the equivalent ``==`` compare.  There is
        # no complement operator, so a negated multi-value In keeps its
        # Not node (like the non-finite Cmp case above).
        keyed = {E._lit_key(v): v for v in e.values}
        vals = tuple(keyed[k] for k in sorted(keyed))
        if not vals:
            return E.TRUE if negate else FALSE
        if len(vals) == 1:
            return _canon(E.Cmp("==", e.col, E.Lit(vals[0])), negate)
        c = E.In(e.col, vals)
        return E.Not(c) if negate else c
    if isinstance(e, (E.And, E.Or)):
        # De Morgan: ¬(a ∧ b) = ¬a ∨ ¬b  (and dually)
        conj = isinstance(e, E.And) ^ negate
        parts = [_canon(p, negate) for p in e.parts]
        return _normal_nary(parts, conj)
    raise TypeError(type(e))


def _nonfinite_lit(e: E.Cmp) -> bool:
    return any(isinstance(s, E.Lit) and isinstance(s.value, float)
               and not math.isfinite(s.value)
               for s in (e.col, e.rhs))


def _normal_nary(parts: List[E.Expr], conj: bool) -> E.Expr:
    """Flatten / constant-fold / dedup / sort an n-ary And (conj=True)
    or Or (conj=False) over already-canonical parts."""
    absorb, neutral = (is_false, is_true) if conj else (is_true, is_false)
    flat: List[E.Expr] = []
    stack = list(reversed(parts))
    while stack:
        p = stack.pop()
        if isinstance(p, E.And if conj else E.Or):
            stack.extend(reversed(p.parts))
            continue
        if absorb(p):                  # FALSE ∧ … / TRUE ∨ …
            return FALSE if conj else E.TRUE
        if not neutral(p):             # drop TRUE ∧ … / FALSE ∨ …
            flat.append(p)
    keyed = {E.canonical(p): p for p in flat}
    ordered = [keyed[k] for k in sorted(keyed)]
    if not ordered:
        return E.TRUE if conj else FALSE
    if len(ordered) == 1:
        return ordered[0]
    return E.And(tuple(ordered)) if conj else E.Or(tuple(ordered))


# ---------------------------------------------------------------------------
# plan canonicalization
# ---------------------------------------------------------------------------
def canonicalize_plan(node: L.Node) -> L.Node:
    """Rewrite ``node`` (bottom-up) into the plan normal form.

    Accepts anything :func:`logical.as_node` accepts (a Relation or a
    raw Node) and always returns a raw ``logical.Node``."""
    node = L.as_node(node)
    if node.children:
        node = node.with_children(
            tuple(canonicalize_plan(c) for c in node.children))
    if isinstance(node, L.Filter):
        pred = canonicalize_expr(node.pred)
        if is_true(pred):
            return node.child
        if isinstance(node.child, L.Filter):
            # merge stacked filters into one conjunction (their masks
            # compose by ∧ regardless of stacking order)
            merged = _normal_nary([pred, node.child.pred], conj=True)
            return replace(node.child, pred=merged) if not is_true(merged) \
                else node.child.child
        return replace(node, pred=pred)
    if isinstance(node, L.Project):
        # duplicate columns in a legacy hand-built Project denote the
        # same physical relation (executed Tables are dicts keyed by
        # column name, so duplicates collapse anyway); normalizing them
        # away here makes the fingerprint match the bytes actually
        # materialized.  The builder rejects duplicates outright.
        cols, seen = [], set()
        for c in node.cols:
            if c not in seen:
                seen.add(c)
                cols.append(c)
        child = node.child
        if isinstance(child, L.Project):
            child = child.child            # Project∘Project collapses
        if tuple(cols) == tuple(child.schema.names):
            return child                   # identity projection
        return replace(node, child=child, cols=tuple(cols))
    return node


# ---------------------------------------------------------------------------
# plan pretty-printer (Relation.explain_str / QueryHandle.explain)
# ---------------------------------------------------------------------------
def format_plan(node: L.Node, *, show_schema: bool = False) -> str:
    """Human-oriented plan rendering: one node per line, box-drawing
    tree rails, operator attributes inline, optionally each node's
    output schema."""
    node = L.as_node(node)
    lines: List[str] = []

    def detail(n: L.Node) -> str:
        if isinstance(n, L.Scan):
            parts = "" if n.parts is None else f" parts={list(n.parts)}"
            return f"Scan {n.table} [{n.fmt}]{parts}"
        if isinstance(n, L.CachedScan):
            return f"CachedScan ψ={n.psi.hex()[:12]}"
        if isinstance(n, L.Filter):
            return f"Filter {E.pretty(n.pred)}"
        if isinstance(n, L.Project):
            return f"Project {', '.join(n.cols)}"
        if isinstance(n, L.Join):
            keys = ", ".join(f"{a}={b}" for a, b in n.on)
            return f"Join [{keys}]"
        if isinstance(n, L.Aggregate):
            aggs = ", ".join(f"{o}={f}({c or '*'})" for o, f, c in n.aggs)
            by = ", ".join(n.group_by) or "()"
            return f"Aggregate by {by}: {aggs}"
        if isinstance(n, L.Sort):
            return f"Sort {n.by}{' desc' if n.desc else ''}"
        if isinstance(n, L.Limit):
            return f"Limit {n.n}"
        if isinstance(n, L.Union):
            return "Union"
        if isinstance(n, L.Cache):
            return f"Cache ψ={n.psi.hex()[:12]}"
        extra = ""
        if n.label == "fused":   # FusedPipeline without importing fuse
            extra = (f" {E.pretty(n.pred)} → {', '.join(n.cols)}"
                     if n.cols else f" {E.pretty(n.pred)}")
        return f"{type(n).__name__}{extra}"

    def walk(n: L.Node, prefix: str, tail: str) -> None:
        text = detail(n)
        if show_schema:
            text += f"   ⟨{', '.join(n.schema.names)}⟩"
        lines.append(prefix + text)
        kids = n.children
        for i, c in enumerate(kids):
            last = i == len(kids) - 1
            branch = tail + ("└─ " if last else "├─ ")
            walk(c, branch, tail + ("   " if last else "│  "))

    walk(node, "", "")
    return "\n".join(lines)
