"""Canonical plan IR: the normalization pass in front of fingerprinting.

The paper's sharing machinery (loose ψ for structure, strict content
fingerprints for cache identity) only pays off when *semantically*
equivalent queries reach it as *syntactically* equal trees.  Clients —
and the fluent :mod:`relational.api` builder — produce many spellings
of the same query: reordered conjuncts, ``Not(x >= 5)`` instead of
``x < 5``, literal-on-left compares, stacked filters, redundant
projections.  This module rewrites every plan into one normal form, so
all those spellings map to ONE ψ and ONE strict fingerprint — and the
MQO can actually share their work.

Expression normal form (:func:`canonicalize_expr`):

  * **negation normal form** — ``Not`` is pushed through ``And``/``Or``
    (De Morgan), double negations cancel, and ``Not(Cmp)`` folds into
    the complementary operator; the only surviving ``Not`` is
    ``Not(TRUE)`` (the engine's FALSE).
  * **orientation** — literal-on-left compares flip to column-on-left
    (``5 < price`` ⇒ ``price > 5``).
  * **constant folding** — Lit-Lit compares evaluate; a false conjunct
    collapses the ``And``, a true disjunct collapses the ``Or``;
    ``TRUE`` conjuncts / ``FALSE`` disjuncts are pruned.
  * **flatten + sort + dedup** — nested ``And``/``Or`` flatten into one
    n-ary node whose parts are deduplicated and sorted by their
    canonical key (commutativity).

Plan normal form (:func:`canonicalize_plan`):

  * every ``Filter`` predicate is canonicalized; ``Filter(TRUE)``
    disappears; adjacent Filters merge into one conjunction.
  * **interval normal form** (PR 8, schema-aware — it needs the child's
    column types, so it lives in the plan pass, not
    :func:`canonicalize_expr`): conjunctive compares over one numeric
    column range-merge to the tightest bounds (``a > 5 & a > 3`` →
    ``a > 5``), fractional thresholds on integer columns fold through
    the exact :func:`expr.fold_int_cmp` semantics partition pruning
    uses (``qty > 10.5`` ≡ ``qty >= 11``), strict integer bounds
    normalize to inclusive ones (``a > 5`` ≡ ``a >= 6``), and a
    contradictory conjunction (``a > 5 & a < 3``) collapses to
    ``FALSE``.
  * **projection normal form** — duplicate columns are dropped,
    ``Project(Project(x))`` collapses, and an identity projection
    (exactly the child's schema, in order) disappears.

:func:`subsumes` / :func:`subsumption_residual` decide — conservatively,
over the normalized conjunct sets — whether one predicate's rows are a
superset of another's, so the service can resume a query from a
resident covering expression whose predicate is merely *weaker* and
apply only the residual conjuncts (PR 8 semantic reuse).

The pass is applied by the service layer to *every* submitted plan —
builder-made or hand-made — before local optimization and
fingerprinting, so legacy ``logical.Node`` trees get the same identity
as their :class:`~repro.relational.api.Relation` equivalents.
"""
from __future__ import annotations

import math
from dataclasses import replace
from typing import Dict, List, Optional

import numpy as np

from . import expr as E
from . import logical as L

#: The engine's FALSE: ``Not(TRUE)`` — representable everywhere ``Not``
#: and ``TrueExpr`` are (eval, pruning, stats), without a new IR node.
FALSE = E.Not(E.TRUE)


def is_true(e: E.Expr) -> bool:
    return isinstance(e, E.TrueExpr)


def is_false(e: E.Expr) -> bool:
    return isinstance(e, E.Not) and isinstance(e.part, E.TrueExpr)


# ---------------------------------------------------------------------------
# expression canonicalization
# ---------------------------------------------------------------------------
def canonicalize_expr(e: E.Expr) -> E.Expr:
    """Rewrite ``e`` into the canonical normal form described above.

    Semantics-preserving on every value the engine can hold: the
    canonical expression evaluates to the same row mask as the
    original (property-tested in tests/test_canonical.py).  The
    ordered-complement fold (``¬(x <= v)`` → ``x > v``) additionally
    assumes totally ordered column domains — IEEE NaN would satisfy
    neither side — which holds because ``build_table_stats`` rejects
    non-finite float columns at registration, the only catalog entry
    point."""
    return _canon(e, negate=False)


def _canon(e: E.Expr, negate: bool) -> E.Expr:
    if isinstance(e, E.TrueExpr):
        return FALSE if negate else E.TRUE
    if isinstance(e, E.Not):
        return _canon(e.part, not negate)      # ¬¬x = x
    if isinstance(e, E.Cmp):
        c = E.oriented(e)
        if negate:
            if _nonfinite_lit(c):
                # IEEE NaN/inf literal: the operator complement is NOT
                # the negation (NaN satisfies neither x>v nor x<=v), so
                # keep the Not node — correctness over normalization
                return E.Not(c)
            c = E.Cmp(E.NEGATE[c.op], c.col, c.rhs)
        if isinstance(c.col, E.Lit) and isinstance(c.rhs, E.Lit):
            return E.TRUE if E.const_cmp(c) else FALSE
        return c
    if isinstance(e, E.In):
        # dedup + sort values by literal key; empty membership is FALSE,
        # a singleton folds to the equivalent ``==`` compare.  There is
        # no complement operator, so a negated multi-value In keeps its
        # Not node (like the non-finite Cmp case above).
        keyed = {E._lit_key(v): v for v in e.values}
        vals = tuple(keyed[k] for k in sorted(keyed))
        if not vals:
            return E.TRUE if negate else FALSE
        if len(vals) == 1:
            return _canon(E.Cmp("==", e.col, E.Lit(vals[0])), negate)
        c = E.In(e.col, vals)
        return E.Not(c) if negate else c
    if isinstance(e, (E.And, E.Or)):
        # De Morgan: ¬(a ∧ b) = ¬a ∨ ¬b  (and dually)
        conj = isinstance(e, E.And) ^ negate
        parts = [_canon(p, negate) for p in e.parts]
        return _normal_nary(parts, conj)
    raise TypeError(type(e))


def _nonfinite_lit(e: E.Cmp) -> bool:
    return any(isinstance(s, E.Lit) and isinstance(s.value, float)
               and not math.isfinite(s.value)
               for s in (e.col, e.rhs))


def _normal_nary(parts: List[E.Expr], conj: bool) -> E.Expr:
    """Flatten / constant-fold / dedup / sort an n-ary And (conj=True)
    or Or (conj=False) over already-canonical parts."""
    absorb, neutral = (is_false, is_true) if conj else (is_true, is_false)
    flat: List[E.Expr] = []
    stack = list(reversed(parts))
    while stack:
        p = stack.pop()
        if isinstance(p, E.And if conj else E.Or):
            stack.extend(reversed(p.parts))
            continue
        if absorb(p):                  # FALSE ∧ … / TRUE ∨ …
            return FALSE if conj else E.TRUE
        if not neutral(p):             # drop TRUE ∧ … / FALSE ∨ …
            flat.append(p)
    keyed = {E.canonical(p): p for p in flat}
    ordered = [keyed[k] for k in sorted(keyed)]
    if not ordered:
        return E.TRUE if conj else FALSE
    if len(ordered) == 1:
        return ordered[0]
    return E.And(tuple(ordered)) if conj else E.Or(tuple(ordered))


# ---------------------------------------------------------------------------
# interval normal form + subsumption (PR 8)
# ---------------------------------------------------------------------------
#: signed integer bit widths per schema column kind
_INT_BITS = {"i32": 32, "i64": 64}


def conjuncts_of(e: E.Expr) -> List[E.Expr]:
    """Top-level conjunct list of a canonical expression (TRUE → [])."""
    if is_true(e):
        return []
    if isinstance(e, E.And):
        return list(e.parts)
    return [e]


def _num_key(kind: str, v):
    """Comparison-space key of a literal against a numeric column, or
    None when exact interval reasoning is unsound for it.

    Integer columns get the exact Python int — but ONLY in the column's
    representable range: ``eval_expr`` casts literals with
    ``jnp.asarray(v, dtype)``, which WRAPS out-of-range ints, so those
    atoms must stay verbatim.  f32 columns key on ``float(np.float32(v))``
    (the value execution actually compares against): two thresholds that
    round to one f32 are the same predicate, and bound tightness must be
    decided post-rounding or merging could drop a strict bound that
    still excludes rows."""
    if isinstance(v, bool):
        v = int(v)
    if not isinstance(v, (int, float)):
        return None
    if isinstance(v, float) and not math.isfinite(v):
        return None
    if kind in _INT_BITS:
        if isinstance(v, float):
            if not v.is_integer():
                return None            # caller folds via fold_int_cmp
            v = int(v)
        half = 1 << (_INT_BITS[kind] - 1)
        if not -half <= v <= half - 1:
            return None
        return v
    if kind == "f32":
        return float(np.float32(v))
    return None


def _numeric_atom(c: E.Expr, schema):
    """Classify one canonical conjunct for interval reasoning.

    Returns ``(col_name, op, key)`` for an exactly-reasoned numeric
    ``Col op Lit`` compare, the string ``"true"``/``"false"`` when the
    atom folds to a constant (fractional threshold off the integer
    range), or None for everything the machinery must keep verbatim
    (strings, col-col, Or/Not/In, out-of-range ints, NaN)."""
    if not (isinstance(c, E.Cmp) and isinstance(c.col, E.Col)
            and isinstance(c.rhs, E.Lit)):
        return None
    name = c.col.name
    if not schema.has(name):
        return None
    kind = schema.coltype(name).kind
    if kind not in _INT_BITS and kind != "f32":
        return None
    v = c.rhs.value
    if isinstance(v, bool):
        v = int(v)
    if not isinstance(v, (int, float)):
        return None
    if (kind in _INT_BITS and isinstance(v, float)
            and math.isfinite(v) and not v.is_integer()):
        # the ONE shared folding helper (also used by eval_expr and
        # partition._part_maybe; drift is pinned by the shared case
        # table in tests/test_subsumption.py)
        folded = E.fold_int_cmp(c.op, v, bits=_INT_BITS[kind])
        if folded[0] == "all":
            return "true" if folded[1] else "false"
        _, op, b = folded
        key = _num_key(kind, b)
        return None if key is None else (name, op, key)
    key = _num_key(kind, v)
    return None if key is None else (name, c.op, key)


def _add_bound(iv: dict, kind: str, op: str, key) -> None:
    """Fold one atom into the per-column interval state ``iv``
    (keys: lo/hi = (key, strict), eq, neq, ins, false)."""
    if kind in _INT_BITS:
        # integer domains: strict bounds have an exact inclusive form
        # (a > 5 ⟺ a >= 6) — normalizing here makes merging, emission,
        # and implication all operate on one spelling
        half = 1 << (_INT_BITS[kind] - 1)
        if op == ">":
            if key == half - 1:
                iv["false"] = True
                return
            op, key = ">=", key + 1
        elif op == "<":
            if key == -half:
                iv["false"] = True
                return
            op, key = "<=", key - 1
    strict = op in (">", "<")
    if op in (">", ">="):
        cur = iv.get("lo")
        if (cur is None or key > cur[0]
                or (key == cur[0] and strict and not cur[1])):
            iv["lo"] = (key, strict)
    elif op in ("<", "<="):
        cur = iv.get("hi")
        if (cur is None or key < cur[0]
                or (key == cur[0] and strict and not cur[1])):
            iv["hi"] = (key, strict)
    elif op == "==":
        cur = iv.get("eq")
        if cur is not None and cur != key:
            iv["false"] = True
        iv["eq"] = key
    elif op == "!=":
        iv.setdefault("neq", set()).add(key)


def _iv_contradicts(iv: dict) -> bool:
    if iv.get("false"):
        return True
    eq, lo, hi = iv.get("eq"), iv.get("lo"), iv.get("hi")
    neq = iv.get("neq", set())
    if eq is not None:
        if lo and (eq < lo[0] or (eq == lo[0] and lo[1])):
            return True
        if hi and (eq > hi[0] or (eq == hi[0] and hi[1])):
            return True
        return eq in neq
    if lo and hi:
        if lo[0] > hi[0]:
            return True
        if lo[0] == hi[0] and (lo[1] or hi[1] or lo[0] in neq):
            return True
    return False


def _in_keys(e: E.In, kind: str) -> Optional[frozenset]:
    keys = [_num_key(kind, v) for v in e.values]
    if any(k is None for k in keys):
        return None
    return frozenset(keys)


def _summarize(parts: List[E.Expr], schema):
    """Decompose canonical conjuncts into per-column interval state
    plus the verbatim residual.  Returns (ivs, residual, keys, false):
    ``ivs`` maps column → interval dict, ``residual`` holds the atoms
    kept as-is (which still includes In atoms whose keys also land in
    ``ivs[..]["ins"]`` for implication checks), ``keys`` the canonical
    key of every conjunct, ``false`` whether the conjunction is
    unsatisfiable."""
    ivs: Dict[str, dict] = {}
    residual: List[E.Expr] = []
    keys = set()
    false = False
    for p in parts:
        keys.add(E.canonical(p))
        a = _numeric_atom(p, schema)
        if a == "false":
            false = True
            continue
        if a == "true":
            continue
        if a is None:
            if (isinstance(p, E.In) and schema.has(p.col.name)):
                kind = schema.coltype(p.col.name).kind
                if kind in _INT_BITS or kind == "f32":
                    ks = _in_keys(p, kind)
                    if ks is not None:
                        iv = ivs.setdefault(p.col.name, {"kind": kind})
                        iv.setdefault("ins", []).append(ks)
            residual.append(p)
            continue
        name, op, key = a
        iv = ivs.setdefault(name, {"kind": schema.coltype(name).kind})
        _add_bound(iv, iv["kind"], op, key)
    for iv in ivs.values():
        if _iv_contradicts(iv):
            false = True
    return ivs, residual, keys, false


def _emit_atoms(name: str, iv: dict) -> List[E.Expr]:
    """Re-emit one column's merged interval as canonical atoms."""
    col = E.Col(name)
    eq, lo, hi = iv.get("eq"), iv.get("lo"), iv.get("hi")
    neq = iv.get("neq", set())
    if eq is not None:                 # == implies every other bound
        return [E.Cmp("==", col, E.Lit(eq))]
    if (lo and hi and lo[0] == hi[0] and not lo[1] and not hi[1]):
        return [E.Cmp("==", col, E.Lit(lo[0]))]   # degenerate [v, v]
    out: List[E.Expr] = []
    if lo:
        out.append(E.Cmp(">" if lo[1] else ">=", col, E.Lit(lo[0])))
    if hi:
        out.append(E.Cmp("<" if hi[1] else "<=", col, E.Lit(hi[0])))
    for k in sorted(neq):
        inside = not ((lo and (k < lo[0] or (k == lo[0] and lo[1])))
                      or (hi and (k > hi[0] or (k == hi[0] and hi[1]))))
        if inside:                     # outside the interval ⇒ implied
            out.append(E.Cmp("!=", col, E.Lit(k)))
    return out


def normalize_intervals(pred: E.Expr, schema) -> E.Expr:
    """Interval normal form of an already-canonical predicate over the
    given schema: per-column range-merge of its top-level conjuncts,
    schema-aware integer-threshold folding, contradiction → FALSE.
    Bit-identical to ``pred`` on every value the engine can hold
    (property-tested in tests/test_subsumption.py)."""
    parts = conjuncts_of(pred)
    if not parts:
        return pred
    ivs, residual, _, false = _summarize(parts, schema)
    if false:
        return FALSE
    out = list(residual)
    for name in sorted(ivs):
        out.extend(_emit_atoms(name, ivs[name]))
    norm = _normal_nary(out, conj=True)
    return pred if E.canonical(norm) == E.canonical(pred) else norm


def _implied(ivs: dict, keys: set, atom: E.Expr, schema) -> bool:
    """Does the conjunct set summarized as (ivs, keys) imply ``atom``?
    Conservative: False means "could not prove", never "disproved"."""
    if E.canonical(atom) in keys:
        return True
    a = _numeric_atom(atom, schema)
    if a == "true":
        return True
    if a is None or a == "false":
        if (isinstance(atom, E.In) and schema.has(atom.col.name)):
            kind = schema.coltype(atom.col.name).kind
            if kind in _INT_BITS or kind == "f32":
                want = _in_keys(atom, kind)
                iv = ivs.get(atom.col.name)
                if want is not None and iv is not None:
                    if iv.get("eq") is not None and iv["eq"] in want:
                        return True
                    return any(s <= want for s in iv.get("ins", []))
        return False
    name, op, key = a
    iv = ivs.get(name)
    if iv is None:
        return False
    if iv["kind"] in _INT_BITS:
        # same inclusive normalization the summary side applied
        if op == ">":
            op, key = ">=", key + 1
        elif op == "<":
            op, key = "<=", key - 1

    def sat(x) -> bool:
        return {"<": x < key, "<=": x <= key, ">": x > key,
                ">=": x >= key, "==": x == key, "!=": x != key}[op]

    eq = iv.get("eq")
    if eq is not None:
        return sat(eq)
    if any(all(sat(x) for x in s) for s in iv.get("ins", [])):
        return True
    lo, hi = iv.get("lo"), iv.get("hi")
    if op in (">", ">="):
        return lo is not None and (
            lo[0] > key or (lo[0] == key and (lo[1] or op == ">=")))
    if op in ("<", "<="):
        return hi is not None and (
            hi[0] < key or (hi[0] == key and (hi[1] or op == "<=")))
    if op == "==":
        return (lo is not None and hi is not None
                and lo[0] == hi[0] == key and not lo[1] and not hi[1])
    # op == "!=": implied when the interval (or an explicit !=) excludes it
    if key in iv.get("neq", set()):
        return True
    if lo and (key < lo[0] or (key == lo[0] and lo[1])):
        return True
    return bool(hi and (key > hi[0] or (key == hi[0] and hi[1])))


def subsumption_residual(p: E.Expr, q: E.Expr,
                         schema) -> Optional[E.Expr]:
    """If ``p`` subsumes ``q`` — every row satisfying ``q`` satisfies
    ``p`` — return the residual predicate to apply on top of ``p``'s
    rows so that ``p ∧ residual ⟺ q`` (TRUE when q ⟺ p); else None.

    Decision is conservative over the interval-normalized conjunct
    sets: each conjunct of ``p`` must be provably implied by ``q``'s
    conjuncts (exact canonical match, interval containment, ==/In
    membership).  The residual keeps exactly the conjuncts of ``q``
    not already implied by ``p``."""
    p = normalize_intervals(canonicalize_expr(p), schema)
    q = normalize_intervals(canonicalize_expr(q), schema)
    if is_false(q):
        return FALSE                   # vacuous: q selects nothing
    q_parts = conjuncts_of(q)
    q_ivs, _, q_keys, q_false = _summarize(q_parts, schema)
    if q_false:
        return FALSE
    for conj in conjuncts_of(p):
        if not _implied(q_ivs, q_keys, conj, schema):
            return None
    p_ivs, _, p_keys, _ = _summarize(conjuncts_of(p), schema)
    resid = [cq for cq in q_parts
             if not _implied(p_ivs, p_keys, cq, schema)]
    return _normal_nary(resid, conj=True)


def subsumes(p: E.Expr, q: E.Expr, schema) -> bool:
    """True iff rows(q) ⊆ rows(p) is provable (``p`` weaker/equal)."""
    return subsumption_residual(p, q, schema) is not None


# ---------------------------------------------------------------------------
# plan canonicalization
# ---------------------------------------------------------------------------
def canonicalize_plan(node: L.Node) -> L.Node:
    """Rewrite ``node`` (bottom-up) into the plan normal form.

    Accepts anything :func:`logical.as_node` accepts (a Relation or a
    raw Node) and always returns a raw ``logical.Node``."""
    node = L.as_node(node)
    if node.children:
        node = node.with_children(
            tuple(canonicalize_plan(c) for c in node.children))
    if isinstance(node, L.Filter):
        pred = canonicalize_expr(node.pred)
        if is_true(pred):
            return node.child
        if isinstance(node.child, L.Filter):
            # merge stacked filters into one conjunction (their masks
            # compose by ∧ regardless of stacking order)
            merged = _normal_nary([pred, node.child.pred], conj=True)
            if is_true(merged):
                return node.child.child
            out: L.Node = replace(node.child, pred=merged)
        else:
            out = replace(node, pred=pred)
        # interval normal form needs column types — available here
        # (the child's schema), not in the schema-free expression pass
        pred = normalize_intervals(out.pred, out.child.schema)
        if is_true(pred):
            return out.child
        return out if pred is out.pred else replace(out, pred=pred)
    if isinstance(node, L.Project):
        # duplicate columns in a legacy hand-built Project denote the
        # same physical relation (executed Tables are dicts keyed by
        # column name, so duplicates collapse anyway); normalizing them
        # away here makes the fingerprint match the bytes actually
        # materialized.  The builder rejects duplicates outright.
        cols, seen = [], set()
        for c in node.cols:
            if c not in seen:
                seen.add(c)
                cols.append(c)
        child = node.child
        if isinstance(child, L.Project):
            child = child.child            # Project∘Project collapses
        if tuple(cols) == tuple(child.schema.names):
            return child                   # identity projection
        return replace(node, child=child, cols=tuple(cols))
    return node


# ---------------------------------------------------------------------------
# plan pretty-printer (Relation.explain_str / QueryHandle.explain)
# ---------------------------------------------------------------------------
def format_plan(node: L.Node, *, show_schema: bool = False) -> str:
    """Human-oriented plan rendering: one node per line, box-drawing
    tree rails, operator attributes inline, optionally each node's
    output schema."""
    node = L.as_node(node)
    lines: List[str] = []

    def detail(n: L.Node) -> str:
        if isinstance(n, L.Scan):
            parts = "" if n.parts is None else f" parts={list(n.parts)}"
            return f"Scan {n.table} [{n.fmt}]{parts}"
        if isinstance(n, L.CachedScan):
            return f"CachedScan ψ={n.psi.hex()[:12]}"
        if isinstance(n, L.Filter):
            return f"Filter {E.pretty(n.pred)}"
        if isinstance(n, L.Project):
            return f"Project {', '.join(n.cols)}"
        if isinstance(n, L.Join):
            keys = ", ".join(f"{a}={b}" for a, b in n.on)
            return f"Join [{keys}]"
        if isinstance(n, L.Aggregate):
            aggs = ", ".join(f"{o}={f}({c or '*'})" for o, f, c in n.aggs)
            by = ", ".join(n.group_by) or "()"
            return f"Aggregate by {by}: {aggs}"
        if isinstance(n, L.Sort):
            return f"Sort {n.by}{' desc' if n.desc else ''}"
        if isinstance(n, L.Limit):
            return f"Limit {n.n}"
        if isinstance(n, L.Union):
            return "Union"
        if isinstance(n, L.Cache):
            return f"Cache ψ={n.psi.hex()[:12]}"
        extra = ""
        if n.label == "fused":   # FusedPipeline without importing fuse
            extra = (f" {E.pretty(n.pred)} → {', '.join(n.cols)}"
                     if n.cols else f" {E.pretty(n.pred)}")
        return f"{type(n).__name__}{extra}"

    def walk(n: L.Node, prefix: str, tail: str) -> None:
        text = detail(n)
        if show_schema:
            text += f"   ⟨{', '.join(n.schema.names)}⟩"
        lines.append(prefix + text)
        kids = n.children
        for i, c in enumerate(kids):
            last = i == len(kids) - 1
            branch = tail + ("└─ " if last else "├─ ")
            walk(c, branch, tail + ("   " if last else "│  "))

    walk(node, "", "")
    return "\n".join(lines)
