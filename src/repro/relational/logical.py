"""Relational logical plans (the SparkSQL/Catalyst analog).

Nodes conform to :class:`repro.core.plan.PlanNode`:

  * loose operators (fingerprint = label only): ``Scan``, ``Filter``,
    ``Project``, ``CachedScan`` — the ones a *shared operator* can
    subsume across similar subexpressions;
  * strict operators (label + canonical attributes): ``Join``,
    ``Aggregate``, ``Sort``, ``Limit``, ``Union``;
  * cache-unfriendly (never the root of an SE, shared only when
    syntactically equal): ``Join``, ``Union`` (+ implicit cartesian).

Merged (covering) Filter/Project nodes carry the member ``variants`` so
the rewriter can build extraction plans and validity can be checked
(a divergent filter below an Aggregate/Limit cannot be re-extracted).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Tuple

from . import expr as E
from .schema import ColType, Schema


@dataclass(frozen=True)
class Node:
    """Base class; subclasses are immutable dataclasses."""

    # --- PlanNode protocol defaults ---------------------------------------
    @property
    def children(self) -> Tuple["Node", ...]:
        return ()

    @property
    def label(self) -> str:
        raise NotImplementedError

    loose: bool = field(default=False, init=False, repr=False)
    cache_friendly: bool = field(default=True, init=False, repr=False)
    commutative: bool = field(default=False, init=False, repr=False)
    # Can a member's filter be re-applied on top of this operator's output?
    refilter_safe: bool = field(default=True, init=False, repr=False)

    @property
    def strict_attrs(self) -> object:
        return None

    @property
    def content_attrs(self) -> object:
        """Full attribute content for cross-batch strict fingerprints
        (loose nodes override; strict nodes are covered by
        ``strict_attrs``)."""
        return self.strict_attrs

    @property
    def schema(self) -> Schema:
        raise NotImplementedError

    def merge(self, others: Sequence["Node"]) -> "Node":
        return self  # strict nodes: syntactically equal by fingerprint

    def with_children(self, children: Tuple["Node", ...]) -> "Node":
        raise NotImplementedError

    # convenience builder API (DataFrame-style)
    def filter(self, pred: E.Expr) -> "Filter":
        return Filter(self, pred)

    def project(self, *cols: str) -> "Project":
        return Project(self, tuple(cols))

    def join(self, other: "Node", left_on: str, right_on: str) -> "Join":
        return Join(self, other, ((left_on, right_on),))

    def groupby(self, *keys: str):
        return _GroupBy(self, tuple(keys))

    def sort(self, by: str, desc: bool = False) -> "Sort":
        return Sort(self, by, desc)

    def limit(self, n: int) -> "Limit":
        return Limit(self, n)

    def union(self, other: "Node") -> "Union":
        return Union(self, other)


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Scan(Node):
    table: str = ""
    fmt: str = "columnar"          # "columnar" (Parquet analog) | "csv"
    _schema: Schema = None         # type: ignore[assignment]
    # Partition restriction (relational.partition): None scans the whole
    # table; a tuple of partition ids scans only those contiguous row
    # ranges (set by partition pruning and by per-partition CE
    # materialization).  Loose fingerprints ignore it (label only);
    # strict content fingerprints include it so a restricted scan never
    # aliases the full relation.
    parts: Optional[Tuple[int, ...]] = None

    loose = True

    @property
    def label(self) -> str:
        return f"scan:{self.table}:{self.fmt}"

    @property
    def content_attrs(self) -> object:
        return self.parts

    @property
    def schema(self) -> Schema:
        return self._schema

    def with_children(self, children):
        assert not children
        return self

    def merge(self, others):
        return self


@dataclass(frozen=True)
class CachedScan(Node):
    """Leaf reading a relation materialized in the cache (by ψ)."""

    psi: bytes = b""
    _schema: Schema = None  # type: ignore[assignment]
    source_label: str = ""

    loose = True

    @property
    def content_attrs(self) -> object:
        return self.psi

    @property
    def label(self) -> str:
        return f"cached:{self.psi.hex()[:12]}"

    @property
    def schema(self) -> Schema:
        return self._schema

    def with_children(self, children):
        assert not children
        return self


@dataclass(frozen=True)
class Filter(Node):
    child: Node = None  # type: ignore[assignment]
    pred: E.Expr = E.TRUE
    # covering-node metadata: each SE member's original predicate
    variants: Tuple[tuple, ...] = ()   # canonical forms, for divergence test
    variant_preds: Tuple[E.Expr, ...] = ()

    loose = True

    @property
    def children(self):
        return (self.child,)

    @property
    def label(self) -> str:
        return "filter"

    @property
    def schema(self) -> Schema:
        return self.child.schema

    @property
    def divergent(self) -> bool:
        return len(set(self.variants)) > 1

    @property
    def content_attrs(self) -> object:
        return E.canonical(self.pred)

    def with_children(self, children):
        (c,) = children
        return replace(self, child=c)

    def merge(self, others: Sequence["Filter"]) -> "Filter":
        all_nodes = (self, *others)
        merged = E.or_(*(n.pred for n in all_nodes))
        return Filter(
            child=self.child,
            pred=merged,
            variants=tuple(E.canonical(n.pred) for n in all_nodes),
            variant_preds=tuple(n.pred for n in all_nodes),
        )


@dataclass(frozen=True)
class Project(Node):
    child: Node = None  # type: ignore[assignment]
    cols: Tuple[str, ...] = ()
    variants: Tuple[Tuple[str, ...], ...] = ()

    loose = True

    @property
    def children(self):
        return (self.child,)

    @property
    def label(self) -> str:
        return "project"

    @property
    def schema(self) -> Schema:
        return self.child.schema.select(self.cols)

    @property
    def divergent(self) -> bool:
        return len(set(self.variants)) > 1

    @property
    def content_attrs(self) -> object:
        return self.cols

    def with_children(self, children):
        (c,) = children
        # keep only columns the new child still provides (augmentation /
        # extraction rewrites may swap children)
        cols = tuple(c_ for c_ in self.cols if c.schema.has(c_))
        return replace(self, child=c, cols=cols)

    def merge(self, others: Sequence["Project"]) -> "Project":
        all_nodes = (self, *others)
        union_cols, seen = [], set()
        for n in all_nodes:
            for c in n.cols:
                if c not in seen:
                    seen.add(c)
                    union_cols.append(c)
        # deterministic order: child schema order
        child_order = {n: i for i, n in enumerate(self.child.schema.names)}
        union_cols.sort(key=lambda c: child_order.get(c, 1 << 30))
        return Project(
            child=self.child,
            cols=tuple(union_cols),
            variants=tuple(n.cols for n in all_nodes),
        )


@dataclass(frozen=True)
class Join(Node):
    left: Node = None   # type: ignore[assignment]
    right: Node = None  # type: ignore[assignment]
    on: Tuple[Tuple[str, str], ...] = ()   # ((left_col, right_col),)

    cache_friendly = False
    commutative = True

    @property
    def children(self):
        return (self.left, self.right)

    @property
    def label(self) -> str:
        return "join"

    @property
    def strict_attrs(self):
        # side-order independent: {{lcol, rcol}, ...}
        return frozenset(frozenset(p) for p in self.on)

    @property
    def schema(self) -> Schema:
        return self.left.schema.concat(self.right.schema)

    def with_children(self, children):
        l, r = children
        on = self.on
        # keep key orientation consistent if children were swapped
        if not all(l.schema.has(lc) for lc, _ in on):
            on = tuple((rc, lc) for lc, rc in on)
        return replace(self, left=l, right=r, on=on)


@dataclass(frozen=True)
class Aggregate(Node):
    child: Node = None  # type: ignore[assignment]
    group_by: Tuple[str, ...] = ()
    # (output_name, fn, input_col); fn in sum|min|max|count|mean
    aggs: Tuple[Tuple[str, str, str], ...] = ()

    refilter_safe = False   # re-filtering after aggregation is wrong

    @property
    def children(self):
        return (self.child,)

    @property
    def label(self) -> str:
        return "agg"

    @property
    def strict_attrs(self):
        return (tuple(self.group_by), tuple(self.aggs))

    @property
    def schema(self) -> Schema:
        fields = [(g, self.child.schema.coltype(g)) for g in self.group_by]
        for out, fn, col in self.aggs:
            if fn == "count":
                t: ColType = ColType("i32")
            elif fn == "mean":
                t = ColType("f32")
            else:
                t = self.child.schema.coltype(col)
            fields.append((out, t))
        return Schema(tuple(fields))

    def with_children(self, children):
        (c,) = children
        return replace(self, child=c)


@dataclass(frozen=True)
class Sort(Node):
    child: Node = None  # type: ignore[assignment]
    by: str = ""
    desc: bool = False

    @property
    def children(self):
        return (self.child,)

    @property
    def label(self) -> str:
        return "sort"

    @property
    def strict_attrs(self):
        return (self.by, self.desc)

    @property
    def schema(self) -> Schema:
        return self.child.schema

    def with_children(self, children):
        (c,) = children
        return replace(self, child=c)


@dataclass(frozen=True)
class Limit(Node):
    child: Node = None  # type: ignore[assignment]
    n: int = 0

    refilter_safe = False

    @property
    def children(self):
        return (self.child,)

    @property
    def label(self) -> str:
        return "limit"

    @property
    def strict_attrs(self):
        return (self.n,)

    @property
    def schema(self) -> Schema:
        return self.child.schema

    def with_children(self, children):
        (c,) = children
        return replace(self, child=c)


@dataclass(frozen=True)
class Union(Node):
    left: Node = None   # type: ignore[assignment]
    right: Node = None  # type: ignore[assignment]

    cache_friendly = False
    commutative = True

    @property
    def children(self):
        return (self.left, self.right)

    @property
    def label(self) -> str:
        return "union"

    @property
    def strict_attrs(self):
        return ()

    @property
    def schema(self) -> Schema:
        assert self.left.schema.names == self.right.schema.names
        return self.left.schema

    def with_children(self, children):
        l, r = children
        return replace(self, left=l, right=r)


@dataclass(frozen=True)
class Cache(Node):
    """Terminal materialization marker of a sharing (cache) plan."""

    child: Node = None  # type: ignore[assignment]
    psi: bytes = b""

    @property
    def children(self):
        return (self.child,)

    @property
    def label(self) -> str:
        return "cache"

    @property
    def strict_attrs(self):
        return (self.psi,)

    @property
    def schema(self) -> Schema:
        return self.child.schema

    def with_children(self, children):
        (c,) = children
        return replace(self, child=c)


# ---------------------------------------------------------------------------
class _GroupBy:
    def __init__(self, child: Node, keys: Tuple[str, ...]):
        self.child, self.keys = child, keys

    def agg(self, *aggs: Tuple[str, str, str]) -> Aggregate:
        return Aggregate(self.child, self.keys, tuple(aggs))


def scan(table: str, schema: Schema, fmt: str = "columnar") -> Scan:
    return Scan(table=table, fmt=fmt, _schema=schema)


def as_node(obj) -> Node:
    """Coerce a plan-like object to a raw logical Node.

    The fluent :class:`~repro.relational.api.Relation` (and anything
    else wrapping a plan) exposes ``__plan_node__``; raw Nodes pass
    through.  Every plan *sink* (execute, optimize_single, fuse_plan,
    the service/session entry points) funnels through this, so the two
    frontends meet one code path."""
    hook = getattr(obj, "__plan_node__", None)
    if hook is not None:
        return hook()
    if not isinstance(obj, Node):
        raise TypeError(f"not a logical plan: {type(obj).__name__}")
    return obj


def explain(node: Node, indent: int = 0) -> str:
    node = as_node(node)
    pad = "  " * indent
    extra = ""
    if isinstance(node, Filter):
        extra = f" [{E.pretty(node.pred)}]"
    elif isinstance(node, Project):
        extra = f" [{','.join(node.cols)}]"
    elif isinstance(node, Join):
        extra = f" [{node.on}]"
    elif isinstance(node, Aggregate):
        extra = f" [by={node.group_by} aggs={node.aggs}]"
    lines = [f"{pad}{node.label}{extra}"]
    for c in node.children:
        lines.append(explain(c, indent + 1))
    return "\n".join(lines)
