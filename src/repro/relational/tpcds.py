"""TPC-DS-analog star-schema workload (paper §6.2 macro-benchmark).

A scaled-down retail star schema (store_sales fact + item / customer /
store / date_dim dimensions) and a deterministic library of 50 queries
in the style of the TPC-DS templates runnable on this engine
(joins + filters + projections + aggregations).  Queries come in
parameterized template families, so a batch naturally exhibits the
similar-subexpression structure the paper exploits: same operator trees
with different predicates/columns.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from .api import Relation, c
from .executor import Session
from .schema import F32, I32, STR, Schema
from .service import SessionConfig

STORE_SALES = Schema.of(
    ("ss_sold_date_sk", I32), ("ss_item_sk", I32), ("ss_customer_sk", I32),
    ("ss_store_sk", I32), ("ss_quantity", I32),
    ("ss_wholesale_cost", F32), ("ss_list_price", F32),
    ("ss_sales_price", F32), ("ss_ext_sales_price", F32),
    ("ss_net_profit", F32),
)
ITEM = Schema.of(
    ("i_item_sk", I32), ("i_brand_id", I32), ("i_category_id", I32),
    ("i_category", STR(12)), ("i_current_price", F32), ("i_manager_id", I32),
)
CUSTOMER = Schema.of(
    ("c_customer_sk", I32), ("c_birth_year", I32), ("c_birth_month", I32),
    ("c_gender", STR(4)), ("c_preferred", STR(4)),
)
STORE = Schema.of(
    ("s_store_sk", I32), ("s_state", STR(4)), ("s_number_employees", I32),
    ("s_floor_space", I32),
)
DATE_DIM = Schema.of(
    ("d_date_sk", I32), ("d_year", I32), ("d_moy", I32), ("d_dow", I32),
)

CATEGORIES = [b"Books", b"Electronics", b"Home", b"Jewelry", b"Music",
              b"Shoes", b"Sports", b"Toys", b"Women", b"Men"]
STATES = [b"CA", b"TX", b"NY", b"WA", b"GA", b"OH", b"IL", b"MI"]


def _pad(vals: List[bytes], width: int, n: int, rng) -> np.ndarray:
    pool = np.zeros((len(vals), width), np.uint8)
    for i, v in enumerate(vals):
        b = v[:width]
        pool[i, : len(b)] = np.frombuffer(b, np.uint8)
    return pool[rng.integers(0, len(vals), n)]


def generate_tpcds_catalog(scale_rows: int = 100_000, seed: int = 0
                           ) -> Dict[str, Tuple[Schema, int, dict]]:
    """Typed numpy columns for every table; fact table = scale_rows."""
    rng = np.random.default_rng(seed)
    n_item, n_cust, n_store = 2000, 5000, 100
    n_date = 365 * 5

    item = {
        "i_item_sk": np.arange(n_item, dtype=np.int32),
        "i_brand_id": rng.integers(1, 100, n_item).astype(np.int32),
        "i_category_id": rng.integers(1, 11, n_item).astype(np.int32),
        "i_category": _pad(CATEGORIES, 12, n_item, rng),
        "i_current_price": (rng.random(n_item) * 100).astype(np.float32),
        "i_manager_id": rng.integers(1, 50, n_item).astype(np.int32),
    }
    customer = {
        "c_customer_sk": np.arange(n_cust, dtype=np.int32),
        "c_birth_year": rng.integers(1930, 2005, n_cust).astype(np.int32),
        "c_birth_month": rng.integers(1, 13, n_cust).astype(np.int32),
        "c_gender": _pad([b"F", b"M"], 4, n_cust, rng),
        "c_preferred": _pad([b"Y", b"N"], 4, n_cust, rng),
    }
    store = {
        "s_store_sk": np.arange(n_store, dtype=np.int32),
        "s_state": _pad(STATES, 4, n_store, rng),
        "s_number_employees": rng.integers(50, 1000, n_store
                                           ).astype(np.int32),
        "s_floor_space": rng.integers(1000, 100000, n_store
                                      ).astype(np.int32),
    }
    date_dim = {
        "d_date_sk": np.arange(n_date, dtype=np.int32),
        "d_year": (1998 + (np.arange(n_date) // 365)).astype(np.int32),
        "d_moy": (1 + (np.arange(n_date) % 365) // 31).astype(np.int32)
        .clip(1, 12),
        "d_dow": (np.arange(n_date) % 7).astype(np.int32),
    }
    n = scale_rows
    wholesale = (rng.random(n) * 80).astype(np.float32)
    list_price = wholesale * (1.2 + rng.random(n).astype(np.float32))
    sales_price = list_price * (0.5 + 0.5 * rng.random(n)
                                ).astype(np.float32)
    qty = rng.integers(1, 100, n).astype(np.int32)
    store_sales = {
        "ss_sold_date_sk": rng.integers(0, n_date, n).astype(np.int32),
        "ss_item_sk": rng.integers(0, n_item, n).astype(np.int32),
        "ss_customer_sk": rng.integers(0, n_cust, n).astype(np.int32),
        "ss_store_sk": rng.integers(0, n_store, n).astype(np.int32),
        "ss_quantity": qty,
        "ss_wholesale_cost": wholesale,
        "ss_list_price": list_price,
        "ss_sales_price": sales_price,
        "ss_ext_sales_price": sales_price * qty,
        "ss_net_profit": (sales_price - wholesale) * qty,
    }
    return {
        "store_sales": (STORE_SALES, n, store_sales),
        "item": (ITEM, n_item, item),
        "customer": (CUSTOMER, n_cust, customer),
        "store": (STORE, n_store, store),
        "date_dim": (DATE_DIM, n_date, date_dim),
    }


def build_tpcds_session(scale_rows: int = 100_000, fmt: str = "columnar",
                        budget_bytes: int = 1 << 30, seed: int = 0,
                        config: SessionConfig = None,
                        **session_kw) -> Session:
    """``session_kw`` forwards memory-hierarchy knobs (policy,
    host_budget_bytes, retain_across_batches, ...); they are folded
    into a :class:`SessionConfig` here, so this helper stays off the
    deprecated legacy-kwargs path.  A full ``config`` (e.g. one
    carrying resilience/fault-injection settings) takes precedence
    and must not be mixed with legacy knobs."""
    from .datagen import make_storage

    catalog = generate_tpcds_catalog(scale_rows, seed)
    if config is not None:
        assert not session_kw and budget_bytes == 1 << 30, \
            "pass either a full SessionConfig or legacy knobs, not both"
        cfg = config
    else:
        cfg = SessionConfig.from_legacy_kwargs(budget_bytes=budget_bytes,
                                               **session_kw)
    sess = Session.from_config(cfg)
    for name, (schema, nrows, cols) in catalog.items():
        st, _ = make_storage(name, schema, nrows, fmt, cols=cols)
        sess.register(st, columnar_for_stats=cols)
    return sess


# ---------------------------------------------------------------------------
# the 50-query workload (parameterized template families)
# ---------------------------------------------------------------------------
def tpcds_queries(sess: Session) -> List[Relation]:
    """50 deterministic queries over the star schema, written against
    the fluent :class:`Relation` frontend (``where``/``select`` with
    the operator-overloaded ``c`` column namespace).

    Families (≈ TPC-DS query shapes, adapted to the engine's operator
    set): sales-by-category, customer demographics, store performance,
    profitability scans, date-window reports.  Parameters vary inside a
    family, producing loose-identical plans (the paper's SE setting).
    """
    ss = sess.table("store_sales")
    it = sess.table("item")
    cu = sess.table("customer")
    st_ = sess.table("store")
    dd = sess.table("date_dim")

    qs: List[Relation] = []

    # F1 (10 queries): category sales report for a given year
    #   ss ⋈ item (by category filter) ⋈ date (by year) → agg by brand
    for year, cat in [(1998, b"Books"), (1999, b"Books"),
                      (2000, b"Electronics"), (2001, b"Electronics"),
                      (1998, b"Home"), (1999, b"Sports"),
                      (2000, b"Toys"), (2001, b"Music"),
                      (1999, b"Shoes"), (2000, b"Books")]:
        q = (ss.join(it.where(c.i_category == cat),
                     "ss_item_sk", "i_item_sk")
             .join(dd.where(c.d_year == int(year)),
                   "ss_sold_date_sk", "d_date_sk")
             .group_by("i_brand_id")
             .agg(("total_sales", "sum", "ss_ext_sales_price"),
                  ("n", "count", "")))
        qs.append(q)

    # F2 (10 queries): high-value sales scans with price thresholds;
    # the last two are loss-leader scans whose col-col compare now also
    # routes through the fused filter kernel (postfix "ltc" ops)
    for thr in (50, 60, 70, 80, 90, 55, 65, 75):
        q = (ss.where((c.ss_sales_price > float(thr))
                      & (c.ss_quantity >= 10))
             .select("ss_item_sk", "ss_customer_sk", "ss_sales_price",
                     "ss_net_profit"))
        qs.append(q)
    for min_qty in (10, 25):
        q = (ss.where((c.ss_sales_price < c.ss_wholesale_cost)
                      & (c.ss_quantity >= min_qty))
             .select("ss_item_sk", "ss_customer_sk", "ss_sales_price",
                     "ss_net_profit"))
        qs.append(q)

    # F3 (8 queries): customer demographics per gender / birth cohort
    for gender, y0 in [(b"F", 1960), (b"M", 1960), (b"F", 1975),
                       (b"M", 1975), (b"F", 1990), (b"M", 1990),
                       (b"F", 1950), (b"M", 1950)]:
        q = (ss.join(cu.where((c.c_gender == gender)
                              & (c.c_birth_year >= y0)),
                     "ss_customer_sk", "c_customer_sk")
             .group_by("c_birth_year")
             .agg(("spend", "sum", "ss_ext_sales_price")))
        qs.append(q)

    # F4 (8 queries): store performance by state
    for state in STATES:
        q = (ss.join(st_.where(c.s_state == state),
                     "ss_store_sk", "s_store_sk")
             .group_by("s_store_sk")
             .agg(("profit", "sum", "ss_net_profit"),
                  ("vol", "sum", "ss_quantity")))
        qs.append(q)

    # F5 (6 queries): profitability scans (projection-heavy)
    for lo in (0.0, 10.0, 20.0, 30.0, 40.0, 50.0):
        q = (ss.where(c.ss_net_profit > lo)
             .select("ss_item_sk", "ss_net_profit")
             .sort("ss_net_profit", desc=True)
             .limit(100))
        qs.append(q)

    # F6 (8 queries): monthly windows inside a year
    for year, moy in [(1998, 11), (1998, 12), (1999, 11), (1999, 12),
                      (2000, 6), (2000, 7), (2001, 1), (2001, 2)]:
        q = (ss.join(dd.where((c.d_year == year) & (c.d_moy == moy)),
                     "ss_sold_date_sk", "d_date_sk")
             .join(it, "ss_item_sk", "i_item_sk")
             .group_by("i_category_id")
             .agg(("rev", "sum", "ss_ext_sales_price")))
        qs.append(q)

    assert len(qs) == 50
    return qs
