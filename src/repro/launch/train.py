"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs the fault-tolerant trainer on a (reduced or full) config.  On this
CPU container only smoke-scale configs are runnable; full configs are
exercised through the dry-run (``repro.launch.dryrun``).
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="arch id (append -smoke for the reduced config)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--peak-lr", type=float, default=1e-3)
    ap.add_argument("--grad-compress", action="store_true",
                    help="bf16 gradient all-reduce with error feedback")
    args = ap.parse_args()

    from ..configs import get_config
    from ..data.pipeline import DataConfig
    from ..train.optimizer import OptConfig
    from ..train.trainer import TrainerConfig, train

    cfg = get_config(args.arch)
    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch,
        n_prefix_tokens=cfg.n_prefix_tokens, d_model=cfg.d_model)
    opt_cfg = OptConfig(peak_lr=args.peak_lr,
                        decay_steps=max(args.steps, 10))
    tcfg = TrainerConfig(total_steps=args.steps,
                         ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir)
    result = train(cfg, data_cfg, opt_cfg, tcfg)
    print(f"finished at step {result.final_step}"
          + (f" (resumed from {result.resumed_from})"
             if result.resumed_from else ""))
    for m in result.metrics_log[-5:]:
        print(f"step {m['step']:5d} loss {m['loss']:.4f} "
              f"lr {m['lr']:.2e}")


if __name__ == "__main__":
    main()
