"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Brings up the prefix-MQO serving engine on a reduced config and runs a
shared-prefix demo workload (see examples/llm_serving_mqo.py for the
scripted version).
"""
from __future__ import annotations

import argparse
from dataclasses import replace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--pool-budget-kib", type=int, default=4096)
    ap.add_argument("--block-size", type=int, default=32)
    ap.add_argument("--no-mqo", action="store_true")
    args = ap.parse_args()

    import numpy as np

    from ..configs import get_config
    from ..models.model import init_params
    from ..serving.engine import ServingEngine
    from ..serving.request import GenerationRequest

    name = args.arch if args.arch.endswith("-smoke") \
        else args.arch + "-smoke"
    cfg = replace(get_config(name), n_prefix_tokens=0)
    params = init_params(cfg, 0)
    eng = ServingEngine(cfg, params,
                        pool_budget_bytes=args.pool_budget_kib << 10,
                        block_size=args.block_size, max_len=256)

    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, 96)
    reqs = []
    for i in range(args.requests):
        p = np.concatenate(
            [shared, rng.integers(0, cfg.vocab_size, 8 + i)])
        reqs.append(GenerationRequest(i, p.astype(np.int32), 8))

    outs, rep = eng.run_batch(reqs, mqo=not args.no_mqo)
    print(f"served {rep.n_requests} requests; prefix SEs={rep.n_ses} "
          f"admitted={rep.n_selected}")
    print(f"prefill tokens {rep.tokens_prefilled} / baseline "
          f"{rep.tokens_prefilled_baseline} "
          f"(ratio {rep.prefill_token_ratio:.2f}); "
          f"pool {rep.pool_used >> 10} KiB")
    for i, o in enumerate(outs[:4]):
        print(f"req {i}: {o.tolist()}")


if __name__ == "__main__":
    main()
