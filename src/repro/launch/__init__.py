# NOTE: do not import dryrun here — it mutates XLA_FLAGS on import and
# must only be imported as the entry module of a dedicated process.
from .mesh import data_axes, make_production_mesh, make_test_mesh
