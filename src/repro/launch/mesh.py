"""Production mesh construction.

Called as a FUNCTION so importing this module never touches jax device
state.  Single-pod: 16 x 16 = 256 chips ("data", "model"); multi-pod:
2 x 16 x 16 = 512 chips ("pod", "data", "model") — the pod axis is the
slow (DCN) dimension, so sharding rules only ever place the batch on
it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale SPMD tests (host platform devices)."""
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def data_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
