"""Production mesh construction.

Called as a FUNCTION so importing this module never touches jax device
state.  Single-pod: 16 x 16 = 256 chips ("data", "model"); multi-pod:
2 x 16 x 16 = 512 chips ("pod", "data", "model") — the pod axis is the
slow (DCN) dimension, so sharding rules only ever place the batch on
it.
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    """Version-portable mesh construction: newer jax wants explicit
    axis_types (Auto), older jax (< 0.5) has neither AxisType nor the
    axis_types parameter — fall back progressively."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    try:
        return jax.make_mesh(shape, axes)
    except AttributeError:  # pragma: no cover - very old jax
        import numpy as np

        devices = np.asarray(jax.devices()[: int(np.prod(shape))])
        return jax.sharding.Mesh(devices.reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale SPMD tests (host platform devices)."""
    return _mesh(shape, axes)


def data_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
