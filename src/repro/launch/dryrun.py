"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the placeholder device count before ANY other import — jax
locks the device count on first initialization.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs import get_config, list_configs
from ..models.config import ArchConfig
from ..models.decoder import init_cache
from ..models.model import (SHAPES, ShapeCell, decode_step, forward,
                            get_shape, input_specs, loss_fn, model_specs)
from ..models.common import abstract_params
from ..train.optimizer import OptConfig
from ..train.train_step import make_train_step
from .mesh import make_production_mesh
from .roofline import (collective_bytes_from_hlo, roofline_terms,
                       summarize_memory)
from .sharding import (batch_shardings, cache_shardings, opt_state_shardings,
                       param_shardings)

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")

# long_500k needs sub-quadratic attention; skip for pure full-attention
# archs (documented in DESIGN.md §Arch-applicability)
LONG_OK = {"falcon-mamba-7b", "recurrentgemma-9b", "gemma3-1b",
           "gemma3-12b"}


def cell_is_skipped(arch: str, shape: str) -> Optional[str]:
    if shape == "long_500k" and arch not in LONG_OK:
        return "long_500k skipped: pure full-attention arch (quadratic)"
    return None


def _abstract_opt_state(aparams):
    f32 = lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32)
    return {"m": jax.tree.map(f32, aparams),
            "v": jax.tree.map(f32, aparams),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _scan_body_probe(cfg: ArchConfig, cell: ShapeCell, mesh) -> dict:
    """Per-trip cost of the scanned pattern body.

    XLA's HloCostAnalysis counts a while-loop body ONCE regardless of
    trip count (verified empirically), so the full-module numbers
    undercount the (full_repeats - 1) remaining trips.  This probe
    lowers one pattern application (and its VJP for train cells) with
    the same shardings and returns the per-trip flops/bytes/collective
    bytes to add back.  (The time-axis lax.scan inside Mamba/RG-LRU
    bodies is elementwise-dominated and left uncorrected; noted in
    EXPERIMENTS.md.)
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..models.decoder import (_block_decode, _kind_cache,
                                  block_forward, block_specs)

    if cfg.full_repeats <= 1:
        return {"flops": 0.0, "bytes": 0.0, "coll": 0.0, "trips": 1}

    body_specs = {str(p): block_specs(cfg, kind, cfg.ffn_kind)
                  for p, kind in enumerate(cfg.pattern)}
    ab_params = abstract_params(body_specs)
    p_sh = param_shardings(body_specs, cfg, mesh)
    b, t = cell.global_batch, cell.seq_len
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    x_spec = P(batch_axes) if b > 1 else P()
    dt = jnp.dtype(cfg.dtype)

    if cell.step == "decode":
        ax = jax.ShapeDtypeStruct((b, 1, cfg.d_model), dt)
        acache = jax.eval_shape(
            lambda: {str(p): _kind_cache(cfg, kind, b, t, dt)
                     for p, kind in enumerate(cfg.pattern)})
        c_sh = cache_shardings(cfg, cell, mesh, acache)

        def body(lp, cache, x):
            ncs = {}
            for p_i, kind in enumerate(cfg.pattern):
                x, nc = _block_decode(lp[str(p_i)], x, cache[str(p_i)],
                                      jnp.int32(1), cfg, kind,
                                      cfg.ffn_kind, dt)
                ncs[str(p_i)] = nc
            return x, ncs

        fn = jax.jit(body, in_shardings=(p_sh, c_sh,
                                         NamedSharding(mesh, x_spec)))
        compiled = fn.lower(ab_params, acache, ax).compile()
        cost = compiled.cost_analysis() or {}
        coll = collective_bytes_from_hlo(compiled.as_text())["total"]
        return {"flops": cost.get("flops", 0.0),
                "bytes": cost.get("bytes accessed", 0.0),
                "coll": coll, "trips": cfg.full_repeats}

    ax = jax.ShapeDtypeStruct((b, t, cfg.d_model), dt)
    positions = jnp.arange(t, dtype=jnp.int32)

    def fwd(lp, x):
        for p_i, kind in enumerate(cfg.pattern):
            x = block_forward(lp[str(p_i)], x, cfg, kind, cfg.ffn_kind,
                              positions, dt)
        return x

    x_sh = NamedSharding(mesh, x_spec)
    fwd_c = jax.jit(fwd, in_shardings=(p_sh, x_sh)).lower(
        ab_params, ax).compile()
    cost_f = fwd_c.cost_analysis() or {}
    coll_f = collective_bytes_from_hlo(fwd_c.as_text())["total"]
    flops = cost_f.get("flops", 0.0)
    bytes_ = cost_f.get("bytes accessed", 0.0)
    coll = coll_f

    if cell.step == "train":
        def vjp_body(lp, x, ct):
            _, pull = jax.vjp(fwd, lp, x)
            return pull(ct)

        vjp_c = jax.jit(vjp_body, in_shardings=(p_sh, x_sh, x_sh)).lower(
            ab_params, ax, ax).compile()
        cost_b = vjp_c.cost_analysis() or {}
        # with remat the loop executes fwd (1) + recompute-fwd + bwd
        # (vjp probe) per trip; without remat, just the vjp probe.
        if cfg.remat in ("block", "full"):
            flops += cost_b.get("flops", 0.0)
            bytes_ += cost_b.get("bytes accessed", 0.0)
            coll += collective_bytes_from_hlo(vjp_c.as_text())["total"]
        else:
            flops = cost_b.get("flops", 0.0)
            bytes_ = cost_b.get("bytes accessed", 0.0)
            coll = collective_bytes_from_hlo(vjp_c.as_text())["total"]
    return {"flops": flops, "bytes": bytes_, "coll": coll,
            "trips": cfg.full_repeats}


def lower_cell(cfg: ArchConfig, cell: ShapeCell, mesh,
               logits_sharded: bool = False,
               kv_seq_model: bool = False):
    """Build (fn, abstract args, in_shardings) for one cell."""
    specs = model_specs(cfg)
    aparams = abstract_params(specs)
    p_sh = param_shardings(specs, cfg, mesh)

    if cell.step == "train":
        abatch = input_specs(cfg, cell)
        b_sh = batch_shardings(cfg, cell, mesh, abatch)
        aopt = _abstract_opt_state(aparams)
        o_sh = opt_state_shardings(p_sh, mesh)
        step = make_train_step(cfg, OptConfig())
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     donate_argnums=(0, 1))
        return fn, (aparams, aopt, abatch)

    if cell.step == "prefill":
        abatch = input_specs(cfg, cell)
        b_sh = batch_shardings(cfg, cell, mesh, abatch)

        def prefill(params, batch):
            return forward(params, batch["tokens"], cfg,
                           batch.get("prefix_embeds"))

        fn = jax.jit(prefill, in_shardings=(p_sh, b_sh))
        return fn, (aparams, abatch)

    # decode: one token against a seq_len-long cache
    acache = jax.eval_shape(
        lambda: init_cache(cfg, cell.global_batch, cell.seq_len))
    c_sh = cache_shardings(cfg, cell, mesh, acache,
                           kv_seq_model=kv_seq_model)
    atoken = input_specs(cfg, cell)["token"]
    t_sh = batch_shardings(cfg, cell, mesh, {"token": atoken})["token"]
    cur = jax.ShapeDtypeStruct((), jnp.int32)

    def serve_step(params, cache, token, cur_len):
        return decode_step(params, cache, token, cur_len, cfg)

    out_sh = None
    if logits_sharded:
        # keep logits vocab-sharded on the way out: downstream sampling
        # (argmax/top-k) runs shard-local + a tiny reduce instead of
        # all-gathering (B, V)
        from jax.sharding import NamedSharding, PartitionSpec as P

        batch_axes = tuple(a for a in ("pod", "data")
                           if a in mesh.axis_names)
        b = cell.global_batch
        n_b = 1
        for a in batch_axes:
            n_b *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
        logit_sh = NamedSharding(
            mesh, P(batch_axes if b % n_b == 0 and b > 1 else None,
                    "model" if cfg.vocab_size % dict(
                        zip(mesh.axis_names,
                            mesh.devices.shape))["model"] == 0
                    else None))
        out_sh = (logit_sh, c_sh)

    fn = jax.jit(serve_step,
                 in_shardings=(p_sh, c_sh, t_sh, None),
                 out_shardings=out_sh,
                 donate_argnums=(1,))
    return fn, (aparams, acache, atoken, cur)


def run_cell(arch: str, shape: str, multi_pod: bool,
             save: bool = True, variant: str = "",
             options: Optional[dict] = None) -> dict:
    """options (perf-iteration knobs):
      shard_acts: bool       — activation sharding constraints
                               (tokens/experts/batch/vocab)
      remat: "none"|"block"  — override activation checkpoint policy
      capacity_factor: float — MoE expert-capacity override
      fsdp: bool             — override ZeRO-3 param sharding
    """
    from contextlib import ExitStack
    from dataclasses import replace as dc_replace

    from ..models.common import activation_sharding

    options = options or {}
    cell = get_shape(shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    result = {"arch": arch, "shape": shape, "mesh": mesh_name,
              "variant": variant or "baseline", "options": options,
              "status": "ok"}
    skip = cell_is_skipped(arch, shape)
    if skip:
        result["status"] = "skipped"
        result["reason"] = skip
        _save(result, save)
        return result

    cfg = get_config(arch)
    if "remat" in options:
        cfg = dc_replace(cfg, remat=options["remat"])
    if "capacity_factor" in options:
        cfg = dc_replace(cfg, capacity_factor=options["capacity_factor"])
    if "fsdp" in options:
        cfg = dc_replace(cfg, fsdp_params=options["fsdp"])
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    t0 = time.time()
    try:
        with ExitStack() as stack:
            axes = {}
            if options.get("shard_acts"):
                axes.update(tokens=batch_axes, batch=batch_axes,
                            vocab="model")
                if options["shard_acts"] != "tokens":
                    # "full": also pin expert slots to the model axis
                    axes["experts"] = "model"
            if options.get("moe_ep"):
                axes["moe_ep"] = (batch_axes, "model")
            if axes:
                stack.enter_context(activation_sharding(mesh, **axes))
            fn, args = lower_cell(cfg, cell, mesh,
                                  logits_sharded=bool(
                                      options.get("logits_sharded")),
                                  kv_seq_model=bool(
                                      options.get("kv_seq_model")))
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            cost = compiled.cost_analysis() or {}
            mem = summarize_memory(compiled)
            hlo = compiled.as_text()
            coll = collective_bytes_from_hlo(hlo)

            # correct for while-body single-counting (see probe docstring)
            probe = _scan_body_probe(cfg, cell, mesh)
            extra = probe["trips"] - 1
            flops = cost.get("flops", 0.0) + extra * probe["flops"]
            bytes_ = (cost.get("bytes accessed", 0.0)
                      + extra * probe["bytes"])
            coll_total = coll["total"] + extra * probe["coll"]

        total, active = cfg.param_count()
        tokens = cell.global_batch * (1 if cell.step == "decode"
                                      else cell.seq_len)
        result.update({
            "chips": n_chips,
            "lower_seconds": round(t_lower, 1),
            "compile_seconds": round(t_compile, 1),
            "flops_per_device": flops,
            "bytes_accessed_per_device": bytes_,
            "collective_bytes_per_device": coll_total,
            "flops_raw_hlo": cost.get("flops", 0.0),
            "scan_body_probe": probe,
            "collectives": coll["by_op"],
            "collective_counts": coll.get("op_counts", {}),
            "memory": mem,
            "params_total": total,
            "params_active": active,
            "tokens_per_step": tokens,
            "step_kind": cell.step,
        })
        result["roofline"] = roofline_terms(result)
    except Exception as e:
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
    _save(result, save)
    return result


def _save(result: dict, save: bool):
    if not save:
        return
    os.makedirs(REPORT_DIR, exist_ok=True)
    name = f"{result['arch']}__{result['shape']}__{result['mesh']}"
    if result.get("variant") and result["variant"] != "baseline":
        name += f"__{result['variant']}"
    with open(os.path.join(REPORT_DIR, name + ".json"), "w") as f:
        json.dump(result, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true",
                    help="re-run cells that already have a report")
    args = ap.parse_args()

    archs = list_configs() if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                out = os.path.join(
                    REPORT_DIR, f"{arch}__{shape}__{mesh_name}.json")
                if not args.force and os.path.exists(out):
                    with open(out) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[cached ] {arch} {shape} {mesh_name}: "
                              f"{prev['status']}")
                        continue
                t0 = time.time()
                r = run_cell(arch, shape, mp)
                dom = (r.get("roofline") or {}).get("dominant", "-")
                print(f"[{r['status']:7s}] {arch} {shape} {mesh_name} "
                      f"({time.time()-t0:.0f}s) dominant={dom}",
                      flush=True)
                if r["status"] == "error":
                    print("   ", r["error"].splitlines()[0][:200],
                          flush=True)


if __name__ == "__main__":
    main()
