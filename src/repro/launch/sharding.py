"""Sharding rules: logical parameter axes -> mesh axes.

Parameters carry logical axes from their ParamSpec (("embed", "ffn"),
("experts", "embed", "ffn"), ...).  Rules map logical names to mesh
axes per arch/cell:

  * TP: heads / ffn / vocab / experts -> "model"
  * DP: batch -> ("pod", "data")
  * FSDP (MoE giants, cfg.fsdp_params): the weights' "embed" axis ->
    "data" (ZeRO-3: params + optimizer state sharded; all-gathered on
    use by the partitioner)
  * SP (long_500k): the KV/state cache sequence dim -> "data"

Every assignment is guarded by divisibility-or-large (dim >= axis
size); an axis is used at most once per spec.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.common import ParamSpec
from ..models.config import ArchConfig
from ..models.model import ShapeCell


def logical_rules(cfg: ArchConfig, mesh: Mesh) -> Dict[str, tuple]:
    batch = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    rules: Dict[str, tuple] = {
        "batch": batch,
        "vocab": ("model",),
        "heads": ("model",),
        "ffn": ("model",),
        "experts": ("model",),
        "embed": ("data",) if cfg.fsdp_params else (),
        "layers": (),
        "seq": (),
    }
    return rules


def _spec_for(shape: Tuple[int, ...], logical: Tuple[Optional[str], ...],
              rules: Dict[str, tuple], mesh: Mesh) -> P:
    used = set()
    out = []
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, name in zip(shape, logical):
        axes = rules.get(name, ()) if name else ()
        chosen = []
        prod = 1
        for ax in axes:
            if ax in used or ax not in sizes:
                continue
            # jit argument shardings require exact divisibility (e.g.
            # internvl2's vocab 92553 cannot shard 16-way; a production
            # deployment would pad the vocab — we keep the assignment's
            # exact config and replicate instead)
            if dim % (sizes[ax] * prod) == 0:
                chosen.append(ax)
                used.add(ax)
                prod *= sizes[ax]
        if not chosen:
            out.append(None)
        elif len(chosen) == 1:
            out.append(chosen[0])
        else:
            out.append(tuple(chosen))
    return P(*out)


def param_shardings(specs_tree, cfg: ArchConfig, mesh: Mesh):
    """Pytree of NamedShardings matching the ParamSpec tree."""
    rules = logical_rules(cfg, mesh)

    def one(s: ParamSpec):
        return NamedSharding(mesh,
                             _spec_for(s.shape, s.logical_axes, rules,
                                       mesh))

    return jax.tree.map(one, specs_tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def opt_state_shardings(param_sh, step_leaf_mesh: Mesh):
    """m/v mirror the params; the step counter is replicated."""
    return {
        "m": param_sh,
        "v": param_sh,
        "step": NamedSharding(step_leaf_mesh, P()),
    }


def batch_shardings(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh,
                    abstract_batch) -> dict:
    """Inputs: shard dim 0 (batch) over (pod, data); long-context decode
    with batch=1 falls back to replication (the cache carries SP)."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_batch = int(np.prod([sizes[a] for a in batch_axes]))

    def one(a):
        if a.ndim >= 1 and a.shape[0] % n_batch == 0 and a.shape[0] > 1:
            return NamedSharding(mesh, P(batch_axes))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, abstract_batch)


def cache_shardings(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh,
                    abstract_cache, kv_seq_model: bool = False):
    """Decode-cache sharding, keyed on the cache-leaf names:

      k/v     GQA KV (B, H, S, D)  -> batch->(pod,data), H->model;
                                      batch=1 (long_500k) -> S->data (SP)
      ckv,
      k_rope  MLA latent (B, S, r) -> batch->(pod,data) or S->data (SP)
      conv,
      ssm     (B, d, k)            -> batch, d->model
      h       (B, d)               -> batch, d->model

    Leaves under the scanned-repeats subtree carry a leading layers dim
    (replicated).
    """
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_batch = int(np.prod([sizes[a] for a in batch_axes]))
    n_model = sizes.get("model", 1)
    data_only = tuple(a for a in batch_axes if a == "data")

    def core_spec(name: str, core: Tuple[int, ...]):
        b_ok = core[0] % n_batch == 0 and core[0] > 1
        batch_sp = batch_axes if b_ok else None
        if name in ("k", "v"):                      # (B, H, S, D)
            h_ok = core[1] % n_model == 0 and core[1] >= n_model
            s_ok = (not b_ok) and data_only and core[2] % sizes["data"] == 0
            if kv_seq_model and not h_ok and core[2] % n_model == 0:
                # MQA/kv=1 archs: heads can't split — sequence-shard
                # the cache over the model axis (distributed
                # flash-decode; partial softmax + small reduce)
                return (batch_sp, None, "model", None)
            return (batch_sp, "model" if h_ok else None,
                    data_only if s_ok else None, None)
        if name in ("ckv", "k_rope"):               # (B, S, r)
            s_ok = (not b_ok) and data_only and core[1] % sizes["data"] == 0
            return (batch_sp, data_only if s_ok else None, None)
        if name in ("conv", "ssm"):                 # (B, d, k)
            d_ok = core[1] % n_model == 0
            return (batch_sp, "model" if d_ok else None, None)
        if name == "h":                             # (B, d)
            d_ok = core[1] % n_model == 0
            return (batch_sp, "model" if d_ok else None)
        return tuple(None for _ in core)

    def one(path, a):
        keys = [str(getattr(p, "key", "")) for p in path]
        name = keys[-1]
        scanned = "scan" in keys
        core = a.shape[1:] if scanned else a.shape
        spec = core_spec(name, core)
        if scanned:
            spec = (None,) + spec
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, abstract_cache)
