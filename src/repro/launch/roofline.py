"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw_per_chip
    collective = collective_bytes_per_device / ICI_link_bw

cost_analysis() of an SPMD-partitioned module reports PER-DEVICE
flops/bytes (the module is one replica's program), so no extra /chips.
collective_bytes is parsed from the optimized HLO: the summed operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (per-device local shapes, post-partitioning).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                "all-to-all", "collective-permute")

# e.g.  %all-reduce.5 = f32[512,1024]{1,0} all-reduce(...)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every dtype[shape] group in a result type."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict:
    """Parse optimized HLO; returns {'total': int, 'by_op': {op: bytes}}."""
    by_op: Dict[str, int] = {}
    count: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        for op in _COLLECTIVES:
            # match the op as the instruction, not inside metadata
            marker = f" {op}("
            if marker not in line and f" {op}-start(" not in line:
                continue
            lhs = line.split("=", 1)
            if len(lhs) != 2:
                continue
            # result type sits between '=' and the op name
            rhs = lhs[1]
            idx = rhs.find(op)
            result_type = rhs[:idx]
            b = _shape_bytes(result_type)
            by_op[op] = by_op.get(op, 0) + b
            count[op] = count.get(op, 0) + 1
            break
    return {"total": sum(by_op.values()), "by_op": by_op,
            "op_counts": count}


def summarize_memory(compiled) -> Dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        live = (out.get("argument_size_in_bytes", 0)
                + out.get("output_size_in_bytes", 0)
                + out.get("temp_size_in_bytes", 0)
                - out.get("alias_size_in_bytes", 0))
        out["peak_bytes_per_device_est"] = live
    return out


def roofline_terms(cell: Dict) -> Dict:
    """cell: a dry-run result dict (per-device quantities)."""
    flops = float(cell.get("flops_per_device") or 0.0)
    bytes_ = float(cell.get("bytes_accessed_per_device") or 0.0)
    coll = float(cell.get("collective_bytes_per_device") or 0.0)
    chips = int(cell.get("chips") or 1)

    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_ / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())

    # MODEL_FLOPS = 6·N_active·D (training) / 2·N_active·D (inference)
    n_active = float(cell.get("params_active") or 0.0)
    tokens = float(cell.get("tokens_per_step") or 0.0)
    mult = 6.0 if cell.get("step_kind") == "train" else 2.0
    model_flops = mult * n_active * tokens
    model_flops_per_dev = model_flops / max(chips, 1)
    useful = model_flops_per_dev / flops if flops else 0.0

    # roofline fraction: useful model FLOPs per device per second at
    # the bound, over peak
    step_time = max(bound, 1e-12)
    mfu = model_flops_per_dev / step_time / PEAK_FLOPS

    return {
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "bound_s": round(bound, 6),
        "model_flops_total": model_flops,
        "useful_flops_ratio": round(useful, 4),
        "roofline_fraction_mfu": round(mfu, 4),
    }
