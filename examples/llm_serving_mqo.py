"""LLM-serving scenario: prefix-cache MQO over a few-shot workload.

Requests sharing few-shot prompt templates are batched; the engine
fingerprints token-block chains, admits shared prefixes into the HBM
pool via the multiple-choice knapsack, and serves every request from
the longest admitted prefix.  Generations are bit-identical to the
unoptimized path.

    PYTHONPATH=src python examples/llm_serving_mqo.py [--arch granite-8b]
"""
import argparse
import os
import sys
from dataclasses import replace

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--budget-kib", type=int, default=4096)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models.model import init_params
    from repro.serving.engine import ServingEngine
    from repro.serving.request import GenerationRequest

    cfg = replace(get_config(args.arch + "-smoke"), n_prefix_tokens=0)
    params = init_params(cfg, 0)
    eng = ServingEngine(cfg, params,
                        pool_budget_bytes=args.budget_kib << 10,
                        block_size=32, max_len=256)

    rng = np.random.default_rng(0)
    templates = [rng.integers(0, cfg.vocab_size, 96) for _ in range(3)]

    def workload():
        reqs = []
        for i in range(args.requests):
            t = templates[i % len(templates)]
            p = np.concatenate(
                [t, rng.integers(0, cfg.vocab_size, 8 + i)])
            reqs.append(GenerationRequest(i, p.astype(np.int32), 8))
        return reqs

    base, base_rep = eng.run_batch(workload(), mqo=False)
    rng = np.random.default_rng(0)  # same workload again
    templates = [rng.integers(0, cfg.vocab_size, 96) for _ in range(3)]
    opt, rep = eng.run_batch(workload(), mqo=True)

    same = all((a == b).all() for a, b in zip(base, opt))
    print(f"arch={args.arch}-smoke  requests={rep.n_requests}")
    print(f"generations identical: {same}")
    print(f"shared prefixes found: {rep.n_ses}, admitted: "
          f"{rep.n_selected} (pool {rep.pool_used >> 10} / "
          f"{rep.pool_budget >> 10} KiB)")
    print(f"prefill tokens: {rep.tokens_prefilled} vs baseline "
          f"{rep.tokens_prefilled_baseline} "
          f"(ratio {rep.prefill_token_ratio:.2f})")
    print(f"wall: {rep.wall_seconds:.2f}s vs {base_rep.wall_seconds:.2f}s")


if __name__ == "__main__":
    main()
