"""Quickstart: the paper's running example (§3) end to end, written
against the fluent lazy :class:`Relation` frontend (PR 5).

Three HR queries share scans, filters and a join; queries are composed
with the operator-overloaded column namespace ``c`` (``c.salary >
20000``, combined with ``&``/``|``/``~``), compiled through the
canonical plan IR — so any syntactic spelling of the same query maps
to one fingerprint — and optimized as a batch: the multi-query
optimizer finds the similar subexpressions, builds covering sharing
plans, selects them under a memory budget via the multiple-choice
knapsack, rewrites the batch, and the engine executes it with the
covering relations cached in (device) RAM.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.relational import (I32, STR, MemoryConfig, Partitioning,
                              QueryService, Schema, Session, SessionConfig,
                              c, make_storage)


def build_catalog(sess: Session, seed: int = 7):
    rng = np.random.default_rng(seed)
    n_emp, n_dept, n_sal = 20_000, 60, 40_000
    gender = np.zeros((n_emp, 4), np.uint8)
    gender[:, 0] = np.where(rng.random(n_emp) < 0.5, ord("F"), ord("M"))
    loc = np.zeros((n_dept, 4), np.uint8)
    us = rng.random(n_dept) < 0.5
    loc[us, 0], loc[us, 1] = ord("u"), ord("s")
    loc[~us, 0], loc[~us, 1] = ord("f"), ord("r")
    tables = {
        "employees": (Schema.of(
            ("emp_id", I32), ("name", STR(12)), ("gender", STR(4)),
            ("age", I32), ("dep", I32)), n_emp, {
            "emp_id": np.arange(n_emp, dtype=np.int32),
            "name": rng.integers(97, 123, (n_emp, 12)).astype(np.uint8),
            "gender": gender,
            "age": rng.integers(18, 65, n_emp).astype(np.int32),
            "dep": rng.integers(0, n_dept, n_emp).astype(np.int32)}),
        "departments": (Schema.of(
            ("dept_id", I32), ("dept_name", STR(12)),
            ("location", STR(4))), n_dept, {
            "dept_id": np.arange(n_dept, dtype=np.int32),
            "dept_name": rng.integers(97, 123, (n_dept, 12)
                                      ).astype(np.uint8),
            "location": loc}),
        "salaries": (Schema.of(
            ("sal_emp_id", I32), ("salary", I32), ("from_year", I32)),
            n_sal, {
            "sal_emp_id": rng.integers(0, n_emp, n_sal).astype(np.int32),
            "salary": rng.integers(10_000, 90_000, n_sal
                                   ).astype(np.int32),
            "from_year": rng.integers(2000, 2020, n_sal
                                      ).astype(np.int32)}),
    }
    for name, (schema, nrows, cols) in tables.items():
        st, _ = make_storage(name, schema, nrows, "csv", cols=cols)
        if name == "salaries":
            # horizontal range partitioning (PR 4): rows re-clustered
            # into 8 contiguous salary ranges with per-partition
            # min/max/NDV stats — selective salary filters then PRUNE
            # partitions before scanning, and covering expressions over
            # the table can be cached partition by partition (the MCKP
            # keeps the hot fraction when the whole CE doesn't fit)
            sess.register(st, columnar_for_stats=cols,
                          partitioning=Partitioning(
                              column="salary", scheme="range",
                              n_partitions=8))
        else:
            sess.register(st, columnar_for_stats=cols)


def main():
    # one frozen config instead of the legacy knob sprawl (the old
    # keyword arguments still work as deprecation shims)
    sess = Session.from_config(SessionConfig(
        memory=MemoryConfig(budget_bytes=64 << 20)))
    build_catalog(sess)
    emp, dept, sal = (sess.table("employees"), sess.table("departments"),
                      sess.table("salaries"))

    # lazy, immutable Relations: nothing executes until a sink is hit
    q1 = (emp.where(c.gender == "F")
          .join(dept.where(c.location == "us"), "dep", "dept_id")
          .join(sal.where(c.salary > 20000), "emp_id", "sal_emp_id")
          .select("name", "dept_name", "salary")
          .sort("salary", desc=True))
    q2 = (emp.where(c.gender == "F")
          .join(dept.where(c.location == "us"), "dep", "dept_id")
          .join(sal.where(c.from_year >= 2010), "emp_id", "sal_emp_id")
          .select("name", "dept_name", "from_year"))
    q3 = (emp.where(c.age > 30)
          .join(sal.where(c.salary > 30000), "emp_id", "sal_emp_id")
          .select("emp_id", "name", "salary", "from_year"))

    print("=== query 1 (canonical logical plan) ===")
    print(q1.explain_str(show_schema=True))

    # any spelling of the same predicate compiles to the same
    # fingerprint: literal-on-left, pushed negation, swapped conjuncts
    q1_variant = (emp.where(~(c.gender != "F"))
                  .join(dept.where("us" == c.location), "dep", "dept_id")
                  .join(sal.where(20000 < c.salary), "emp_id",
                        "sal_emp_id")
                  .select("name", "dept_name", "salary")
                  .sort("salary", desc=True))
    same = (q1.logical_plan() == q1_variant.logical_plan())
    print(f"\nsyntactic variant canonicalizes identically: {same}")

    base = sess.run_batch([q1, q2, q3], mqo=False)
    opt = sess.run_batch([q1, q2, q3], mqo=True)

    r = opt.mqo.report
    print(f"\nSEs found: {r.n_ses}   CEs built: {r.n_ces}   "
          f"selected: {r.n_selected}   "
          f"cache weight: {r.selected_weight / 1024:.0f} KiB "
          f"(budget {r.budget >> 20} MiB)")
    print(f"optimize time: {r.optimize_seconds * 1e3:.1f} ms")
    for i, (b, o) in enumerate(zip(base.results, opt.results)):
        same = b.table.row_multiset() == o.table.row_multiset()
        print(f"q{i + 1}: rows={o.table.nrows:6d} identical={same} "
              f"runtime {b.seconds:.3f}s -> {o.seconds:.3f}s")
    print(f"aggregate: {base.total_seconds:.3f}s -> "
          f"{opt.total_seconds:.3f}s "
          f"({opt.total_seconds / base.total_seconds:.2f}x)")

    # -- the online front-end: continuous submission, lazy handles ------
    # clients submit at any time; the service closes a micro-batch
    # window on count (here), deadline, or flush(), runs the MQO per
    # window, and re-prices still-resident covering relations as
    # already-paid — a recurring query resumes from cache.
    svc = QueryService(sess, max_batch=3)
    h1, h2, h3 = svc.submit(q1), svc.submit(q2), svc.submit(q3)
    print(f"\nQueryService: window closed on count, "
          f"h1 rows={h1.result().nrows}")
    e = h1.explain()
    print(f"h1 explain: window={e['window']} ces={len(e['ces'])} "
          f"resident_reuse={e['resident_reuse']}")

    # -- partition pruning on the partitioned table ---------------------
    # salaries is range-partitioned on salary: a selective filter scans
    # only the partitions whose [min, max] can satisfy it
    info = sess.stats.partitions["salaries"]
    high_pay = c.salary > 80_000
    from repro.relational import prune_parts

    live = prune_parts(high_pay.expr, info)
    print(f"\npartitioned scan: salary>80000 touches "
          f"{len(live)}/{info.n_partitions} partitions {list(live)}")
    top = (sal.where(high_pay).select("sal_emp_id", "salary")).collect()
    print(f"rows={top.nrows} (pruned scan, bit-identical to unpruned)")

    # -- pid cache (PR 8): execution history prunes where stats can't ---
    # a needle predicate on NON-partition columns: every partition's
    # min/max covers both atoms, so stats refute nothing — but the
    # first execution records WHICH partitions actually produced rows
    # (a per-predicate bitset in the tiny `pid` memory pool), and the
    # repeat run intersects against it and scans only those
    needle = (c.from_year == 2001) & (c.sal_emp_id < 50)
    nq = sal.where(needle).select("sal_emp_id", "salary")
    first = sess.run_batch([nq], mqo=False)
    again = sess.run_batch([nq], mqo=False)
    same = (first.results[0].table.row_multiset()
            == again.results[0].table.row_multiset())
    print(f"pid pool: run 1 recorded {first.metrics.pid_records} "
          f"bitset(s); run 2 hit {again.metrics.pid_hits} and pruned "
          f"{again.metrics.pid_pruned_parts}/{info.n_partitions} "
          f"partitions (identical rows: {same})")

    # -- semantic subsumption (PR 8): resume from a WEAKER resident CE --
    # a window of identical broad queries materializes a covering
    # expression for age >= 30; a later STRICTLY STRONGER query — never
    # seen before, so no exact-fingerprint reuse is possible — is
    # recognized (after the window's MQO leaves it unrewritten) as
    # IMPLIED by the resident predicate and resumes from the cached CE,
    # applying only the residual conjuncts
    weak = emp.where(c.age >= 30).select("emp_id", "age", "dep")
    for h in [svc.submit(weak) for _ in range(3)]:
        h.result()
    strong = emp.where((c.age >= 45) & (c.dep < 20)).select("emp_id",
                                                            "age")
    hp = svc.submit(strong)
    svc.flush()
    ex = hp.explain()
    sub = ex.get("subsumption", {})
    print(f"subsumption: hit={ex['subsumption_hit']} "
          f"exact_ce_hit={ex['resident_reuse']} "
          f"rows={hp.result().nrows}")
    print(f"  resumes from CE {sub.get('strict_psi')} "
          f"with residual {sub.get('residual')}")

    # -- async serving front (PR 10): concurrent clients, one session ---
    # the asyncio front takes concurrent submissions on the event loop,
    # a BACKGROUND task closes deadline windows (no caller needs to be
    # in flight), and admission control charges each tenant's in-flight
    # count and attributed pool bytes against its quota.  Execution
    # still funnels through the one sync window path, so results are
    # bit-identical to run_batch / QueryService.
    import asyncio

    from repro.relational import (AsyncConfig, AsyncQueryService,
                                  TenantQuota)

    async def serve():
        cfg = AsyncConfig(
            max_batch=3, max_wait_s=0.05,
            quotas={"dash": TenantQuota(max_inflight=8),
                    "adhoc": TenantQuota(max_inflight=1,
                                         on_over="queue")})
        async with AsyncQueryService(sess, config=cfg) as asvc:
            handles = [await asvc.submit(q, tenant="dash")
                       for q in (q1, q2, q3)]
            ha = await asvc.submit(q3, tenant="adhoc")
            tables = [await h for h in handles] + [await ha]
            return tables, asvc.metrics_report()

    atabs, arep = asyncio.run(serve())
    same = all(a.row_multiset() == b.table.row_multiset()
               for a, b in zip(atabs[:3], opt.results))
    print(f"\nasync front: {len(atabs)} queries over 2 tenants, "
          f"bit-identical to the batch run: {same}")
    for t, row in sorted(arep["tenants"].items()):
        print(f"  tenant {t}: submitted="
              f"{row.get('queries.submitted', 0):.0f} "
              f"bytes={row.get('bytes_total', 0)}B "
              f"admission={row.get('admission')}")


if __name__ == "__main__":
    main()
