"""End-to-end training driver: train a ~100M-param LM for a few hundred
steps on CPU with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py \
        [--arch granite-8b] [--steps 300] [--width 512]
"""
import argparse
import os
import sys
from dataclasses import replace

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--width", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data.pipeline import DataConfig
    from repro.train.optimizer import OptConfig
    from repro.train.trainer import TrainerConfig, train

    base = get_config(args.arch + "-smoke")
    cfg = replace(
        base, name=f"{args.arch}-train-demo",
        d_model=args.width, n_heads=max(4, args.width // 64),
        n_kv_heads=max(2, args.width // 128), head_dim=64,
        d_ff=args.width * 4, vocab_size=4096,
        n_layers=args.layers, n_prefix_tokens=0, dtype="float32")
    total, active = cfg.param_count()
    print(f"training {cfg.name}: {total / 1e6:.1f}M params "
          f"({active / 1e6:.1f}M active), {args.steps} steps")

    data_cfg = DataConfig(vocab_size=cfg.vocab_size,
                          seq_len=args.seq_len,
                          global_batch=args.batch, seed=0)
    opt_cfg = OptConfig(peak_lr=3e-3, warmup_steps=20,
                        decay_steps=args.steps)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=100,
                         ckpt_dir=args.ckpt_dir, log_every=20)
    result = train(cfg, data_cfg, opt_cfg, tcfg)
    if result.resumed_from is not None:
        print(f"(resumed from checkpoint step {result.resumed_from})")
    for m in result.metrics_log:
        print(f"step {m['step']:4d}  loss {m['loss']:.4f}  "
              f"lr {m['lr']:.2e}  |g| {m['grad_norm']:.3f}  "
              f"{m['step_seconds'] * 1e3:.0f} ms/step")
    first = result.metrics_log[0]["loss"]
    last = result.metrics_log[-1]["loss"]
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'no improvement'})")


if __name__ == "__main__":
    main()
