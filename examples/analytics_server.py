"""Analytics-server scenario: the TPC-DS-analog workload served ONLINE
through the QueryService (paper §5's accumulate-optimize-execute server,
PR 3's continuous-submission front-end), with queries composed in the
fluent :class:`Relation` frontend (PR 5).

Clients submit lazy Relations one at a time; the service accumulates
them into micro-batch windows (closed by count here), compiles every
submission through the canonical plan IR — so differently-spelled
equivalent queries share one fingerprint — runs the multi-query
optimizer per window with resident-CE re-pricing, and resolves lazy
handles.  A recurring dashboard pass is compared against (a) the same
queries with MQO off and (b) the cold first pass — showing both
within-window sharing and cross-window resident reuse.  A final
section demonstrates the canonicalization contract: a builder-made
query and a differently-spelled hand-built ``logical.Node`` tree of
the same semantics land on the SAME covering expression.

A resilience section (PR 6) then replays a dashboard window under
deterministic fault injection: transient faults recover invisibly
(retry / one rung down the degradation ladder, logged per attempt),
while a query driven past ``max_attempts`` resolves its OWN handle to
a ``QueryError`` — siblings complete, ``result()`` re-raises,
``explain()`` carries the post-mortem, and the memory-pool audit stays
clean.

A final telemetry section (PR 9) replays a warm dashboard pass with
span tracing enabled, dumps a Perfetto-loadable Chrome trace of the
query lifecycle, and prints the unified ``metrics_report()``: query
counters, per-template latency percentiles, pool hit rates, and the
cost model's predicted-vs-actual calibration table.

    PYTHONPATH=src python examples/analytics_server.py \
        [--window 12] [--max-batch 4] [--passes 3]
"""
import argparse
import os
import sys
import time
import warnings

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--window", type=int, default=12,
                    help="queries per dashboard pass (capped at the "
                         "16-query F2+F5 template pool)")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="micro-batch window size (count trigger)")
    ap.add_argument("--passes", type=int, default=3,
                    help="recurring dashboard passes (first is cold)")
    ap.add_argument("--scale-rows", type=int, default=80_000)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.relational import QueryService, c, expr as E
    from repro.relational.tpcds import build_tpcds_session, tpcds_queries

    sess = build_tpcds_session(scale_rows=args.scale_rows,
                               budget_bytes=1 << 30)
    qs = tpcds_queries(sess)
    # a recurring dashboard draws from template FAMILIES (the paper's
    # SE setting): interleave the scan-heavy F2 (high-value sales) and
    # F5 (profitability) families so every window holds similar shapes
    rng = np.random.default_rng(args.seed)
    pool = list(range(10, 20)) + list(range(36, 42))   # F2 + F5
    idx = rng.permutation(pool)[: min(args.window, len(pool))]
    dashboard = [qs[i] for i in idx]
    print(f"dashboard of {len(dashboard)} queries: "
          f"{sorted(idx.tolist())}, window size {args.max_batch}")

    # baseline: same queries, no worksharing
    base = sess.run_batch(dashboard, mqo=False)

    svc = QueryService(sess, max_batch=args.max_batch)
    pass_seconds = []
    reuse_counts = []
    for p in range(args.passes):
        t0 = time.perf_counter()
        handles = [svc.submit(q) for q in dashboard]
        svc.flush()                       # close the trailing window
        pass_seconds.append(time.perf_counter() - t0)
        reuse_counts.append(
            sum(1 for h in handles if h.explain()["resident_reuse"]))
        if p == 0:
            for b, h in zip(base.results, handles):
                assert (b.table.row_multiset()
                        == h.result().row_multiset())
            ex = handles[0].explain()
            print(f"first handle explain: window={ex['window']} "
                  f"pos={ex['position']} ces={len(ex['ces'])} "
                  f"reuse={ex['resident_reuse']}")

    cold, warm = pass_seconds[0], min(pass_seconds[1:] or pass_seconds)
    print(f"queries with resident-CE reuse per pass: {reuse_counts}")
    print(f"no-MQO baseline: {base.total_seconds:.2f}s   "
          f"cold windowed pass: {cold:.2f}s   "
          f"warm windowed pass: {warm:.2f}s")
    print(f"aggregate ratio (warm windowed / no-MQO): "
          f"{warm / base.total_seconds:.2f}")
    print(f"warm speedup over cold: {cold / max(warm, 1e-9):.2f}x")

    # -- canonicalization recovers sharing across query spellings -------
    # the same semantics three ways: fluent builder, fluent builder
    # with flipped/negated/shuffled predicates, and a hand-assembled
    # legacy logical.Node tree (accepted as a deprecated shim)
    ss = sess.table("store_sales")
    q_builder = (ss.where((c.ss_sales_price > 50.0)
                          & (c.ss_quantity >= 10))
                 .select("ss_item_sk", "ss_sales_price"))
    q_variant = (ss.where(~(c.ss_quantity < 10)
                          & (50.0 < c.ss_sales_price))
                 .select("ss_item_sk", "ss_sales_price"))
    raw_scan = sess.scan_node("store_sales")
    q_legacy = (raw_scan
                .filter(E.and_(E.cmp("ss_quantity", ">=", 10),
                               E.cmp("ss_sales_price", ">", 50.0)))
                .project("ss_item_sk", "ss_sales_price"))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        h1, h2, h3 = (svc.submit(q_builder), svc.submit(q_variant),
                      svc.submit(q_legacy))
        svc.flush()
    keys = [{ce["strict_psi"] for ce in h.explain()["ces"]}
            for h in (h1, h2, h3)]
    print(f"\nmixed-spelling window: builder/variant/legacy CE keys "
          f"equal = {keys[0] == keys[1] == keys[2]} "
          f"(shared CE provenance: {sorted(keys[0])})")

    # -- error handles and degradation reporting (PR 6) -----------------
    # the same dashboard window on a session with deterministic fault
    # injection: a seeded 10% transient rate at the kernel-launch and
    # H2D points.  Transient faults recover invisibly — retried in
    # place or one rung down the Pallas → fused-XLA → eager ladder —
    # and every step lands in the window report.
    from repro.core.faults import FaultConfig
    from repro.relational import MemoryConfig, SessionConfig

    fcfg = (SessionConfig(memory=MemoryConfig(budget_bytes=1 << 30))
            .with_faults(FaultConfig(seed=args.seed, rates={
                "kernel_launch": 0.10, "scan_h2d": 0.10})))
    fsess = build_tpcds_session(scale_rows=args.scale_rows, config=fcfg)
    fsvc = QueryService(fsess, max_batch=args.max_batch)
    fhandles = [fsvc.submit(q) for q in tpcds_queries(fsess)[10:14]]
    fsvc.flush()
    rep = fsess.fault_injector.report()
    print(f"\nfaulted window: {rep['n_fired']} faults fired "
          f"{rep['fired']}, "
          f"failed handles: {sum(h.failed for h in fhandles)}/4 "
          f"(transient faults recover without failing queries)")

    # drive one query past max_attempts: a scheduled fault kills the
    # first query's first two H2D transfers (attempts 1 and 2), so its
    # handle resolves to a QueryError — the window's other query, whose
    # transfers draw later schedule indices, is untouched
    hard = (SessionConfig(memory=MemoryConfig(budget_bytes=1 << 30))
            .with_resilience(max_attempts=2)
            .with_faults(FaultConfig(seed=args.seed,
                                     schedule={"scan_h2d": (0, 1)})))
    hsess = build_tpcds_session(scale_rows=args.scale_rows, config=hard)
    hsvc = QueryService(hsess, max_batch=2)
    h_doomed = hsvc.submit(hsess.table("store_sales")
                           .where(c.ss_sales_price > 60.0)
                           .select("ss_item_sk"))
    h_fine = hsvc.submit(hsess.table("store_sales")
                         .where(c.ss_quantity >= 20)
                         .select("ss_item_sk"))
    hsvc.flush()
    err = h_doomed.error
    ex = h_doomed.explain()
    print(f"doomed handle: failed={h_doomed.failed} after "
          f"{err.attempts} attempts — {err.exception!r}")
    print("  attempt log:",
          [f"{e['action']}->{e['level']}" for e in ex["events"]])
    try:
        h_doomed.result()
    except Exception as exc:
        print(f"  result() re-raises: {type(exc).__name__}")
    print(f"sibling handle unaffected: "
          f"{h_fine.result().nrows} rows; "
          f"memory audit clean = {hsess.memory.audit() == []}")

    # -- window-batched shared dispatch (PR 7) ---------------------------
    # a recurring template family: four same-SHAPE filters whose
    # literals change every window.  The executor hoists the literals
    # into operand arrays and runs the whole window as ONE batched mask
    # dispatch — ``explain()`` names the window positions that shared
    # it — and the compiled program is keyed by plan shape, so window 2
    # (fresh literals) re-traces nothing.
    from repro.relational import MqoConfig

    wb_cfg = SessionConfig(memory=MemoryConfig(budget_bytes=1 << 30),
                           mqo=MqoConfig(enabled=False))
    wsess = build_tpcds_session(scale_rows=args.scale_rows, config=wb_cfg)
    wsvc = QueryService(wsess, max_batch=4)
    print()
    for w in range(2):
        tpl = [wsess.table("store_sales")
               .where((c.ss_quantity > 5 + 3 * i + w)
                      & (c.ss_quantity < 80 - 2 * i))
               .select("ss_item_sk", "ss_quantity") for i in range(4)]
        whs = [wsvc.submit(q) for q in tpl]
        wsvc.flush()
        ex = whs[0].explain()
        print(f"batched window {w}: shared_dispatch="
              f"{ex.get('shared_dispatch')} "
              f"({sum(h.result().nrows for h in whs)} rows out, "
              f"literals fresh, one kernel launch for the window)")

    # -- semantic subsumption (PR 8) -------------------------------------
    # drill-down serving: the dashboard's broad filter stays resident,
    # and every follow-up narrows it with FRESH literals — no exact
    # fingerprint ever repeats, so resident re-pricing (PR 3) can't
    # fire.  Subsumption recognizes each drill-down the window's MQO
    # left unrewritten as IMPLIED by the weaker resident CE and resumes
    # from it, applying only the residual conjuncts.
    dsess = build_tpcds_session(scale_rows=args.scale_rows,
                                budget_bytes=1 << 30)
    dsvc = QueryService(dsess, max_batch=4)
    broad = (dsess.table("store_sales")
             .where(c.ss_sales_price > 40.0)
             .select("ss_item_sk", "ss_sales_price", "ss_quantity"))
    for h in [dsvc.submit(broad) for _ in range(3)]:
        h.result()                    # window materializes the broad CE
    dsvc.flush()
    print()
    for k in range(3):
        drill = (dsess.table("store_sales")
                 .where((c.ss_sales_price > 52.0 + k)
                        & (c.ss_quantity >= 11 + k))
                 .select("ss_item_sk", "ss_sales_price"))
        dh = dsvc.submit(drill)
        dsvc.flush()
        dx = dh.explain()
        sub = dx.get("subsumption", {})
        print(f"drill-down {k}: subsumption_hit={dx['subsumption_hit']} "
              f"exact_ce_hit={dx['resident_reuse']} "
              f"rows={dh.result().nrows} "
              f"resumes from {sub.get('strict_psi')} "
              f"residual={sub.get('residual')}")

    # -- unified telemetry (PR 9) ----------------------------------------
    # the long-lived session has been counting all along (the metrics
    # registry and the cost-model calibration log are always on); span
    # tracing is opt-in.  Enable it, replay one warm dashboard pass
    # through the original service, and dump a Perfetto-loadable Chrome
    # trace of the full lifecycle (submit -> window -> canonicalize ->
    # MQO -> dispatch -> resolve) next to a metrics snapshot.
    sess.enable_tracing()
    for h in [svc.submit(q) for q in dashboard]:
        h.result()
    svc.flush()
    os.makedirs("reports", exist_ok=True)
    trace_path = os.path.join("reports", "analytics_trace.json")
    doc = sess.telemetry().export_chrome_trace(trace_path)
    print(f"\ntraced warm pass: {len(doc['traceEvents'])} span events "
          f"-> {trace_path} (load in https://ui.perfetto.dev)")

    rep = svc.metrics_report()
    counters = rep["registry"]["counters"]
    lat = rep["latency"]["all"]
    print(f"queries: {counters['queries.submitted']:.0f} submitted / "
          f"{counters.get('queries.succeeded', 0):.0f} ok / "
          f"{counters.get('queries.failed', 0):.0f} failed over "
          f"{counters['windows.closed']:.0f} windows; "
          f"inter-arrival EWMA "
          f"{rep['arrival_interval_ewma_s']['value'] * 1e3:.2f} ms")
    print(f"latency p50/p90/p99 = {lat['p50'] * 1e3:.1f}/"
          f"{lat['p90'] * 1e3:.1f}/{lat['p99'] * 1e3:.1f} ms over "
          f"{len(rep['latency']['families'])} template families")
    for name, st in sorted(rep["pools"].items()):
        print(f"pool {name:<6} hit_rate={st['hit_rate']:.2f} "
              f"used={st.get('used', 0)}B evictions="
              f"{st.get('evictions', 0)}")
    for kind, row in rep["calibration"]["kinds"].items():
        print(f"calibration[{kind}]: n={row['n']} "
              f"predicted_cost={row['predicted_cost']:.3g} "
              f"measured={row['measured_seconds']:.3f}s "
              f"bytes_err={row['bytes_mean_abs_rel_err']:.2f}")

    # -- async serving front (PR 10) -------------------------------------
    # the same warm session served to CONCURRENT clients: submissions
    # land on the asyncio event loop, a background task closes deadline
    # windows with nobody in flight, per-tenant admission bounds each
    # client class, and with ``adaptive=True`` per-family arrival-rate
    # EWMAs + the p99 SLO budget size every window at open time —
    # bursty dashboard traffic fills large shared windows while the SLO
    # caps the wait.  Execution funnels through the same sync window
    # path, so results stay bit-identical.
    import asyncio

    from repro.relational import (AsyncConfig, AsyncQueryService,
                                  TenantQuota)

    n_clients, per_client = 6, 4

    async def client(asvc, i, rng2, handles):
        for k in range(per_client):
            await asyncio.sleep(float(rng2.exponential(0.005)))
            h = await asvc.submit(dashboard[(i + k) % len(dashboard)],
                                  tenant=f"team{i % 2}")
            handles.append(h)

    async def serve():
        # the SLO budget is what's left after the OBSERVED window-exec
        # p99 — this session's cold compile passes pushed that to
        # seconds, so a tight SLO would (correctly) collapse every
        # window to min_batch; a loose one lets the arrival EWMAs grow
        # shared windows up to the cap
        cfg = AsyncConfig(
            max_batch=4, max_wait_s=0.02,
            adaptive=True, slo_p99_s=10.0, max_batch_cap=16,
            quotas={"team0": TenantQuota(max_inflight=16),
                    "team1": TenantQuota(max_inflight=16)})
        async with AsyncQueryService(sess, config=cfg) as asvc:
            handles = []
            rngs = [np.random.default_rng(100 + i)
                    for i in range(n_clients)]
            t0 = time.perf_counter()
            await asyncio.gather(*(client(asvc, i, rngs[i], handles)
                                   for i in range(n_clients)))
            tables = await asyncio.gather(
                *(h.result() for h in handles))
            wall = time.perf_counter() - t0
            return handles, tables, wall, asvc.metrics_report()

    ahandles, atables, wall, arep = asyncio.run(serve())
    sizes = sorted(h.explain()["window_size"] for h in ahandles)
    print(f"\nasync adaptive serving: {len(atables)} queries from "
          f"{n_clients} concurrent clients in {wall:.2f}s "
          f"({len(atables) / wall:.0f} q/s), window sizes {sizes}")
    for t in sorted(arep["tenants"]):
        row = arep["tenants"][t]
        print(f"  tenant {t}: submitted="
              f"{row.get('queries.submitted', 0):.0f} "
              f"bytes={row.get('bytes_total', 0)}B "
              f"admission={row.get('admission')}")


if __name__ == "__main__":
    main()
