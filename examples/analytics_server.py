"""Analytics-server scenario: the TPC-DS-analog workload batched
through the SparkSQL-Server-style session (paper §6.2).

Accumulates a window of concurrent queries, triggers the MQO, and
executes — printing the per-query runtime-ratio distribution.

    PYTHONPATH=src python examples/analytics_server.py [--window 12]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--window", type=int, default=12)
    ap.add_argument("--scale-rows", type=int, default=80_000)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.relational.tpcds import build_tpcds_session, tpcds_queries

    sess = build_tpcds_session(scale_rows=args.scale_rows,
                               budget_bytes=1 << 30)
    qs = tpcds_queries(sess)
    rng = np.random.default_rng(args.seed)
    idx = rng.choice(len(qs), size=args.window, replace=False)
    batch = [qs[i] for i in idx]
    print(f"window of {args.window} queries: {sorted(idx.tolist())}")

    base = sess.run_batch(batch, mqo=False)
    opt = sess.run_batch(batch, mqo=True)

    r = opt.mqo.report
    print(f"SEs={r.n_ses} CEs={r.n_ces} selected={r.n_selected} "
          f"weight={r.selected_weight >> 10} KiB "
          f"optimize={r.optimize_seconds * 1e3:.0f} ms")
    ratios = []
    for i, (b, o) in enumerate(zip(base.results, opt.results)):
        assert b.table.row_multiset() == o.table.row_multiset()
        ratios.append(o.seconds / max(b.seconds, 1e-9))
    ratios.sort()
    print("runtime ratios (sorted):",
          " ".join(f"{x:.2f}" for x in ratios))
    print(f"aggregate ratio: "
          f"{opt.total_seconds / base.total_seconds:.2f} "
          f"({base.total_seconds:.2f}s -> {opt.total_seconds:.2f}s)")


if __name__ == "__main__":
    main()
