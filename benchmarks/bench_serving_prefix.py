"""Beyond-paper table: serving-layer prefix-cache MQO.

A shared-prefix request workload (few-shot prompt templates) served
with MQO on/off: prefill-token ratio, wall time, pool bytes; plus the
per-arch knapsack-weight table (bytes to cache a 4k-token prefix) that
drives admission differences across the assigned architectures.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Dict, List

import numpy as np

from common import csv_line, save_result
from repro.configs import get_config
from repro.models.model import init_params
from repro.serving.costs import ServingCostModel
from repro.serving.engine import ServingEngine
from repro.serving.request import GenerationRequest


def _workload(cfg, n_templates=3, per_template=4, shared_len=128,
              tail=16, seed=0) -> List[GenerationRequest]:
    rng = np.random.default_rng(seed)
    reqs, rid = [], 0
    for t in range(n_templates):
        shared = rng.integers(0, cfg.vocab_size, shared_len)
        for i in range(per_template):
            p = np.concatenate(
                [shared, rng.integers(0, cfg.vocab_size, tail + i)])
            reqs.append(GenerationRequest(rid, p.astype(np.int32), 4))
            rid += 1
    return reqs


def run(arch: str = "granite-8b") -> Dict:
    cfg = replace(get_config(arch + "-smoke"), n_prefix_tokens=0)
    params = init_params(cfg, 0)
    eng = ServingEngine(cfg, params, pool_budget_bytes=1 << 22,
                        block_size=32, max_len=256)

    def mk():
        return _workload(cfg)

    base_out, base_rep = eng.run_batch(mk(), mqo=False)
    mqo_out, rep = eng.run_batch(mk(), mqo=True)
    assert all((a == b).all() for a, b in zip(base_out, mqo_out))

    weights = {}
    for a in ("granite-8b", "deepseek-v2-236b", "gemma3-12b",
              "falcon-mamba-7b", "recurrentgemma-9b"):
        cm = ServingCostModel(get_config(a))
        weights[a] = {"prefix_4k_bytes": cm.state_bytes(4096),
                      "prefix_32k_bytes": cm.state_bytes(32768)}

    out = {
        "arch": arch,
        "identical_generations": True,
        "tokens_prefilled_mqo": rep.tokens_prefilled,
        "tokens_prefilled_base": rep.tokens_prefilled_baseline,
        "prefill_token_ratio": rep.prefill_token_ratio,
        "wall_mqo_s": rep.wall_seconds,
        "wall_base_s": base_rep.wall_seconds,
        "n_selected": rep.n_selected,
        "pool_used": rep.pool_used,
        "per_arch_prefix_weights": weights,
    }
    save_result("serving_prefix", out)
    return out


def main() -> List[str]:
    out = run()
    lines = [csv_line(
        "serving_prefix[granite-smoke]", out["wall_mqo_s"],
        f"prefill_ratio={out['prefill_token_ratio']:.2f};"
        f"wall_ratio={out['wall_mqo_s'] / out['wall_base_s']:.2f};"
        f"selected={out['n_selected']}")]
    w = out["per_arch_prefix_weights"]
    gqa = w["granite-8b"]["prefix_4k_bytes"]
    mla = w["deepseek-v2-236b"]["prefix_4k_bytes"]
    ssm = w["falcon-mamba-7b"]["prefix_4k_bytes"]
    lines.append(csv_line(
        "prefix_weights[4k]", 0.0,
        f"gqa={gqa};mla={mla};ssm={ssm};"
        f"mla_vs_gqa={gqa / max(mla, 1):.1f}x;"
        f"ssm_vs_gqa={gqa / max(ssm, 1):.1f}x"))
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
