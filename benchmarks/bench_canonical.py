"""Mixed-syntax recurring stream (ISSUE 5 acceptance): the canonical
plan IR recovers cross-window CE sharing when every dashboard pass
spells the same queries differently.  The implementation lives in
``bench_service`` (it reuses that harness's sessions and knobs); this
module is the runner registration that emits BENCH_pr5.json.

Acceptance: mixed_warm_speedup >= 1.3 and canonical_hit_rate > 0.
"""
from typing import List

from bench_service import main_mixed, run_mixed  # noqa: F401


def main() -> List[str]:
    return main_mixed()


if __name__ == "__main__":
    print("\n".join(main()))
