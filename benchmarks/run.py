"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

  bench_filter_micro      paper Fig. 5–7  (filter queries, CSV+Parquet)
  bench_projection_micro  paper Fig. 8–9  (projection queries)
  bench_macro_tpcds       paper Fig. 3    (50-query TPC-DS CDF)
  bench_window            paper Fig. 4    (batching-window sweep)
  bench_mckp              paper §6.2      (optimizer overhead < 2 s)
  bench_serving_prefix    beyond-paper    (LLM prefix-cache MQO)
  roofline_report         assignment      (dry-run roofline terms)
"""
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

MODULES = [
    "bench_mckp",
    "bench_filter_micro",
    "bench_projection_micro",
    "bench_window",
    "bench_macro_tpcds",
    "bench_serving_prefix",
    "roofline_report",
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for mod_name in MODULES:
        t0 = time.time()
        try:
            mod = __import__(mod_name)
            for line in mod.main():
                print(line, flush=True)
            print(f"# {mod_name} done in {time.time() - t0:.1f}s",
                  flush=True)
        except Exception:
            failures += 1
            print(f"# {mod_name} FAILED:", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
