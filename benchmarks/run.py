"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

  bench_filter_micro      paper Fig. 5–7  (filter queries, CSV+Parquet)
                          + fused-vs-eager pipeline comparison (PR 1)
  bench_projection_micro  paper Fig. 8–9  (projection queries)
                          + fused-vs-eager pipeline comparison (PR 1)
  bench_macro_tpcds       paper Fig. 3    (50-query TPC-DS CDF)
  bench_window            paper Fig. 4    (batching-window sweep)
  bench_mckp              paper §6.2      (optimizer overhead < 2 s)
  bench_batch_reuse       beyond-paper    (cold vs warm repeat batch,
                          cross-batch CE retention per policy — PR 2)
  bench_service           beyond-paper    (online QueryService windows:
                          interleaved arrivals + warm residents vs the
                          cold one-shot batch — PR 3)
  bench_canonical         beyond-paper    (mixed-syntax recurring
                          stream: the canonical plan IR folds every
                          author spelling onto one fingerprint, so
                          warm windows keep hitting resident CEs —
                          PR 5)
  bench_partition         beyond-paper    (partition-grained MCKP on
                          the selective dashboard: partial admission
                          under a sub-CE budget, warm partial
                          residency vs cold — PR 4)
  bench_resilience        beyond-paper    (warm-stream throughput at a
                          5% injected transient-fault rate vs the
                          fault-free warm stream: isolation + retry
                          overhead bounded — PR 6)
  bench_window_batch      beyond-paper    (window-batched kernel
                          execution + plan-shape compile cache: warm
                          recurring-template windows vs per-query
                          literal-keyed dispatch — PR 7)
  bench_subsumption       beyond-paper    (semantic subsumption + pid
                          pool: fresh-literal drill-down stream served
                          from a WEAKER resident CE with zero
                          exact-fingerprint hits — PR 8)
  bench_async             beyond-paper    (asyncio serving front:
                          Poisson clients, adaptive vs fixed windows,
                          per-tenant admission — PR 10)
  bench_serving_prefix    beyond-paper    (LLM prefix-cache MQO)
  roofline_report         assignment      (dry-run roofline terms)

Usage:
  python benchmarks/run.py                       # everything
  python benchmarks/run.py bench_filter_micro bench_projection_micro \
      --out BENCH_pr1.json                       # subset, merged JSON
"""
import argparse
import json
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

MODULES = [
    "bench_mckp",
    "bench_filter_micro",
    "bench_projection_micro",
    "bench_window",
    "bench_macro_tpcds",
    "bench_batch_reuse",
    "bench_service",
    "bench_canonical",
    "bench_partition",
    "bench_resilience",
    "bench_window_batch",
    "bench_subsumption",
    "bench_telemetry",
    "bench_async",
    "bench_serving_prefix",
    "roofline_report",
]

# modules that legitimately emit no reports/bench/*.json artifact (the
# roofline report is a stdout-only dry-run summary); every other bench
# MUST save_result or the run fails loudly (PR 9 satellite — a silently
# missing BENCH artifact is how BENCH_pr7.json went uncommitted)
NO_ARTIFACT = frozenset({"roofline_report"})


def _artifacts_written_since(t0: float) -> int:
    """JSON result files common.save_result produced after ``t0``."""
    from common import RESULTS_DIR

    if not os.path.isdir(RESULTS_DIR):
        return 0
    return sum(
        1 for fn in os.listdir(RESULTS_DIR)
        if fn.endswith(".json")
        and os.path.getmtime(os.path.join(RESULTS_DIR, fn)) >= t0)


def _merge_results(out_path: str, since: float) -> None:
    """Collect the per-module JSONs written by common.save_result
    DURING THIS RUN into a single file (the PR-over-PR perf trajectory
    artifact); stale results from earlier runs are left out."""
    from common import RESULTS_DIR

    merged = {}
    if os.path.isdir(RESULTS_DIR):
        for fn in sorted(os.listdir(RESULTS_DIR)):
            path = os.path.join(RESULTS_DIR, fn)
            if fn.endswith(".json") and os.path.getmtime(path) >= since:
                with open(path) as f:
                    merged[fn[:-5]] = json.load(f)
    with open(out_path, "w") as f:
        json.dump(merged, f, indent=1)
    print(f"# merged {len(merged)} result sets -> {out_path}", flush=True)


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("modules", nargs="*",
                        help=f"subset of {MODULES} (default: all)")
    parser.add_argument("--out", default=None,
                        help="merge reports/bench/*.json into this file")
    args = parser.parse_args()
    modules = args.modules or MODULES
    unknown = [m for m in modules if m not in MODULES]
    if unknown:
        parser.error(f"unknown modules: {unknown}")

    print("name,us_per_call,derived")
    t_start = time.time()
    failures = 0
    for mod_name in modules:
        t0 = time.time()
        try:
            mod = __import__(mod_name)
            for line in mod.main():
                print(line, flush=True)
            if (mod_name not in NO_ARTIFACT
                    and _artifacts_written_since(t0) == 0):
                failures += 1
                print(f"# {mod_name} FAILED: completed without writing "
                      f"any reports/bench/*.json artifact — its results "
                      f"would be missing from the --out merge",
                      flush=True)
                continue
            print(f"# {mod_name} done in {time.time() - t0:.1f}s",
                  flush=True)
        except Exception:
            failures += 1
            print(f"# {mod_name} FAILED:", flush=True)
            traceback.print_exc()
    if args.out:
        _merge_results(args.out, since=t_start)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
