"""Online QueryService windows (ISSUE 3 acceptance): interleaved
arrivals through micro-batch windows with warm residents vs the cold
one-shot batch.

The recurring-dashboard workload of ``bench_batch_reuse`` — the
scan-dominated F2 (high-value sales scans) + F5 (profitability scans)
template families over the CSV fact table under the paper's ~200 MB/s
disk profile — arrives as a STREAM: queries submitted one at a time in
an interleaved family order, accumulated into count-closed windows of
``MAX_BATCH``.  Because a recurring dashboard re-arrives in the same
order, each warm window regenerates the same covering content an
earlier window materialized; the strict-keyed CE cache keeps every
window's CEs resident side by side and the window-level MCKP re-prices
them as zero-weight already-paid items (plus single-query resident
resume for windows left with one matching query).

Measured (both sides are WALL time around the full call, so the
windowed side's per-window optimize overhead is charged against it):
  * ``cold_oneshot_s`` — a cold session's one-shot ``run_batch`` over
    the whole dashboard (pays disk, CSV parse, CE materialization and
    one optimizer pass);
  * ``warm_windowed_s`` — steady-state windowed pass (best of
    ``REPEATS``) on the long-lived session, including one optimizer
    pass per window.

Jit compilation is paid by a throwaway warmup session (as in
bench_batch_reuse), so the comparison isolates the service/memory
effect.

Acceptance: windowed_warm_speedup = cold_oneshot_s / warm_windowed_s
>= 1.3.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from common import csv_line, save_result
from repro.relational import QueryService
from repro.relational.tpcds import build_tpcds_session, tpcds_queries

SCALE_ROWS = 120_000
BUDGET = 1 << 30
FMT = "csv"                 # parse is the shareable work CEs eliminate
DISK_LATENCY = 5e-9         # paper §6.3 commodity-disk regime (~200 MB/s)
MAX_BATCH = 4
REPEATS = 5


def _dashboard(qs):
    """The recurring scan-heavy stream: F2 (10) + F5 (6) queries,
    interleaved across the two families (arrival order is part of the
    recurring pattern, so windows recur identically)."""
    picked = qs[10:20] + qs[36:42]
    order = np.random.default_rng(0).permutation(len(picked))
    return [picked[i] for i in order]


def _windowed_pass(svc: QueryService, queries) -> Dict:
    t0 = time.perf_counter()
    handles = [svc.submit(q) for q in queries]
    svc.flush()
    seconds = time.perf_counter() - t0
    return {
        "seconds": seconds,
        "reused": sum(1 for h in handles
                      if h.explain()["resident_reuse"]),
        "handles": handles,
    }


def run() -> Dict:
    # pay jit compilation once, outside the measured sessions
    warmup = build_tpcds_session(scale_rows=SCALE_ROWS, fmt=FMT,
                                 budget_bytes=BUDGET)
    wq = _dashboard(tpcds_queries(warmup))
    warmup.run_batch(wq, mqo=True)
    wsvc = QueryService(warmup, max_batch=MAX_BATCH)
    for q in wq:
        wsvc.submit(q)
    wsvc.flush()

    # cold one-shot: fresh session, whole dashboard in one pre-closed
    # window (this is also what primes the long-lived session); wall
    # time so the one optimizer pass is charged like the windows' are
    sess = build_tpcds_session(scale_rows=SCALE_ROWS, fmt=FMT,
                               budget_bytes=BUDGET)
    sess.disk_latency_per_byte = DISK_LATENCY
    queries = _dashboard(tpcds_queries(sess))
    t0 = time.perf_counter()
    cold = sess.run_batch(queries, mqo=True)
    cold_wall = time.perf_counter() - t0

    # online service on the SAME long-lived session: first windowed
    # pass materializes the window-level CEs, steady state reuses them
    svc = QueryService(sess, max_batch=MAX_BATCH)
    prime = _windowed_pass(svc, queries)
    warm_passes = [_windowed_pass(svc, queries) for _ in range(REPEATS)]
    warm = min(warm_passes, key=lambda p: p["seconds"])

    # correctness: the streamed results match independent execution
    base = sess.run_batch(queries, mqo=False)
    for b, h in zip(base.results, warm["handles"]):
        assert b.table.row_multiset() == h.result().row_multiset()

    n = len(queries)
    out = {
        "scale_rows": SCALE_ROWS, "fmt": FMT,
        "disk_latency_per_byte": DISK_LATENCY,
        "n_queries": n, "max_batch": MAX_BATCH,
        "cold_oneshot_s": cold_wall,
        "cold_exec_s": cold.total_seconds,
        "cold_optimize_s": cold.optimize_seconds,
        "prime_windowed_s": prime["seconds"],
        "warm_windowed_s": warm["seconds"],
        "warm_pass_seconds": [p["seconds"] for p in warm_passes],
        "windowed_warm_speedup": cold_wall
        / max(warm["seconds"], 1e-12),
        "warm_throughput_qps": n / max(warm["seconds"], 1e-12),
        "cold_throughput_qps": n / max(cold_wall, 1e-12),
        "warm_reused_handles": warm["reused"],
        "memory": {k: v for k, v in sess.memory.report().items()
                   if k != "pools"},
    }
    save_result("service_windows", out)
    return out


def main() -> List[str]:
    out = run()
    return [csv_line(
        "service_windows", out["warm_windowed_s"],
        f"cold_oneshot_s={out['cold_oneshot_s']:.3f};"
        f"warm_windowed_s={out['warm_windowed_s']:.3f};"
        f"speedup={out['windowed_warm_speedup']:.2f};"
        f"reused={out['warm_reused_handles']}/{out['n_queries']}")]


# ---------------------------------------------------------------------------
# mixed-syntax recurring stream (ISSUE 5): canonicalization recovers
# the sharing a recurring dashboard loses when every pass spells its
# queries differently (reordered conjuncts, pushed negations, flipped
# literal-on-left compares, legacy hand-built trees).
# ---------------------------------------------------------------------------
def _mixed_spellings(sess, style: int):
    """The F2+F5 dashboard, each query in one of four author styles.
    All styles are semantically identical; only style 0 is the
    'native' spelling — canonicalization must fold the rest onto it."""
    import warnings

    from repro.relational import c, expr as E

    ss = sess.table("store_sales")
    qs = []
    for thr in (50, 60, 70, 80, 90, 55, 65, 75):
        t = float(thr)
        if style == 0:
            pred = (c.ss_sales_price > t) & (c.ss_quantity >= 10)
        elif style == 1:                 # reordered conjuncts
            pred = (c.ss_quantity >= 10) & (c.ss_sales_price > t)
        elif style == 2:                 # flipped literal + negation
            pred = (t < c.ss_sales_price) & ~(c.ss_quantity < 10)
        else:                            # legacy hand-built raw tree
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                node = (sess.scan_node("store_sales")
                        .filter(E.and_(
                            E.Not(E.cmp("ss_quantity", "<", 10)),
                            E.Cmp("<", E.Lit(t),
                                  E.Col("ss_sales_price"))))
                        .project("ss_item_sk", "ss_customer_sk",
                                 "ss_sales_price", "ss_net_profit"))
            qs.append(node)
            continue
        qs.append(ss.where(pred).select(
            "ss_item_sk", "ss_customer_sk", "ss_sales_price",
            "ss_net_profit"))
    for lo in (0.0, 10.0, 20.0, 30.0, 40.0, 50.0):
        if style in (0, 1):
            pred = c.ss_net_profit > lo
        elif style == 2:                 # pushed negation
            pred = ~(c.ss_net_profit <= lo)
        else:                            # literal on the left
            pred = lo < c.ss_net_profit
        qs.append(ss.where(pred).select("ss_item_sk", "ss_net_profit")
                  .sort("ss_net_profit", desc=True).limit(100))
    order = np.random.default_rng(0).permutation(len(qs))
    return [qs[i] for i in order]


def _mixed_pass(svc, queries):
    import warnings

    t0 = time.perf_counter()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        handles = [svc.submit(q) for q in queries]
        svc.flush()
    seconds = time.perf_counter() - t0
    exps = [h.explain() for h in handles]
    return {
        "seconds": seconds,
        "handles": handles,
        "reused": sum(1 for e in exps if e["resident_reuse"]),
        "with_ces": sum(1 for e in exps if e["ces"]),
    }


def run_mixed() -> Dict:
    """Warm mixed-syntax windowed stream vs the cold one-shot batch.

    Every pass re-spells the SAME dashboard in a different author
    style; without the canonical IR each pass would produce fresh
    strict fingerprints and rebuild every CE.  ``canonical_hit_rate``
    is the fraction of warm CE-consuming handles that hit a resident
    covering entry despite the spelling change."""
    n_styles = 4

    # jit warmup outside the measured sessions
    warmup = build_tpcds_session(scale_rows=SCALE_ROWS, fmt=FMT,
                                 budget_bytes=BUDGET)
    wsvc = QueryService(warmup, max_batch=MAX_BATCH)
    _mixed_pass(wsvc, _mixed_spellings(warmup, 0))
    _mixed_pass(wsvc, _mixed_spellings(warmup, 1))

    # cold: fresh session, one-shot over the style-0 spelling
    sess = build_tpcds_session(scale_rows=SCALE_ROWS, fmt=FMT,
                               budget_bytes=BUDGET)
    sess.disk_latency_per_byte = DISK_LATENCY
    t0 = time.perf_counter()
    cold = sess.run_batch(_mixed_spellings(sess, 0), mqo=True)
    cold_wall = time.perf_counter() - t0

    # warm: windowed passes, each in a DIFFERENT spelling of the same
    # dashboard (style rotates per pass).  The first pass is the
    # window-granularity prime: its MAX_BATCH windows merge different
    # member subsets than the 14-query one-shot did, so it materializes
    # the window-shaped CEs the steady-state passes then re-hit.
    svc = QueryService(sess, max_batch=MAX_BATCH)
    prime = _mixed_pass(svc, _mixed_spellings(sess, 1))
    seen_styles = {0, 1}          # cold batch was style 0, prime style 1
    passes, fresh_flags = [], []
    for p in range(REPEATS):
        style = (p + 2) % n_styles
        fresh_flags.append(style not in seen_styles)
        seen_styles.add(style)
        passes.append(_mixed_pass(svc, _mixed_spellings(sess, style)))
    warm = min(passes, key=lambda p: p["seconds"])

    # correctness: mixed-spelling results match independent execution
    base = sess.run_batch(_mixed_spellings(sess, 0), mqo=False)
    for b, h in zip(base.results, warm["handles"]):
        assert b.table.row_multiset() == h.result().row_multiset()

    # the hit rate counts ONLY first-encounter spellings (styles the
    # session has never executed) — a hit there proves the canonical
    # IR folded the new spelling onto a resident strict fingerprint;
    # repeat-style passes would hit even without canonicalization, so
    # they contribute to the speedup but not to this metric
    fresh = [p for p, f in zip(passes, fresh_flags) if f]
    hits = sum(p["reused"] for p in fresh)
    total = sum(p["with_ces"] for p in fresh)
    n = len(base.results)
    out = {
        "scale_rows": SCALE_ROWS, "fmt": FMT,
        "disk_latency_per_byte": DISK_LATENCY,
        "n_queries": n, "max_batch": MAX_BATCH, "n_styles": n_styles,
        "cold_oneshot_s": cold_wall,
        "cold_exec_s": cold.total_seconds,
        "prime_mixed_s": prime["seconds"],
        "warm_mixed_s": warm["seconds"],
        "pass_seconds": [p["seconds"] for p in passes],
        "mixed_warm_speedup": cold_wall / max(warm["seconds"], 1e-12),
        "canonical_hit_rate": hits / max(total, 1),
        "fresh_spelling_passes": sum(fresh_flags),
        "warm_reused_per_pass": [p["reused"] for p in passes],
    }
    save_result("service_mixed_syntax", out)
    return out


def main_mixed() -> List[str]:
    out = run_mixed()
    return [csv_line(
        "service_mixed_syntax", out["warm_mixed_s"],
        f"cold_oneshot_s={out['cold_oneshot_s']:.3f};"
        f"warm_mixed_s={out['warm_mixed_s']:.3f};"
        f"speedup={out['mixed_warm_speedup']:.2f};"
        f"canonical_hit_rate={out['canonical_hit_rate']:.2f}")]


# ---------------------------------------------------------------------------
# tracing-overhead gate (PR 9): the telemetry subsystem must be cheap
# enough to leave on — warm-window throughput with span tracing ENABLED
# must stay within 5% of the tracing-DISABLED throughput on the same
# long-lived session.  (The metrics registry + calibration log are
# always on in both modes; the gate isolates the opt-in span tracer.)
# ---------------------------------------------------------------------------
TRACING_MIN_RATIO = 0.95


def run_tracing_overhead() -> Dict:
    # jit warmup outside the measured session
    warmup = build_tpcds_session(scale_rows=SCALE_ROWS, fmt=FMT,
                                 budget_bytes=BUDGET)
    wq = _dashboard(tpcds_queries(warmup))
    wsvc = QueryService(warmup, max_batch=MAX_BATCH)
    _windowed_pass(wsvc, wq)

    sess = build_tpcds_session(scale_rows=SCALE_ROWS, fmt=FMT,
                               budget_bytes=BUDGET)
    sess.disk_latency_per_byte = DISK_LATENCY
    queries = _dashboard(tpcds_queries(sess))
    svc = QueryService(sess, max_batch=MAX_BATCH)
    _windowed_pass(svc, queries)          # prime the resident CEs

    # interleave the two modes pass-by-pass so drift (allocator state,
    # cache temperature) hits both sides equally; best-of per mode
    off_s: List[float] = []
    on_s: List[float] = []
    for _ in range(REPEATS):
        sess.telemetry().disable_tracing()
        off_s.append(_windowed_pass(svc, queries)["seconds"])
        sess.enable_tracing()
        on_s.append(_windowed_pass(svc, queries)["seconds"])
    tracer = sess.telemetry().tracer
    n_spans = sum(1 for root in tracer.finished for _ in root.walk())
    trace = sess.telemetry().export_chrome_trace()
    sess.telemetry().disable_tracing()

    n = len(queries)
    disabled_s, enabled_s = min(off_s), min(on_s)
    ratio = (n / max(enabled_s, 1e-12)) / (n / max(disabled_s, 1e-12))
    out = {
        "scale_rows": SCALE_ROWS, "fmt": FMT,
        "n_queries": n, "max_batch": MAX_BATCH,
        "disabled_warm_s": disabled_s,
        "enabled_warm_s": enabled_s,
        "disabled_pass_seconds": off_s,
        "enabled_pass_seconds": on_s,
        "throughput_ratio": ratio,
        "min_ratio": TRACING_MIN_RATIO,
        "traced_spans": n_spans,
        "trace_events": len(trace["traceEvents"]),
    }
    save_result("service_tracing_overhead", out)
    if ratio < TRACING_MIN_RATIO:
        raise RuntimeError(
            f"tracing overhead gate: enabled/disabled warm throughput "
            f"ratio {ratio:.3f} < {TRACING_MIN_RATIO}")
    return out


def main_tracing() -> List[str]:
    out = run_tracing_overhead()
    return [csv_line(
        "service_tracing_overhead", out["enabled_warm_s"],
        f"disabled_warm_s={out['disabled_warm_s']:.3f};"
        f"enabled_warm_s={out['enabled_warm_s']:.3f};"
        f"throughput_ratio={out['throughput_ratio']:.3f};"
        f"spans={out['traced_spans']}")]


if __name__ == "__main__":
    print("\n".join(main()))
    print("\n".join(main_mixed()))
    print("\n".join(main_tracing()))
