"""Online QueryService windows (ISSUE 3 acceptance): interleaved
arrivals through micro-batch windows with warm residents vs the cold
one-shot batch.

The recurring-dashboard workload of ``bench_batch_reuse`` — the
scan-dominated F2 (high-value sales scans) + F5 (profitability scans)
template families over the CSV fact table under the paper's ~200 MB/s
disk profile — arrives as a STREAM: queries submitted one at a time in
an interleaved family order, accumulated into count-closed windows of
``MAX_BATCH``.  Because a recurring dashboard re-arrives in the same
order, each warm window regenerates the same covering content an
earlier window materialized; the strict-keyed CE cache keeps every
window's CEs resident side by side and the window-level MCKP re-prices
them as zero-weight already-paid items (plus single-query resident
resume for windows left with one matching query).

Measured (both sides are WALL time around the full call, so the
windowed side's per-window optimize overhead is charged against it):
  * ``cold_oneshot_s`` — a cold session's one-shot ``run_batch`` over
    the whole dashboard (pays disk, CSV parse, CE materialization and
    one optimizer pass);
  * ``warm_windowed_s`` — steady-state windowed pass (best of
    ``REPEATS``) on the long-lived session, including one optimizer
    pass per window.

Jit compilation is paid by a throwaway warmup session (as in
bench_batch_reuse), so the comparison isolates the service/memory
effect.

Acceptance: windowed_warm_speedup = cold_oneshot_s / warm_windowed_s
>= 1.3.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from common import csv_line, save_result
from repro.relational import QueryService
from repro.relational.tpcds import build_tpcds_session, tpcds_queries

SCALE_ROWS = 120_000
BUDGET = 1 << 30
FMT = "csv"                 # parse is the shareable work CEs eliminate
DISK_LATENCY = 5e-9         # paper §6.3 commodity-disk regime (~200 MB/s)
MAX_BATCH = 4
REPEATS = 5


def _dashboard(qs):
    """The recurring scan-heavy stream: F2 (10) + F5 (6) queries,
    interleaved across the two families (arrival order is part of the
    recurring pattern, so windows recur identically)."""
    picked = qs[10:20] + qs[36:42]
    order = np.random.default_rng(0).permutation(len(picked))
    return [picked[i] for i in order]


def _windowed_pass(svc: QueryService, queries) -> Dict:
    t0 = time.perf_counter()
    handles = [svc.submit(q) for q in queries]
    svc.flush()
    seconds = time.perf_counter() - t0
    return {
        "seconds": seconds,
        "reused": sum(1 for h in handles
                      if h.explain()["resident_reuse"]),
        "handles": handles,
    }


def run() -> Dict:
    # pay jit compilation once, outside the measured sessions
    warmup = build_tpcds_session(scale_rows=SCALE_ROWS, fmt=FMT,
                                 budget_bytes=BUDGET)
    wq = _dashboard(tpcds_queries(warmup))
    warmup.run_batch(wq, mqo=True)
    wsvc = QueryService(warmup, max_batch=MAX_BATCH)
    for q in wq:
        wsvc.submit(q)
    wsvc.flush()

    # cold one-shot: fresh session, whole dashboard in one pre-closed
    # window (this is also what primes the long-lived session); wall
    # time so the one optimizer pass is charged like the windows' are
    sess = build_tpcds_session(scale_rows=SCALE_ROWS, fmt=FMT,
                               budget_bytes=BUDGET)
    sess.disk_latency_per_byte = DISK_LATENCY
    queries = _dashboard(tpcds_queries(sess))
    t0 = time.perf_counter()
    cold = sess.run_batch(queries, mqo=True)
    cold_wall = time.perf_counter() - t0

    # online service on the SAME long-lived session: first windowed
    # pass materializes the window-level CEs, steady state reuses them
    svc = QueryService(sess, max_batch=MAX_BATCH)
    prime = _windowed_pass(svc, queries)
    warm_passes = [_windowed_pass(svc, queries) for _ in range(REPEATS)]
    warm = min(warm_passes, key=lambda p: p["seconds"])

    # correctness: the streamed results match independent execution
    base = sess.run_batch(queries, mqo=False)
    for b, h in zip(base.results, warm["handles"]):
        assert b.table.row_multiset() == h.result().row_multiset()

    n = len(queries)
    out = {
        "scale_rows": SCALE_ROWS, "fmt": FMT,
        "disk_latency_per_byte": DISK_LATENCY,
        "n_queries": n, "max_batch": MAX_BATCH,
        "cold_oneshot_s": cold_wall,
        "cold_exec_s": cold.total_seconds,
        "cold_optimize_s": cold.optimize_seconds,
        "prime_windowed_s": prime["seconds"],
        "warm_windowed_s": warm["seconds"],
        "warm_pass_seconds": [p["seconds"] for p in warm_passes],
        "windowed_warm_speedup": cold_wall
        / max(warm["seconds"], 1e-12),
        "warm_throughput_qps": n / max(warm["seconds"], 1e-12),
        "cold_throughput_qps": n / max(cold_wall, 1e-12),
        "warm_reused_handles": warm["reused"],
        "memory": {k: v for k, v in sess.memory.report().items()
                   if k != "pools"},
    }
    save_result("service_windows", out)
    return out


def main() -> List[str]:
    out = run()
    return [csv_line(
        "service_windows", out["warm_windowed_s"],
        f"cold_oneshot_s={out['cold_oneshot_s']:.3f};"
        f"warm_windowed_s={out['warm_windowed_s']:.3f};"
        f"speedup={out['windowed_warm_speedup']:.2f};"
        f"reused={out['warm_reused_handles']}/{out['n_queries']}")]


if __name__ == "__main__":
    print("\n".join(main()))
