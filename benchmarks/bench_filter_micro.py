"""Paper Fig. 5–7: filter-based micro-benchmark.

Two queries over the synthetic "people" relation, each a filter with a
different predicate on the same attribute, executed with (i) no
sharing, (ii) naive full-input caching (FC), (iii) worksharing (WS).
Reported per input size and format: individual + aggregate latencies
and cache bytes — reproducing the paper's claims that WS beats both
baseline (~40–50 % aggregate on CSV) and FC, with ~25 % of the input
cached.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from common import csv_line, fused_vs_eager, save_result
from repro.relational import Session, SessionConfig, expr as E, make_storage
from repro.relational.datagen import generate_columns, people_schema


def _mk_session(nrows: int, fmt: str, budget: int,
                fused: bool = True) -> Session:
    schema = people_schema()
    cols = generate_columns(schema, nrows, seed=0)
    # fused=False reproduces the seed eager executor (per-operator
    # dispatch, host sync after every filter, no device scan cache)
    sess = Session.from_config(SessionConfig.from_legacy_kwargs(
        budget_bytes=budget, fuse=fused, defer_sync=fused,
        use_scan_cache=fused))
    st, _ = make_storage("people", schema, nrows, fmt, cols=cols)
    sess.register(st, columnar_for_stats=cols)
    return sess


def _queries(sess: Session):
    people = sess.table("people")
    # paper Fig. 5: SELECT * WHERE age < P1 / age > P2 (age = n1,
    # uniform in [1, 1000]) — ~25% selectivity each
    q1 = people.filter(E.cmp("age", "<", 250))
    q2 = people.filter(E.cmp("age", ">", 750))
    return [q1, q2]


def _chain_queries(sess: Session):
    """Batched Scan→Filter→Project chains (the fusion-layer hot path)."""
    people = sess.table("people")
    return [
        people.filter(E.cmp("age", "<", 250))
              .project("name", "age", "salary"),
        people.filter(E.cmp("age", ">", 750))
              .project("name", "age", "salary"),
        people.filter(E.and_(E.cmp("age", ">", 250),
                             E.cmp("salary", "<", 500_000)))
              .project("name", "salary"),
        people.filter(E.cmp("d1", "<", 0.5)).project("age", "d1", "d2"),
    ]


def run_fused_vs_eager(**kw) -> Dict:
    """ISSUE 1 acceptance: fusion layer on vs the seed eager path."""
    return fused_vs_eager(_mk_session, _chain_queries,
                          "filter_micro_fused", **kw)


def run(sizes=(50_000, 100_000, 200_000), fmts=("csv", "columnar"),
        budget=1 << 28, repeats: int = 3) -> Dict:
    out: Dict = {"sizes": list(sizes), "rows": []}
    for fmt in fmts:
        for n in sizes:
            sess = _mk_session(n, fmt, budget)
            qs = _queries(sess)
            # steady-state timing: the first pass pays jit compilation
            # (the paper's queries run for minutes; ours for ms, so a
            # cold pass would measure the compiler), then keep the
            # MINIMUM over ``repeats`` warm passes — a single warm pass
            # proved noisy enough to flag phantom regressions when the
            # machine is contended
            sess.run_batch(qs, mqo=False)
            base = min((sess.run_batch(qs, mqo=False)
                        for _ in range(repeats)),
                       key=lambda r: r.total_seconds)
            sess.run_batch_fullcache(qs)
            fc = min((sess.run_batch_fullcache(qs)
                      for _ in range(repeats)),
                     key=lambda r: r.total_seconds)
            sess.run_batch(qs, mqo=True)
            ws = min((sess.run_batch(qs, mqo=True)
                      for _ in range(repeats)),
                     key=lambda r: r.total_seconds)
            for b, o in zip(base.results, ws.results):
                assert b.table.row_multiset() == o.table.row_multiset()
            input_bytes = sess.catalog["people"].disk_bytes
            ws_cache = sum(e["nbytes"] for e in
                           ws.cache_report.get("entries", []))
            fc_cache = sum(e["nbytes"] for e in
                           fc.cache_report.get("entries", []))
            row = {
                "fmt": fmt, "nrows": n,
                "q_base": [r.seconds for r in base.results],
                "q_fc": [r.seconds for r in fc.results],
                "q_ws": [r.seconds for r in ws.results],
                "agg_base": base.total_seconds,
                "agg_fc": fc.total_seconds,
                "agg_ws": ws.total_seconds,
                "ws_over_base": ws.total_seconds / base.total_seconds,
                "fc_over_base": fc.total_seconds / base.total_seconds,
                "cache_frac_ws": ws_cache / max(input_bytes, 1),
                "cache_frac_fc": fc_cache / max(input_bytes, 1),
            }
            if row["ws_over_base"] > 1.05:
                # the paper's headline claim is that worksharing BEATS
                # per-query execution; a warm-path ratio above 1.05 is
                # a regression (e.g. literal-keyed re-tracing), not
                # noise — fail the bench run loudly
                raise RuntimeError(
                    f"filter_micro regression: worksharing slower than "
                    f"baseline at {fmt}/{n}: "
                    f"ws_over_base={row['ws_over_base']:.3f} > 1.05")
            out["rows"].append(row)
    save_result("filter_micro", out)
    return out


def main() -> List[str]:
    out = run()
    lines = []
    for r in out["rows"]:
        lines.append(csv_line(
            f"filter_micro[{r['fmt']},{r['nrows']}]",
            r["agg_ws"],
            f"ws/base={r['ws_over_base']:.2f};fc/base="
            f"{r['fc_over_base']:.2f};cache_frac={r['cache_frac_ws']:.2f}"
        ))
    fused = run_fused_vs_eager()
    for r in fused["rows"]:
        lines.append(csv_line(
            f"filter_micro_fused[{r['fmt']},{r['nrows']}]",
            r["agg_fused"],
            f"fused_speedup={r['fused_speedup']:.2f}"))
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
