"""Resilience overhead (ISSUE 6 acceptance): warm-stream throughput
with a 5% injected transient-fault rate vs the fault-free warm stream.

The recurring F2+F5 dashboard of ``bench_service`` streams through
count-closed QueryService windows on two long-lived sessions that
differ ONLY in fault injection: one clean, one with a seeded 5%
Bernoulli fault rate at the transient operational points (scan H2D
transfer, kernel launch, spill-to-host).  Warm windows run with CEs
and scan columns resident, so injected faults land on the real hot
path — kernel launches retrying one rung down the degradation ladder,
H2D transfers retrying in place, spills degrading to drops — while
per-query isolation and the window audit stay on.

Measured (best of ``REPEATS`` warm passes, wall time around the full
submit+flush stream, identical to bench_service's accounting):
  * ``fault_free_qps``  — clean session steady state;
  * ``faulted_qps``     — 5% fault rate steady state, every query
    still resolving successfully and bit-identical to the clean run.

Acceptance: throughput_ratio = faulted_qps / fault_free_qps >= 0.8
(the isolation + retry machinery costs at most 20% under faults; the
fault-free path costs nothing measurable — the injector is None).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from common import csv_line, save_result
from repro.core.faults import FaultConfig
from repro.relational import QueryService
from repro.relational.tpcds import build_tpcds_session, tpcds_queries

SCALE_ROWS = 60_000
BUDGET = 1 << 30
FMT = "csv"
DISK_LATENCY = 5e-9
MAX_BATCH = 4
REPEATS = 3
FAULT_RATE = 0.05
FAULT_POINTS = ("scan_h2d", "kernel_launch", "spill_to_host")


def _dashboard(qs):
    picked = qs[10:20] + qs[36:42]
    order = np.random.default_rng(0).permutation(len(picked))
    return [picked[i] for i in order]


def _mk_session(faulted: bool):
    sess = build_tpcds_session(scale_rows=SCALE_ROWS, fmt=FMT,
                               budget_bytes=BUDGET)
    sess.disk_latency_per_byte = DISK_LATENCY
    if faulted:
        from repro.core.faults import FaultInjector
        cfg = FaultConfig(seed=6, rates={p: FAULT_RATE
                                         for p in FAULT_POINTS})
        sess.fault_injector = FaultInjector.from_config(cfg)
        sess.memory.faults = sess.fault_injector
    return sess


def _warm_stream(sess) -> Dict:
    """Prime one full pass, then take the best of REPEATS warm passes."""
    queries = _dashboard(tpcds_queries(sess))
    svc = QueryService(sess, max_batch=MAX_BATCH)
    for q in queries:                    # prime: materializes the CEs
        svc.submit(q)
    svc.flush()
    best, handles = float("inf"), None
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        hs = [svc.submit(q) for q in queries]
        svc.flush()
        dt = time.perf_counter() - t0
        if dt < best:
            best, handles = dt, hs
    assert all(h.done and not h.failed for h in handles), \
        "a warm query failed permanently under the 5% transient rate"
    return {"seconds": best, "handles": handles,
            "n_queries": len(queries)}


def run() -> Dict:
    # pay jit compilation outside both measured sessions
    warmup = _mk_session(faulted=False)
    wsvc = QueryService(warmup, max_batch=MAX_BATCH)
    for q in _dashboard(tpcds_queries(warmup)):
        wsvc.submit(q)
    wsvc.flush()

    clean = _mk_session(faulted=False)
    faulted = _mk_session(faulted=True)
    base = _warm_stream(clean)
    hurt = _warm_stream(faulted)

    # correctness under faults: bit-identical to the clean stream
    for hb, hf in zip(base["handles"], hurt["handles"]):
        assert hb.result().row_multiset() == hf.result().row_multiset()
    violations = faulted.memory.audit()
    assert violations == [], violations

    n = base["n_queries"]
    inj = faulted.fault_injector
    out = {
        "scale_rows": SCALE_ROWS, "fmt": FMT, "max_batch": MAX_BATCH,
        "fault_rate": FAULT_RATE, "fault_points": list(FAULT_POINTS),
        "n_queries": n,
        "fault_free_warm_s": base["seconds"],
        "faulted_warm_s": hurt["seconds"],
        "fault_free_qps": n / max(base["seconds"], 1e-12),
        "faulted_qps": n / max(hurt["seconds"], 1e-12),
        "throughput_ratio": base["seconds"]
        / max(hurt["seconds"], 1e-12),
        "faults_fired": inj.n_fired,
        "faults_by_point": inj.fired_by_point(),
        "acceptance_ratio_ge_0.8": (base["seconds"]
                                    / max(hurt["seconds"], 1e-12))
        >= 0.8,
    }
    save_result("resilience", out)
    return out


def main() -> List[str]:
    out = run()
    return [csv_line(
        "resilience_warm_stream", out["faulted_warm_s"],
        f"fault_free_s={out['fault_free_warm_s']:.3f};"
        f"faulted_s={out['faulted_warm_s']:.3f};"
        f"ratio={out['throughput_ratio']:.2f};"
        f"faults_fired={out['faults_fired']}")]


if __name__ == "__main__":
    print("\n".join(main()))
