"""PR 7 acceptance: window-batched kernel execution + plan-shape
compile cache.

A recurring dashboard template family — N_QUERIES same-SHAPE filter
pipelines over one table, literals fresh every window — is streamed
for N_WINDOWS windows through two sessions:

* **baseline** — ``window_batch=False, shape_cache=False``: per-query
  dispatch with literal-keyed jit, so every window's fresh literals
  re-trace every query (the pre-PR-7 behavior);
* **batched** — the defaults: the window's same-shape plans execute as
  ONE batched mask dispatch whose compiled function is keyed by plan
  shape (literals hoisted into operand arrays), so only window 0 ever
  traces.

Acceptance (RuntimeError on violation — ``run.py`` counts module
exceptions as failures, so CI fails loudly):

* warm (windows 1+) throughput >= ``MIN_WARM_SPEEDUP`` x baseline;
* trace-cache hit rate is exactly 1.0 from the second window on
  (``trace_misses == 0``);
* every window actually took the shared dispatch
  (``batched_dispatches >= 1``);
* batched results are bit-identical to per-query baseline results.
"""
from __future__ import annotations

from typing import Dict, List

from common import csv_line, save_result
from repro.relational import Session, SessionConfig, expr as E, make_storage
from repro.relational.datagen import generate_columns, synthetic_schema

N_ROWS = 100_000
N_QUERIES = 6               # template family size per window
N_WINDOWS = 5               # window 0 is the cold (tracing) window
FMT = "columnar"
MIN_WARM_SPEEDUP = 3.0      # ISSUE 7 acceptance floor

SCHEMA = synthetic_schema(n_int=6, n_dbl=4, n_str=2)
COLS = generate_columns(SCHEMA, N_ROWS, seed=7)


def _mk_session(window_batch: bool, shape_cache: bool) -> Session:
    sess = Session.from_config(SessionConfig().with_execution(
        window_batch=window_batch, shape_cache=shape_cache))
    st, _ = make_storage("fact", SCHEMA, N_ROWS, FMT, cols=COLS)
    sess.register(st, columnar_for_stats=COLS)
    return sess


def _window(sess: Session, w: int):
    """One window of the recurring template: same plan shape for all
    N_QUERIES members, literals a function of ``(w, i)`` so every
    window is FRESH literals (a literal-keyed compile cache must
    re-trace; the plan-shape cache must not)."""
    qs = []
    for i in range(N_QUERIES):
        lo = 50 + 13 * i + 7 * w           # n1 uniform in [1, 1000]
        hi = 920 - 11 * i - 5 * w
        qs.append(sess.table("fact")
                  .filter(E.and_(E.cmp("n1", ">", lo),
                                 E.cmp("n1", "<", hi)))
                  .project("n1", "n2", "d1"))
    return qs


def run() -> Dict:
    base = _mk_session(window_batch=False, shape_cache=False)
    batched = _mk_session(window_batch=True, shape_cache=True)

    rows: List[Dict] = []
    for w in range(N_WINDOWS):
        rb = base.run_batch(_window(base, w), mqo=False)
        rg = batched.run_batch(_window(batched, w), mqo=False)
        # batched execution must be BIT-identical to per-query dispatch
        for q, (a, b) in enumerate(zip(rb.results, rg.results)):
            if a.table.row_multiset() != b.table.row_multiset():
                raise RuntimeError(
                    f"window_batch divergence: window {w} query {q} "
                    f"differs between batched and per-query dispatch")
        m = rg.metrics
        hits, misses = m.trace_hits, m.trace_misses
        rows.append({
            "window": w,
            "base_s": rb.total_seconds,
            "batched_s": rg.total_seconds,
            "trace_hits": hits,
            "trace_misses": misses,
            "trace_hit_rate": hits / max(hits + misses, 1),
            "batched_dispatches": m.batched_dispatches,
            "batched_queries": m.batched_queries,
        })
        if m.batched_dispatches < 1:
            raise RuntimeError(
                f"window_batch: window {w} never took the shared "
                f"batched dispatch (batched_dispatches=0)")
        if w >= 1 and misses != 0:
            raise RuntimeError(
                f"window_batch: plan-shape cache missed on window {w} "
                f"({misses} trace misses — hit rate must be 1.0 from "
                f"the second window on)")

    warm = rows[1:]
    warm_base = sum(r["base_s"] for r in warm)
    warm_batched = sum(r["batched_s"] for r in warm)
    speedup = warm_base / max(warm_batched, 1e-12)
    out = {
        "n_rows": N_ROWS, "n_queries": N_QUERIES,
        "n_windows": N_WINDOWS, "fmt": FMT,
        "rows": rows,
        "warm_base_s": warm_base,
        "warm_batched_s": warm_batched,
        "warm_speedup": speedup,
        "warm_trace_hit_rate": min(r["trace_hit_rate"] for r in warm),
    }
    save_result("window_batch", out)
    if speedup < MIN_WARM_SPEEDUP:
        raise RuntimeError(
            f"window_batch: warm throughput only {speedup:.2f}x the "
            f"per-query baseline (acceptance floor "
            f"{MIN_WARM_SPEEDUP:.1f}x)")
    return out


def main() -> List[str]:
    out = run()
    lines = []
    for r in out["rows"]:
        lines.append(csv_line(
            f"window_batch[w{r['window']}]",
            r["batched_s"],
            f"base={r['base_s']:.4f};hit_rate={r['trace_hit_rate']:.2f};"
            f"dispatches={r['batched_dispatches']}"))
    lines.append(csv_line(
        "window_batch[warm]", out["warm_batched_s"],
        f"speedup={out['warm_speedup']:.2f}x;"
        f"hit_rate={out['warm_trace_hit_rate']:.2f}"))
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
