"""Roofline report: renders reports/dryrun/*.json into the §Roofline
markdown table (also consumed by EXPERIMENTS.md)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from common import csv_line

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                          "dryrun")


def load_cells(mesh: str = "pod16x16") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            c = json.load(f)
        if c.get("mesh") == mesh:
            cells.append(c)
    return cells


def render_table(mesh: str = "pod16x16") -> str:
    rows = [
        "| arch | shape | status | compute s | memory s | collective s |"
        " dominant | useful | MFU |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in load_cells(mesh):
        if c["status"] == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | skipped "
                        f"({c['reason'][:40]}…) | | | | | | |")
            continue
        if c["status"] != "ok":
            rows.append(f"| {c['arch']} | {c['shape']} | ERROR | | | | |"
                        f" | |")
            continue
        r = c["roofline"]
        rows.append(
            f"| {c['arch']} | {c['shape']} | ok | {r['compute_s']:.4f} |"
            f" {r['memory_s']:.4f} | {r['collective_s']:.4f} |"
            f" {r['dominant']} | {r['useful_flops_ratio']:.2f} |"
            f" {r['roofline_fraction_mfu']:.3f} |")
    return "\n".join(rows)


def main() -> List[str]:
    lines = []
    for mesh in ("pod16x16", "pod2x16x16"):
        cells = load_cells(mesh)
        ok = [c for c in cells if c["status"] == "ok"]
        skipped = [c for c in cells if c["status"] == "skipped"]
        err = [c for c in cells if c["status"] == "error"]
        lines.append(csv_line(
            f"dryrun[{mesh}]", 0.0,
            f"ok={len(ok)};skipped={len(skipped)};errors={len(err)}"))
        for c in ok:
            r = c["roofline"]
            lines.append(csv_line(
                f"roofline[{c['arch']},{c['shape']},{mesh}]",
                r["bound_s"],
                f"dominant={r['dominant']};mfu="
                f"{r['roofline_fraction_mfu']:.3f};"
                f"useful={r['useful_flops_ratio']:.2f}"))
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
    print()
    print(render_table())
