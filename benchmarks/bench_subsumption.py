"""Semantic subsumption + pid pool (ISSUE 8 acceptance): the
drill-down stream no exact-fingerprint cache can serve.

The workload is interactive drill-down serving over one CSV fact
table: a dashboard's broad filter (``n1 < 600``) arrives as a window
of identical queries and materializes one covering expression; every
follow-up then NARROWS it with fresh literals (``n1 < t & n2 >= u``,
``t`` strictly below 600, new values each pass).  No fingerprint ever
repeats, so PR 3's resident re-pricing and PR 5's canonical-IR folding
are both structurally blind here — the exact-match channels this PR's
subsumption backstop was built to complement.  Each drill-down the
window's MQO leaves unrewritten is recognized as IMPLIED by the
resident CE's weaker predicate and resumes from the cached rows,
applying only the residual conjuncts.

Measured (wall time around the full streamed pass, as in
bench_partition's cold-vs-warm):
  * ``cold_stream_s`` — the drill-down stream on a fresh session with
    NO resident CE: every singleton window pays disk + CSV parse;
  * ``warm_stream_s`` — the same-shaped stream (fresh literals every
    pass, best of ``REPEATS``) on the session holding the broad CE:
    every drill-down resumes via subsumption.

A second phase exercises the ``pid`` pool on a partitioned sibling
table: a needle predicate over non-partition columns is executed
twice — the first run records which partitions produced rows, the
repeat intersects the bitset and skips the empty ones — and the pool's
byte footprint is compared against the CE pool's.

Acceptance (BENCH_pr8.json):
  * every warm drill-down reports ``subsumption_hit`` with ZERO
    exact-fingerprint CE hits (``resident_reuse`` false throughout);
  * subsumption_warm_speedup = cold_stream_s / warm_stream_s >= 1.3;
  * pid pool bytes <= 1% of the CE pool's resident bytes.
"""
from __future__ import annotations

import time
from typing import Dict, List

from common import csv_line, save_result
from repro.relational import (MemoryConfig, Partitioning, QueryService,
                              Session, SessionConfig, expr as E,
                              make_storage)
from repro.relational.datagen import generate_columns, synthetic_schema

SCALE_ROWS = 120_000
FMT = "csv"                 # parse is the shareable work CEs eliminate
DISK_LATENCY = 5e-9         # paper §6.3 commodity-disk regime (~200 MB/s)
N_PARTITIONS = 8
N_SEED = 3                  # identical broad queries in the seed window
N_DRILL = 8                 # strictly-stronger singletons per pass
REPEATS = 5

SCHEMA = synthetic_schema(n_int=6, n_dbl=4, n_str=2)
COLS = generate_columns(SCHEMA, SCALE_ROWS, seed=8)


def build_session() -> Session:
    sess = Session.from_config(SessionConfig(
        memory=MemoryConfig(budget_bytes=1 << 28)))
    sess.disk_latency_per_byte = DISK_LATENCY
    # UNPARTITIONED fact: whole-CE residency is what subsumption
    # resumes from (partition-grained residents live in bench_partition)
    st, _ = make_storage("fact", SCHEMA, SCALE_ROWS, FMT, cols=COLS)
    sess.register(st, columnar_for_stats=COLS)
    # partitioned sibling for the pid-pool phase
    stp, _ = make_storage("factp", SCHEMA, SCALE_ROWS, FMT, cols=COLS)
    sess.register(stp, columnar_for_stats=COLS,
                  partitioning=Partitioning("n1", "range", N_PARTITIONS))
    return sess


def _broad(sess: Session):
    return (sess.table("fact").filter(E.cmp("n1", "<", 600))
            .project("n1", "n2", "n3", "d1"))


def _drill(sess: Session, k: int, pass_no: int):
    """One strictly-stronger follow-up.  Literals depend on BOTH the
    stream position and the pass number, so every submission across
    every pass carries a fingerprint the session has never seen."""
    t = 580 - 10 * k - pass_no          # always < 600: implied by broad
    u = 90 + 10 * k + pass_no
    return (sess.table("fact")
            .filter(E.and_(E.cmp("n1", "<", t), E.cmp("n2", ">=", u)))
            .project("n1", "n2"))


def _drill_pass(sess: Session, svc: QueryService, pass_no: int) -> Dict:
    """One streamed drill-down pass: N_DRILL singleton windows (flushed
    one by one — the worst case for window-level sharing, so any win
    must come from CROSS-window semantic reuse)."""
    t0 = time.perf_counter()
    handles = []
    for k in range(N_DRILL):
        h = svc.submit(_drill(sess, k, pass_no))
        svc.flush()
        handles.append(h)
    for h in handles:
        h.result()
    return {"seconds": time.perf_counter() - t0, "handles": handles}


def _seed(sess: Session, svc: QueryService) -> None:
    for h in [svc.submit(_broad(sess)) for _ in range(N_SEED)]:
        h.result()
    svc.flush()


def run() -> Dict:
    # jit warmup on a throwaway session (as in bench_partition)
    wsess = build_session()
    wsvc = QueryService(wsess, max_batch=N_SEED + 1)
    _seed(wsess, wsvc)
    _drill_pass(wsess, wsvc, 0)

    # cold: fresh session, nothing resident — every drill-down is a
    # full disk + parse scan (m=1 windows never materialize a CE)
    cold_sess = build_session()
    cold_svc = QueryService(cold_sess, max_batch=N_SEED + 1)
    cold = _drill_pass(cold_sess, cold_svc, 0)
    assert all(not h.explain()["subsumption_hit"] for h in cold["handles"])

    # warm: the broad CE is resident; every pass re-draws literals
    sess = build_session()
    svc = QueryService(sess, max_batch=N_SEED + 1)
    _seed(sess, svc)
    warm_passes = [_drill_pass(sess, svc, p + 1) for p in range(REPEATS)]
    warm = min(warm_passes, key=lambda p: p["seconds"])

    # the reuse must be PURELY semantic: every warm drill-down resumed
    # via subsumption, none via an exact-fingerprint resident hit
    explains: List[Dict] = [h.explain() for p in warm_passes
                            for h in p["handles"]]
    all_subsumed = all(e["subsumption_hit"] for e in explains)
    exact_hits = sum(bool(e["resident_reuse"]) for e in explains)

    # correctness: the last pass against plain mqo-off execution on an
    # untouched session
    verify = build_session()
    vq = [_drill(verify, k, REPEATS) for k in range(N_DRILL)]
    base = verify.run_batch(vq, mqo=False)
    for b, h in zip(base.results, warm_passes[-1]["handles"]):
        assert b.table.row_multiset() == h.result().row_multiset()

    # pid phase: needle over non-partition columns of the partitioned
    # sibling — stats refute nothing, history does
    needle = lambda: (sess.table("factp")                   # noqa: E731
                      .filter(E.and_(E.cmp("n2", "==", 777),
                                     E.cmp("n3", "<", 50)))
                      .project("n1", "n2"))
    sess.run_batch([needle()], mqo=False)       # records the bitset
    r2 = sess.run_batch([needle()], mqo=False)  # intersects it
    pid_bytes = sess._pid_pool.used_bytes
    ce_bytes = sess._ce_cache.used_bytes

    out = {
        "scale_rows": SCALE_ROWS, "fmt": FMT,
        "disk_latency_per_byte": DISK_LATENCY,
        "n_seed": N_SEED, "n_drill": N_DRILL, "repeats": REPEATS,
        "cold_stream_s": cold["seconds"],
        "warm_stream_s": warm["seconds"],
        "warm_pass_seconds": [p["seconds"] for p in warm_passes],
        "subsumption_warm_speedup": cold["seconds"]
        / max(warm["seconds"], 1e-12),
        "warm_drilldowns": len(explains),
        "all_subsumption_hits": all_subsumed,
        "exact_ce_hits": exact_hits,
        "pid_bytes": int(pid_bytes),
        "ce_bytes": int(ce_bytes),
        "pid_repeat_pruned_parts": int(r2.metrics.pid_pruned_parts),
        "accept_speedup_ge_1_3": cold["seconds"]
        / max(warm["seconds"], 1e-12) >= 1.3,
        "accept_zero_exact_hits": all_subsumed and exact_hits == 0,
        "accept_pid_le_1pct_of_ce": ce_bytes > 0
        and pid_bytes <= max(1, ce_bytes // 100),
    }
    save_result("bench_subsumption", out)
    return out


def main():
    out = run()
    yield csv_line("subsumption_cold_stream", out["cold_stream_s"],
                   f"drilldowns={out['n_drill']}")
    yield csv_line("subsumption_warm_stream", out["warm_stream_s"],
                   f"speedup={out['subsumption_warm_speedup']:.2f}x "
                   f"exact_hits={out['exact_ce_hits']} "
                   f"pid_bytes={out['pid_bytes']}/{out['ce_bytes']}")


if __name__ == "__main__":
    for line in main():
        print(line)
