"""Cross-batch cache reuse (ISSUE 2 acceptance): cold vs warm repeat.

A recurring TPC-DS-style dashboard batch — the scan-dominated F2
(high-value sales scans) + F5 (profitability scans) template families
over the CSV fact table, under the paper's ~200 MB/s disk-read profile
(§6.3) — is run twice on the same Session with cross-batch retention
on.  The cold run pays disk reads, CSV parse and CE materialization;
the warm repeat re-prices still-resident CEs as zero-weight knapsack
items and serves scans/CEs from the unified memory hierarchy, so it
pays only the per-query residuals.  Measured per eviction policy.

Jit compilation is paid by a throwaway warmup session so cold-vs-warm
isolates the memory-hierarchy effect (Sioulas et al. 2023: recompute
across recurring batches dominates, not compilation).

Acceptance: warm_speedup >= 1.5 with retention on.
"""
from __future__ import annotations

from typing import Dict, List

from common import csv_line, save_result
from repro.relational.tpcds import build_tpcds_session, tpcds_queries

SCALE_ROWS = 120_000
BUDGET = 1 << 30
FMT = "csv"                 # parse is the shareable work CEs eliminate
DISK_LATENCY = 5e-9         # paper §6.3 commodity-disk regime (~200 MB/s)


def _dashboard(qs):
    """The recurring scan-heavy batch: F2 (10) + F5 (6) queries."""
    return qs[10:20] + qs[36:42]


def _run_policy(policy: str, repeats: int = 3) -> Dict:
    # pay jit compilation once, outside the measured sessions
    warmup = build_tpcds_session(scale_rows=SCALE_ROWS, fmt=FMT,
                                 budget_bytes=BUDGET, policy=policy)
    warmup.run_batch(_dashboard(tpcds_queries(warmup)), mqo=True)

    sess = build_tpcds_session(scale_rows=SCALE_ROWS, fmt=FMT,
                               budget_bytes=BUDGET, policy=policy)
    sess.disk_latency_per_byte = DISK_LATENCY
    qs = _dashboard(tpcds_queries(sess))
    cold = sess.run_batch(qs, mqo=True)
    warm_runs = [sess.run_batch(qs, mqo=True) for _ in range(repeats)]
    warm = min(warm_runs, key=lambda b: b.total_seconds)

    base = sess.run_batch(qs, mqo=False)
    for b, w in zip(base.results, warm.results):
        assert b.table.row_multiset() == w.table.row_multiset()

    return {
        "policy": policy,
        "n_queries": len(qs),
        "cold_s": cold.total_seconds,
        "warm_s": warm.total_seconds,
        "warm_speedup": cold.total_seconds / max(warm.total_seconds, 1e-12),
        "cold_selected": cold.mqo.report.n_selected,
        "warm_resident": warm.mqo.report.n_resident,
        "warm_selected_weight": warm.mqo.report.selected_weight,
        "cache": {k: v for k, v in warm.cache_report.items()
                  if k != "entries"},
        "memory": {k: v for k, v in sess.memory.report().items()
                   if k != "pools"},
    }


def run() -> Dict:
    out = {"scale_rows": SCALE_ROWS, "fmt": FMT,
           "disk_latency_per_byte": DISK_LATENCY,
           "policies": [_run_policy(p) for p in ("lru", "benefit")]}
    save_result("batch_reuse", out)
    return out


def main() -> List[str]:
    out = run()
    lines = []
    for row in out["policies"]:
        lines.append(csv_line(
            f"batch_reuse[{row['policy']}]", row["warm_s"],
            f"cold_s={row['cold_s']:.3f};warm_s={row['warm_s']:.3f};"
            f"speedup={row['warm_speedup']:.2f};"
            f"resident={row['warm_resident']}"))
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
