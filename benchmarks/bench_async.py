"""Async serving front (ISSUE 10 acceptance): Poisson arrivals from
concurrent clients through the asyncio front, adaptive vs fixed-knob
windows, plus the single-client async-vs-sync latency lane.

Workload: ``N_CLIENTS`` open-loop clients submit filter/project
queries drawn from three TPC-DS-style template families (fresh literal
per arrival — same loose-ψ family, distinct strict fingerprint) with
seeded exponential inter-arrival gaps.  Open loop: a client never
waits for its previous query before submitting the next, so offered
load is independent of service rate — the regime where window sizing
matters.  The fact table is CSV under the paper's commodity-disk
profile, the regime where windows build covering expressions: each
window pays one shared parse+filter CE per family and every member a
cheap extraction, so per-query cost falls as windows grow.

Two modes on identically-primed sessions:
  * **fixed** — every window uses the sync front's static knobs
    (``max_batch=8``, ``max_wait_s=20 ms``);
  * **adaptive** — per-family arrival EWMAs + the p99 SLO budget set
    each window's batch/wait at open time (cap 64).  At this offered
    load the estimated rate fills the SLO budget, windows grow to the
    cap, and the per-window costs (optimizer pass, batched dispatch)
    amortize over 8x more queries.

Measured per mode: end-to-end wall throughput (first submit -> last
resolve) and per-query latency p50/p95/p99 (submit -> future
resolution).  The single-client lane runs the SAME queries
back-to-back through a sync ``QueryService`` and through the async
front (both ``max_batch=1``) on one warm session — the async hop
(queue + one-thread pool + future) must cost < 10%.

Acceptance (loud-fail, like the PR 9 tracing gate):
  * ``adaptive_over_fixed_throughput >= 1.2`` at
    ``adaptive_p99 <= fixed_p99`` (equal-or-better tail);
  * ``async_over_sync_latency <= 1.10`` in the single-client lane.
"""
from __future__ import annotations

import asyncio
import time
from typing import Dict, List

import numpy as np

from common import csv_line, percentile, save_result
from repro.relational import (AsyncConfig, AsyncQueryService, I32,
                              MemoryConfig, QueryService, Schema,
                              Session, SessionConfig, expr as E,
                              make_storage)

NROWS = 100_000
BUDGET = 1 << 30
FMT = "csv"                 # parse is the shareable work CEs eliminate
DISK_LATENCY = 5e-9         # paper §6.3 commodity-disk regime
N_CLIENTS = 32
N_PER_CLIENT = 8               # 256 queries per mode
MEAN_GAP_S = 0.08              # per client => ~400 q/s offered
SLO_P99_S = 2.0
FIXED = dict(max_batch=8, max_wait_s=0.02)
ADAPTIVE = dict(max_batch=8, max_wait_s=0.02, adaptive=True,
                slo_p99_s=SLO_P99_S, max_batch_cap=64,
                exec_default_s=0.05)
SINGLE_N = 50
MIN_ADAPTIVE_SPEEDUP = 1.2
MAX_ASYNC_OVER_SYNC = 1.10

S = Schema.of(("a", I32), ("b", I32), ("c", I32))


def _mk_session() -> Session:
    rng = np.random.default_rng(7)
    cols = {k: rng.integers(0, 1000, NROWS).astype(np.int32)
            for k in ("a", "b", "c")}
    sess = Session.from_config(
        SessionConfig(memory=MemoryConfig(budget_bytes=BUDGET)))
    st, _ = make_storage("t", S, NROWS, FMT, cols=cols)
    sess.register(st, columnar_for_stats=cols)
    sess.disk_latency_per_byte = DISK_LATENCY
    return sess


def _query(sess, fam: int, lit: int):
    """One arrival: template family ``fam`` with a fresh literal —
    same loose-psi family (the adaptive policy's EWMA key), distinct
    strict fingerprint (no trivial resident short-circuit)."""
    t = sess.table("t")
    if fam == 0:
        return t.filter(E.cmp("a", ">", lit)).project("a", "b")
    if fam == 1:
        return t.filter(E.cmp("b", "<", lit)).project("b", "c")
    return (t.filter(E.and_(E.cmp("a", ">", lit),
                            E.cmp("c", ">", lit // 2)))
            .project("a", "c"))


def _prime(sess) -> None:
    """Pay jit + the plan-shape compile cache once per session (both
    modes get the identical priming), outside the measured stream."""
    sess.run_batch([_query(sess, f, 100 + f) for f in range(3)],
                   mqo=True)
    sess.run_batch([_query(sess, f, 900 - f) for f in range(3)],
                   mqo=True)


async def _client(svc, idx: int, rng, lats: List[float], waiters):
    for k in range(N_PER_CLIENT):
        await asyncio.sleep(float(rng.exponential(MEAN_GAP_S)))
        q = _query(svc.session, (idx + k) % 3,
                   int(rng.integers(1, 999)))
        t0 = time.perf_counter()
        h = await svc.submit(q)

        async def waiter(h=h, t0=t0):
            await h
            lats.append(time.perf_counter() - t0)

        waiters.append(asyncio.create_task(waiter()))


def _run_mode(name: str, cfg_kw: Dict) -> Dict:
    sess = _mk_session()
    _prime(sess)

    async def go(seed0: int):
        lats: List[float] = []
        waiters: List[asyncio.Task] = []
        async with AsyncQueryService(
                sess, config=AsyncConfig(**cfg_kw)) as svc:
            rngs = [np.random.default_rng(seed0 + i)
                    for i in range(N_CLIENTS)]
            t0 = time.perf_counter()
            await asyncio.gather(*(
                _client(svc, i, rngs[i], lats, waiters)
                for i in range(N_CLIENTS)))
            await svc.flush()
            await asyncio.gather(*waiters)
            wall = time.perf_counter() - t0
        return lats, wall

    asyncio.run(go(5000))    # unmeasured: pays this mode's own
    #                          batched-kernel compile shapes
    reg = sess.telemetry().registry
    w_before = reg.value("windows.closed")
    lats, wall = asyncio.run(go(1000))
    n = N_CLIENTS * N_PER_CLIENT
    assert len(lats) == n, (name, len(lats))
    windows = reg.value("windows.closed") - w_before
    batch_h = reg.histogram("window.adaptive.batch")
    return {
        "mode": name, "n_queries": n, "wall_s": wall,
        "throughput_qps": n / max(wall, 1e-12),
        "latency_p50_s": percentile(lats, 0.50),
        "latency_p95_s": percentile(lats, 0.95),
        "latency_p99_s": percentile(lats, 0.99),
        "windows_closed": windows,
        "mean_window_size": n / max(windows, 1),
        "adaptive_batch_mean": (batch_h.mean
                                if batch_h.count else None),
        "predicted_saving_s_ewma":
            reg.ewma("window.adaptive.predicted_saving_s").value or None,
        "realized_saving_s_ewma":
            reg.ewma("window.adaptive.realized_saving_s").value or None,
    }


def _single_client_lane() -> Dict:
    """Same warm session, same query stream: sync QueryService vs the
    async front, one query at a time (max_batch=1)."""
    sess = _mk_session()
    _prime(sess)
    rng = np.random.default_rng(42)
    lits = [int(rng.integers(1, 999)) for _ in range(SINGLE_N)]

    svc = QueryService(sess, max_batch=1)
    sync_lats: List[float] = []
    for k, lit in enumerate(lits):
        q = _query(sess, k % 3, lit)
        t0 = time.perf_counter()
        svc.submit(q).result()
        sync_lats.append(time.perf_counter() - t0)

    async def go():
        lats: List[float] = []
        async with AsyncQueryService(
                sess, config=AsyncConfig(max_batch=1)) as asvc:
            # unmeasured warm-up of the loop/pool plumbing
            await (await asvc.submit(_query(sess, 0, 500)))
            for k, lit in enumerate(lits):
                q = _query(sess, k % 3, lit)
                t0 = time.perf_counter()
                h = await asvc.submit(q)
                await h
                lats.append(time.perf_counter() - t0)
        return lats

    async_lats = asyncio.run(go())
    s_mean = sum(sync_lats) / len(sync_lats)
    a_mean = sum(async_lats) / len(async_lats)
    return {
        "n_queries": SINGLE_N,
        "sync_mean_s": s_mean, "async_mean_s": a_mean,
        "sync_p50_s": percentile(sync_lats, 0.50),
        "async_p50_s": percentile(async_lats, 0.50),
        "async_over_sync_latency": a_mean / max(s_mean, 1e-12),
    }


def run() -> Dict:
    fixed = _run_mode("fixed", FIXED)
    adaptive = _run_mode("adaptive", ADAPTIVE)
    single = _single_client_lane()
    speedup = (adaptive["throughput_qps"]
               / max(fixed["throughput_qps"], 1e-12))
    out = {
        "nrows": NROWS, "n_clients": N_CLIENTS,
        "n_per_client": N_PER_CLIENT, "mean_gap_s": MEAN_GAP_S,
        "offered_qps": N_CLIENTS / MEAN_GAP_S,
        "slo_p99_s": SLO_P99_S,
        "fixed": fixed, "adaptive": adaptive,
        "single_client": single,
        "adaptive_over_fixed_throughput": speedup,
        "min_adaptive_speedup": MIN_ADAPTIVE_SPEEDUP,
        "max_async_over_sync": MAX_ASYNC_OVER_SYNC,
    }
    save_result("async_serving", out)
    if speedup < MIN_ADAPTIVE_SPEEDUP:
        raise RuntimeError(
            f"async serving gate: adaptive/fixed throughput "
            f"{speedup:.2f} < {MIN_ADAPTIVE_SPEEDUP}")
    if adaptive["latency_p99_s"] > fixed["latency_p99_s"]:
        raise RuntimeError(
            f"async serving gate: adaptive p99 "
            f"{adaptive['latency_p99_s']:.3f}s worse than fixed "
            f"{fixed['latency_p99_s']:.3f}s")
    if single["async_over_sync_latency"] > MAX_ASYNC_OVER_SYNC:
        raise RuntimeError(
            f"async serving gate: single-client async/sync latency "
            f"{single['async_over_sync_latency']:.3f} > "
            f"{MAX_ASYNC_OVER_SYNC}")
    return out


def main() -> List[str]:
    out = run()
    f, a, s = out["fixed"], out["adaptive"], out["single_client"]
    return [
        csv_line("async_fixed", f["wall_s"] / f["n_queries"],
                 f"qps={f['throughput_qps']:.0f};"
                 f"p50={f['latency_p50_s']*1e3:.1f}ms;"
                 f"p99={f['latency_p99_s']*1e3:.1f}ms;"
                 f"windows={f['windows_closed']}"),
        csv_line("async_adaptive", a["wall_s"] / a["n_queries"],
                 f"qps={a['throughput_qps']:.0f};"
                 f"p50={a['latency_p50_s']*1e3:.1f}ms;"
                 f"p99={a['latency_p99_s']*1e3:.1f}ms;"
                 f"windows={a['windows_closed']};"
                 f"speedup={out['adaptive_over_fixed_throughput']:.2f}"),
        csv_line("async_single_client", s["async_mean_s"],
                 f"sync={s['sync_mean_s']*1e3:.2f}ms;"
                 f"async={s['async_mean_s']*1e3:.2f}ms;"
                 f"ratio={s['async_over_sync_latency']:.3f}"),
    ]


if __name__ == "__main__":
    print("\n".join(main()))
