"""Paper Fig. 3 + §6.2 text: TPC-DS macro-benchmark.

All 50 queries of the TPC-DS-analog workload executed in identifier
order with MQO enabled vs disabled.  Reports: per-query runtime-ratio
CDF (the paper: ~60 % of queries at ≥80 % reduction, ~82 % improved),
SE/CE counts, optimizer wall time (paper: < 2 s), and cache bytes.
"""
from __future__ import annotations

from typing import Dict, List

from common import csv_line, percentile, save_result
from repro.relational.tpcds import build_tpcds_session, tpcds_queries


def run(scale_rows: int = 120_000, budget: int = 1 << 30,
        fmt: str = "csv") -> Dict:
    # the paper's macro benchmark generates a CSV dataset (§6.1) — the
    # parse cost is precisely the shareable work the CEs eliminate
    sess = build_tpcds_session(scale_rows=scale_rows,
                               budget_bytes=budget, fmt=fmt)
    qs = tpcds_queries(sess)
    sess.run_batch(qs, mqo=False)                # jit warmup pass
    base = sess.run_batch(qs, mqo=False)
    sess.run_batch(qs, mqo=True)
    opt = sess.run_batch(qs, mqo=True)
    for i, (b, o) in enumerate(zip(base.results, opt.results)):
        assert b.table.row_multiset() == o.table.row_multiset(), i

    ratios = [o.seconds / max(b.seconds, 1e-9)
              for b, o in zip(base.results, opt.results)]
    r = opt.mqo.report
    out = {
        "n_queries": len(qs),
        "ratios": ratios,
        "improved_frac": sum(1 for x in ratios if x < 1.0) / len(ratios),
        "ge80pct_reduction_frac": sum(1 for x in ratios if x <= 0.2)
        / len(ratios),
        "median_ratio": percentile(ratios, 0.5),
        "agg_base_s": base.total_seconds,
        "agg_opt_s": opt.total_seconds,
        "agg_ratio": opt.total_seconds / base.total_seconds,
        "n_ses": r.n_ses, "n_ces": r.n_ces,
        "n_selected": r.n_selected,
        "optimize_seconds": r.optimize_seconds,
        "cache_used_bytes": opt.cache_report.get("used", 0),
        "cache_budget": opt.cache_report.get("budget", 0),
    }
    save_result("macro_tpcds", out)
    return out


def run_disk_profile(scale_rows: int = 120_000,
                     budget: int = 1 << 30,
                     disk_latency_per_byte: float = 5e-9) -> Dict:
    """Fig. 3 under the paper's storage regime: a ~200 MB/s
    commodity-disk read cost on every byte fetched from the catalog
    (cache hits skip it — exactly the disk-read avoidance the paper
    measures).  Single pass: jits are warm from the RAM-profile run
    and the sleep term dominates."""
    sess = build_tpcds_session(scale_rows=scale_rows,
                               budget_bytes=budget, fmt="csv")
    sess.disk_latency_per_byte = disk_latency_per_byte
    qs = tpcds_queries(sess)
    base = sess.run_batch(qs, mqo=False)
    opt = sess.run_batch(qs, mqo=True)
    for b, o in zip(base.results, opt.results):
        assert b.table.row_multiset() == o.table.row_multiset()
    ratios = sorted(o.seconds / max(b.seconds, 1e-9)
                    for b, o in zip(base.results, opt.results))
    out = {
        "agg_ratio": opt.total_seconds / base.total_seconds,
        "improved_frac": sum(1 for x in ratios if x < 1) / len(ratios),
        "ge80pct_reduction_frac": sum(1 for x in ratios if x <= 0.2)
        / len(ratios),
        "median_ratio": percentile(ratios, 0.5),
    }
    save_result("macro_tpcds_disk", out)
    return out


def main() -> List[str]:
    out = run()
    lines = [csv_line(
        "macro_tpcds[50q]", out["agg_opt_s"],
        f"agg_ratio={out['agg_ratio']:.2f};"
        f"improved={out['improved_frac']:.2f};"
        f"ge80pct={out['ge80pct_reduction_frac']:.2f};"
        f"ses={out['n_ses']};opt_s={out['optimize_seconds']:.2f}")]
    d = run_disk_profile()
    lines.append(csv_line(
        "macro_tpcds[50q,disk200MBps]", 0.0,
        f"agg_ratio={d['agg_ratio']:.2f};"
        f"improved={d['improved_frac']:.2f};"
        f"ge80pct={d['ge80pct_reduction_frac']:.2f};"
        f"median={d['median_ratio']:.2f}"))
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
