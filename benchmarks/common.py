"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Callable, Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                           "bench")


def save_result(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=str)


def csv_line(name: str, seconds_per_call: float, derived: str) -> str:
    return f"{name},{seconds_per_call * 1e6:.1f},{derived}"


def timed(fn: Callable, warmup: int = 0, iters: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def percentile(xs: List[float], q: float) -> float:
    xs = sorted(xs)
    if not xs:
        return float("nan")
    i = min(len(xs) - 1, int(q * (len(xs) - 1) + 0.5))
    return xs[i]
