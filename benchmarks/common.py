"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Callable, Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                           "bench")


def save_result(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=str)


def csv_line(name: str, seconds_per_call: float, derived: str) -> str:
    return f"{name},{seconds_per_call * 1e6:.1f},{derived}"


def timed(fn: Callable, warmup: int = 0, iters: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def fused_vs_eager(mk_session, chain_queries, result_name: str,
                   sizes=(100_000,), fmts=("csv", "columnar"),
                   budget: int = 1 << 28, repeats: int = 3) -> Dict:
    """Shared fused-vs-seed-eager harness (ISSUE 1 acceptance).

    ``mk_session(nrows, fmt, budget, fused=...)`` builds a Session
    (fused=False must reproduce the seed eager executor);
    ``chain_queries(sess)`` builds the batched Scan→Filter→Project
    chains.  Warmup pays jit compilation (and fills the fused session's
    scan cache — the steady state under measurement); results are
    asserted equal before timing.
    """
    out: Dict = {"rows": []}
    for fmt in fmts:
        for n in sizes:
            eager = mk_session(n, fmt, budget, fused=False)
            fused = mk_session(n, fmt, budget, fused=True)
            qe, qf = chain_queries(eager), chain_queries(fused)
            be = eager.run_batch(qe, mqo=False)
            bf = fused.run_batch(qf, mqo=False)
            for b, o in zip(be.results, bf.results):
                assert b.table.row_multiset() == o.table.row_multiset()
            t_eager = min(eager.run_batch(qe, mqo=False).total_seconds
                          for _ in range(repeats))
            t_fused = min(fused.run_batch(qf, mqo=False).total_seconds
                          for _ in range(repeats))
            out["rows"].append({
                "fmt": fmt, "nrows": n,
                "agg_eager": t_eager, "agg_fused": t_fused,
                "fused_speedup": t_eager / max(t_fused, 1e-12),
            })
    save_result(result_name, out)
    return out


def percentile(xs: List[float], q: float) -> float:
    xs = sorted(xs)
    if not xs:
        return float("nan")
    i = min(len(xs) - 1, int(q * (len(xs) - 1) + 0.5))
    return xs[i]
