"""Paper Fig. 4: batching-window sweep.

Random query subsets (without replacement) of increasing window size
are optimized and executed; reports the runtime-ratio and SE-count
distributions per window size — reproducing the paper's trend: larger
windows => more SEs => lower aggregate runtime (median reduction ~20 %
at window 5 rising toward ~45 % at window 20).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from common import csv_line, percentile, save_result
from repro.relational.tpcds import build_tpcds_session, tpcds_queries


def run(window_sizes=(2, 5, 10, 15, 20), trials: int = 5,
        scale_rows: int = 60_000, budget: int = 1 << 30,
        seed: int = 0) -> Dict:
    sess = build_tpcds_session(scale_rows=scale_rows, budget_bytes=budget,
                               fmt="csv")  # paper §6.1: CSV dataset
    qs = tpcds_queries(sess)
    rng = np.random.default_rng(seed)
    out: Dict = {"window_sizes": list(window_sizes), "per_window": {}}
    for w in window_sizes:
        ratios, n_ses = [], []
        for _ in range(trials):
            idx = rng.choice(len(qs), size=w, replace=False)
            batch = [qs[i] for i in idx]
            sess.run_batch(batch, mqo=False)     # jit warmup pass
            base = sess.run_batch(batch, mqo=False)
            sess.run_batch(batch, mqo=True)
            opt = sess.run_batch(batch, mqo=True)
            for b, o in zip(base.results, opt.results):
                assert b.table.row_multiset() == o.table.row_multiset()
            ratios.append(opt.total_seconds / base.total_seconds)
            n_ses.append(opt.mqo.report.n_ses)
        out["per_window"][w] = {
            "ratios": ratios,
            "median_ratio": percentile(ratios, 0.5),
            "mean_ses": float(np.mean(n_ses)),
            "ses": n_ses,
        }
    save_result("window_sweep", out)
    return out


def main() -> List[str]:
    out = run()
    lines = []
    for w, d in out["per_window"].items():
        lines.append(csv_line(
            f"window_sweep[w={w}]", d["median_ratio"],
            f"median_ratio={d['median_ratio']:.2f};"
            f"mean_ses={d['mean_ses']:.1f}"))
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
