"""Paper Fig. 8–9: projection-based micro-benchmark.

Two projection-only queries over overlapping column sets.  On the
columnar (Parquet-analog) format projections are already cheap, so the
paper reports near-zero benefit vs baseline (while still beating naive
full caching); on CSV the parse cost makes worksharing win big.  Both
effects are asserted in the derived output.
"""
from __future__ import annotations

from typing import Dict, List

from common import csv_line, fused_vs_eager, save_result
from repro.relational import Session, SessionConfig, expr as E, make_storage
from repro.relational.datagen import generate_columns, people_schema


def _mk_session(nrows: int, fmt: str, budget: int,
                fused: bool = True) -> Session:
    schema = people_schema()
    cols = generate_columns(schema, nrows, seed=1)
    sess = Session.from_config(SessionConfig.from_legacy_kwargs(
        budget_bytes=budget, fuse=fused, defer_sync=fused,
        use_scan_cache=fused))
    st, _ = make_storage("people", schema, nrows, fmt, cols=cols)
    sess.register(st, columnar_for_stats=cols)
    return sess


def _queries(sess: Session):
    people = sess.table("people")
    q1 = people.project("name", "age", "salary")
    q2 = people.project("name", "dept", "d1", "d2")
    return [q1, q2]


def _chain_queries(sess: Session):
    """Scan→Filter→Project chains over the projection workload's wide
    column sets (the projection benchmark's fusion-layer variant)."""
    people = sess.table("people")
    return [
        people.filter(E.cmp("salary", ">", 100))
              .project("name", "age", "salary"),
        people.filter(E.cmp("d1", "<", 0.75))
              .project("name", "dept", "d1", "d2"),
    ]


def run_fused_vs_eager(**kw) -> Dict:
    """ISSUE 1 acceptance: fusion layer on vs the seed eager path."""
    kw.setdefault("fmts", ("columnar", "csv"))
    return fused_vs_eager(_mk_session, _chain_queries,
                          "projection_micro_fused", **kw)


def run(sizes=(50_000, 100_000), fmts=("columnar", "csv"),
        budget=1 << 28) -> Dict:
    out: Dict = {"rows": []}
    for fmt in fmts:
        for n in sizes:
            sess = _mk_session(n, fmt, budget)
            qs = _queries(sess)
            sess.run_batch(qs, mqo=False)        # jit warmup pass
            base = sess.run_batch(qs, mqo=False)
            sess.run_batch_fullcache(qs)
            fc = sess.run_batch_fullcache(qs)
            sess.run_batch(qs, mqo=True)
            ws = sess.run_batch(qs, mqo=True)
            for b, o in zip(base.results, ws.results):
                assert b.table.row_multiset() == o.table.row_multiset()
            out["rows"].append({
                "fmt": fmt, "nrows": n,
                "agg_base": base.total_seconds,
                "agg_fc": fc.total_seconds,
                "agg_ws": ws.total_seconds,
                "ws_over_base": ws.total_seconds / base.total_seconds,
                "ws_over_fc": ws.total_seconds / max(fc.total_seconds,
                                                     1e-9),
            })
    save_result("projection_micro", out)
    return out


def main() -> List[str]:
    out = run()
    lines = [csv_line(
        f"projection_micro[{r['fmt']},{r['nrows']}]", r["agg_ws"],
        f"ws/base={r['ws_over_base']:.2f};ws/fc={r['ws_over_fc']:.2f}")
        for r in out["rows"]]
    fused = run_fused_vs_eager()
    for r in fused["rows"]:
        lines.append(csv_line(
            f"projection_micro_fused[{r['fmt']},{r['nrows']}]",
            r["agg_fused"],
            f"fused_speedup={r['fused_speedup']:.2f}"))
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
