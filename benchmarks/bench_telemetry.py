"""Telemetry tracing-overhead gate (PR 9).

Thin runner around :func:`bench_service.main_tracing`: warm-window
throughput on the recurring dashboard with span tracing enabled must
stay >= 0.95x the tracing-disabled throughput (the always-on metrics
registry + calibration log are common to both modes).  Emits the
``service_tracing_overhead`` result set consumed by BENCH_pr9.json.
"""
from bench_service import main_tracing as main

if __name__ == "__main__":
    print("\n".join(main()))
