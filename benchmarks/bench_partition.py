"""Partition-grained caching (ISSUE 4 acceptance): the selective
dashboard stream under a budget that cannot hold a full CE.

The workload is a RECURRING selective dashboard over one partitioned
CSV fact table (range partitioning on ``n1``): one window-sized
template of 4 selective queries (every filter keeps < 40% of the
table, all in the hot ``n1`` range) arrives over and over.  Each
window's MQO merges the template into one covering scan+filter CE
whose live partitions are a strict subset of the table (pruning) — and
the session budget is sized BELOW the full CE weight, so the whole-CE
knapsack of PR 2/3 could admit nothing at all.  The partition-grained
MCKP instead admits the hot fraction: a strict subset of the CE's
partitions, which stays resident across windows; the cold remainder is
recomputed per window (composed at read time).

Measured (wall time around the full streamed pass, as in
bench_batch_reuse's cold-vs-warm-repeat):
  * ``cold_stream_s`` — first streamed pass on a fresh session: every
    window pays disk + CSV parse for all live partitions, plus the
    partial materialization;
  * ``warm_stream_s`` — steady-state repeat (best of ``REPEATS``):
    resident partitions are re-priced as zero-weight items and read
    from cache; only the non-admitted partitions re-pay disk + parse.

Acceptance (BENCH_pr4.json):
  * the optimizer admits a STRICT subset of the CE's live partitions;
  * partition_warm_speedup = cold_stream_s / warm_stream_s >= 1.3.
"""
from __future__ import annotations

import time
from typing import Dict

from common import csv_line, save_result
from repro.relational import (MemoryConfig, Partitioning, QueryService,
                              Session, SessionConfig, expr as E,
                              make_storage)
from repro.relational.datagen import generate_columns, synthetic_schema

SCALE_ROWS = 120_000
FMT = "csv"                 # parse is the shareable work CEs eliminate
DISK_LATENCY = 5e-9         # paper §6.3 commodity-disk regime (~200 MB/s)
N_PARTITIONS = 8
MAX_BATCH = 4               # one dashboard template per window
N_WINDOWS = 4               # windows per streamed pass
REPEATS = 5
BUDGET_FRACTION = 0.7       # of the full CE weight: forces partial
                            # admission (strict subset of partitions)

SCHEMA = synthetic_schema(n_int=6, n_dbl=4, n_str=2)
COLS = generate_columns(SCHEMA, SCALE_ROWS, seed=4)


def build_session(budget_bytes: int) -> Session:
    sess = Session.from_config(SessionConfig(
        memory=MemoryConfig(budget_bytes=budget_bytes)))
    sess.disk_latency_per_byte = DISK_LATENCY
    st, _ = make_storage("fact", SCHEMA, SCALE_ROWS, FMT, cols=COLS)
    sess.register(st, columnar_for_stats=COLS,
                  partitioning=Partitioning("n1", "range", N_PARTITIONS))
    return sess


def _template(sess: Session):
    """One window's worth of the recurring dashboard: 4 selective
    queries sharing the scan+filter SE (n1 uniform in [1, 1000], every
    threshold keeps the hot < 40% — pruning leaves ~half the
    partitions live)."""
    t = lambda: sess.table("fact")
    return [
        t().filter(E.cmp("n1", "<", 250))
        .project("n1", "n2", "n3", "d1"),
        t().filter(E.and_(E.cmp("n1", "<", 300), E.cmp("d1", "<", 0.9)))
        .project("n1", "n2", "d1", "d2"),
        t().filter(E.cmp("n1", "<", 350)).project("n1", "n4", "d3"),
        t().filter(E.and_(E.cmp("n1", "<", 400), E.cmp("n2", ">", 100)))
        .project("n1", "n2", "n5"),
    ]


def _stream(sess: Session):
    return _template(sess) * N_WINDOWS


def probe_full_ce_weight() -> int:
    """Full CE weight (sum of its partition slices) of one template
    window under an unconstrained budget — what the acceptance budget
    must undercut."""
    sess = build_session(1 << 30)
    r = sess.run_batch(_template(sess), mqo=True)
    weights = [sum(sl.weight for sl in ce.partition_detail[1])
               for ce in r.mqo.rewritten.ces if ce.partition_detail]
    return max(weights) if weights else 0


def _streamed_pass(svc: QueryService, queries) -> Dict:
    t0 = time.perf_counter()
    handles = [svc.submit(q) for q in queries]
    svc.flush()
    return {"seconds": time.perf_counter() - t0, "handles": handles}


def run() -> Dict:
    full_ce_w = probe_full_ce_weight()
    # the budget cannot hold one full CE: whole-CE admission of PR 2/3
    # would have nothing to select at all
    budget = max(int(full_ce_w * BUDGET_FRACTION), 1 << 16)

    # jit warmup on a throwaway session (as in bench_service)
    warm_sess = build_session(budget)
    wsvc = QueryService(warm_sess, max_batch=MAX_BATCH)
    for q in _stream(warm_sess):
        wsvc.submit(q)
    wsvc.flush()

    # cold streamed pass: fresh session, every window pays in full
    sess = build_session(budget)
    queries = _stream(sess)
    svc = QueryService(sess, max_batch=MAX_BATCH)
    cold = _streamed_pass(svc, queries)

    # partial admission must be real: a strict subset of live parts
    partial = []
    for h in cold["handles"]:
        for ce in h.explain()["ces"]:
            if "partitions" in ce:
                partial.append(ce["partitions"])
        break
    strict_subset = any(0 < len(p["admitted"]) < len(p["live"])
                        for p in partial)

    # steady-state repeats on the long-lived session
    warm_passes = [_streamed_pass(svc, queries) for _ in range(REPEATS)]
    warm = min(warm_passes, key=lambda p: p["seconds"])

    # correctness: streamed results match independent execution
    base = sess.run_batch(_template(sess), mqo=False)
    for b, h in zip(base.results, warm["handles"][-MAX_BATCH:]):
        assert b.table.row_multiset() == h.result().row_multiset()

    resident = {k.hex()[:12]: sorted(v)
                for k, v in sess.ce_resident_parts().items()}
    out = {
        "scale_rows": SCALE_ROWS, "fmt": FMT,
        "disk_latency_per_byte": DISK_LATENCY,
        "n_partitions": N_PARTITIONS,
        "n_queries": len(queries), "max_batch": MAX_BATCH,
        "full_ce_weight": full_ce_w,
        "budget_bytes": budget,
        "partition_admission": partial,
        "admitted_strict_subset": strict_subset,
        "resident_parts": resident,
        "cold_stream_s": cold["seconds"],
        "warm_stream_s": warm["seconds"],
        "warm_pass_seconds": [p["seconds"] for p in warm_passes],
        "partition_warm_speedup": cold["seconds"]
        / max(warm["seconds"], 1e-12),
        "accept_speedup_ge_1_3": cold["seconds"]
        / max(warm["seconds"], 1e-12) >= 1.3,
    }
    save_result("bench_partition", out)
    return out


def main():
    out = run()
    yield csv_line("partition_cold_stream", out["cold_stream_s"],
                   f"budget={out['budget_bytes']}")
    yield csv_line("partition_warm_stream", out["warm_stream_s"],
                   f"speedup={out['partition_warm_speedup']:.2f}x "
                   f"subset={out['admitted_strict_subset']}")


if __name__ == "__main__":
    for line in main():
        print(line)
