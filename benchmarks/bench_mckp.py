"""Paper §6.2 text: optimizer overhead ("less than 2 seconds").

Scales the MCKP + candidate-generation machinery over synthetic CE
populations far beyond the paper's (60 SEs / 45 CEs) and measures the
end-to-end optimize time of the 50-query TPC-DS batch.
"""
from __future__ import annotations

import time
from typing import Dict, List

from common import csv_line, save_result
from repro.core.candidates import KnapsackItem
from repro.core.covering import CoveringExpression
from repro.core.identify import SimilarSubexpression
from repro.core.mckp import solve_mckp


def _items(g: int, per_group: int) -> List[KnapsackItem]:
    items = []
    for gi in range(g):
        for j in range(per_group):
            se = SimilarSubexpression(psi=bytes([gi % 256, j % 256]) * 8)
            ce = CoveringExpression(se=se, tree=None, psi=se.psi)  # type: ignore
            ce.value = float((gi * 31 + j * 7) % 97 + 1)
            ce.weight = ((gi * 131 + j * 17) % 4096 + 1) * 1024
            items.append(KnapsackItem(ces=(ce,), group=gi))
    return items


def run() -> Dict:
    out: Dict = {"solver": [], "end_to_end": None}
    for g, per in [(45, 4), (200, 8), (1000, 8), (5000, 4)]:
        items = _items(g, per)
        t0 = time.perf_counter()
        sol = solve_mckp(items, capacity=256 << 20, max_buckets=4096)
        dt = time.perf_counter() - t0
        out["solver"].append({"groups": g, "items": len(items),
                              "seconds": dt, "value": sol.total_value})

    from repro.relational.tpcds import build_tpcds_session, tpcds_queries
    from repro.core.optimizer import MultiQueryOptimizer
    from repro.relational.rewriter import (RelationalRewriter,
                                           make_ce_transform)
    from repro.relational.rules import optimize_single

    sess = build_tpcds_session(scale_rows=20_000)
    plans = [optimize_single(q) for q in tpcds_queries(sess)]
    opt = MultiQueryOptimizer(sess.cost_model, RelationalRewriter(),
                              budget_bytes=1 << 30,
                              ce_transform=make_ce_transform())
    t0 = time.perf_counter()
    res = opt.optimize(plans)
    out["end_to_end"] = {"seconds": time.perf_counter() - t0,
                         "n_ses": res.report.n_ses,
                         "n_ces": res.report.n_ces}
    save_result("mckp_overhead", out)
    return out


def main() -> List[str]:
    out = run()
    lines = [csv_line(f"mckp_solver[g={r['groups']}]", r["seconds"],
                      f"items={r['items']}") for r in out["solver"]]
    e = out["end_to_end"]
    lines.append(csv_line("mqo_optimize[50q]", e["seconds"],
                          f"ses={e['n_ses']};under_2s="
                          f"{e['seconds'] < 2.0}"))
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
