"""Physical operator correctness vs the numpy oracle, incl. CSV parsing."""
import numpy as np
import pytest

from oracle import execute_oracle, multiset
from repro.relational import (F32, I32, STR, ExecContext, Schema, execute,
                              expr as E, logical as L, make_storage)
from repro.relational.datagen import generate_columns, to_csv_bytes

SCHEMA = Schema.of(("k", I32), ("v", I32), ("x", F32), ("s", STR(8)))


def _toy(nrows=257, seed=0, fmt="columnar"):
    rng = np.random.default_rng(seed)
    cols = {
        "k": rng.integers(0, 20, nrows).astype(np.int32),
        "v": rng.integers(0, 1000, nrows).astype(np.int32),
        "x": rng.random(nrows).astype(np.float32),
        "s": rng.integers(97, 100, (nrows, 8)).astype(np.uint8),
    }
    st, _ = make_storage("t", SCHEMA, nrows, fmt, cols=cols)
    return st, cols


def _run(plan, storages):
    catalog = {st.name: st for st, _ in storages}
    ctx = ExecContext(catalog=catalog)
    table = execute(plan, ctx)
    return table.row_multiset()


def _expect(plan, storages):
    catalog = {}
    for st, cols in storages:
        if st.fmt == "csv":
            # apply the CSV storage truncation (8 fractional digits) so
            # the oracle sees what the engine can possibly read back
            cols = {
                n: (np.floor(a.astype(np.float64) * 1e8) / 1e8
                    ).astype(np.float32) if a.dtype == np.float32 else a
                for n, a in cols.items()
            }
        catalog[st.name] = (st.schema, st.nrows, cols)
    return multiset(execute_oracle(plan, catalog), plan.schema)


@pytest.mark.parametrize("fmt", ["columnar", "csv"])
class TestScanFormats:
    def test_roundtrip(self, fmt):
        st, cols = _toy(fmt=fmt)
        # exact columns round-trip exactly; the f32 column is checked
        # with allclose in TestCSVParse (CSV digit parse has ~1e-7 noise
        # that can flip the multiset's 4-decimal rounding on knife-edge
        # values).
        plan = L.scan("t", SCHEMA, fmt).project("k", "v", "s")
        assert _run(plan, [(st, cols)]) == _expect(plan, [(st, cols)])

    def test_filter(self, fmt):
        st, cols = _toy(fmt=fmt)
        plan = L.scan("t", SCHEMA, fmt).filter(E.cmp("v", ">", 500))
        assert _run(plan, [(st, cols)]) == _expect(plan, [(st, cols)])


class TestOps:
    def setup_method(self):
        self.st, self.cols = _toy()
        self.scan = L.scan("t", SCHEMA, "columnar")
        self.pair = [(self.st, self.cols)]

    def test_filter_compound_predicate(self):
        p = self.scan.filter(E.or_(
            E.and_(E.cmp("v", ">", 800), E.cmp("k", "<=", 10)),
            E.cmp("x", "<", 0.05),
            E.not_(E.cmp("v", "!=", 3)),
        ))
        assert _run(p, self.pair) == _expect(p, self.pair)

    def test_filter_string_eq(self):
        s0 = bytes(self.cols["s"][0].tobytes())
        p = self.scan.filter(E.cmp("s", "==", s0))
        got = _run(p, self.pair)
        assert got == _expect(p, self.pair)
        assert len(got) >= 1

    def test_filter_empty_result(self):
        p = self.scan.filter(E.cmp("v", ">", 10**8))
        assert _run(p, self.pair) == []

    def test_project(self):
        p = self.scan.project("v", "s")
        assert _run(p, self.pair) == _expect(p, self.pair)

    def test_sort_asc_desc(self):
        for desc in (False, True):
            p = self.scan.project("v", "k").sort("v", desc=desc)
            assert _run(p, self.pair) == _expect(p, self.pair)

    def test_limit(self):
        # limit rows are order-dependent; compare row COUNT + containment
        p = self.scan.sort("v").limit(10)
        got = _run(p, self.pair)
        assert len(got) == 10

    def test_union(self):
        a = self.scan.filter(E.cmp("v", ">", 900)).project("k", "v")
        b = self.scan.filter(E.cmp("v", "<", 50)).project("k", "v")
        p = a.union(b)
        assert _run(p, self.pair) == _expect(p, self.pair)

    def test_aggregate_all_fns(self):
        p = self.scan.groupby("k").agg(
            ("n", "count", ""), ("sv", "sum", "v"), ("mn", "min", "v"),
            ("mx", "max", "v"), ("avg", "mean", "x"))
        assert _run(p, self.pair) == _expect(p, self.pair)

    def test_aggregate_multikey(self):
        st2, cols2 = _toy(nrows=300, seed=3)
        p = (L.scan("t", SCHEMA, "columnar")
             .filter(E.cmp("v", "<", 500))
             .groupby("k", "v").agg(("n", "count", "")))
        assert _run(p, [(st2, cols2)]) == _expect(p, [(st2, cols2)])


class TestJoin:
    def _two(self, nl=211, nr=97, dup=True, seed=1):
        rng = np.random.default_rng(seed)
        sl = Schema.of(("a", I32), ("p", I32))
        sr = Schema.of(("b", I32), ("q", I32))
        lcols = {"a": rng.integers(0, 40, nl).astype(np.int32),
                 "p": rng.integers(0, 100, nl).astype(np.int32)}
        hi = 40 if dup else nr
        rcols = {"b": (rng.integers(0, hi, nr).astype(np.int32) if dup
                       else np.arange(nr, dtype=np.int32)),
                 "q": rng.integers(0, 100, nr).astype(np.int32)}
        stl, _ = make_storage("l", sl, nl, "columnar", cols=lcols)
        str_, _ = make_storage("r", sr, nr, "columnar", cols=rcols)
        return (stl, lcols), (str_, rcols), sl, sr

    def test_many_to_many(self):
        (stl, lc), (str_, rc), sl, sr = self._two(dup=True)
        p = L.scan("l", sl).join(L.scan("r", sr), "a", "b")
        assert _run(p, [(stl, lc), (str_, rc)]) == _expect(
            p, [(stl, lc), (str_, rc)])

    def test_fk_join(self):
        (stl, lc), (str_, rc), sl, sr = self._two(dup=False)
        p = L.scan("l", sl).join(L.scan("r", sr), "a", "b")
        assert _run(p, [(stl, lc), (str_, rc)]) == _expect(
            p, [(stl, lc), (str_, rc)])

    def test_join_no_matches(self):
        (stl, lc), (str_, rc), sl, sr = self._two()
        p = (L.scan("l", sl).filter(E.cmp("a", ">", 1000))
             .join(L.scan("r", sr), "a", "b"))
        assert _run(p, [(stl, lc), (str_, rc)]) == []

    def test_join_after_filters_with_stale_padding(self):
        # regression: compaction slack rows must never match (the
        # searchsorted sentinel bug)
        (stl, lc), (str_, rc), sl, sr = self._two(nl=300, nr=100)
        p = (L.scan("l", sl).filter(E.cmp("p", ">", 50))
             .join(L.scan("r", sr).filter(E.cmp("q", "<", 50)), "a", "b"))
        assert _run(p, [(stl, lc), (str_, rc)]) == _expect(
            p, [(stl, lc), (str_, rc)])


class TestCSVParse:
    def test_csv_int_parse_exact(self):
        rng = np.random.default_rng(0)
        vals = np.concatenate([
            np.array([0, 1, 999_999_999], np.int32),
            rng.integers(0, 10**9, 61).astype(np.int32)])
        schema = Schema.of(("v", I32))
        csv = to_csv_bytes(schema, {"v": vals}, len(vals))
        st = __import__("repro.relational.physical", fromlist=["TableStorage"]
                        ).TableStorage("t", schema, len(vals), "csv",
                                       csv_bytes=csv)
        ctx = ExecContext(catalog={"t": st})
        out = execute(L.scan("t", schema, "csv"), ctx)
        np.testing.assert_array_equal(
            np.asarray(out.columns["v"])[: len(vals)], vals)

    def test_csv_float_parse_close(self):
        rng = np.random.default_rng(0)
        vals = rng.random(64).astype(np.float32)
        schema = Schema.of(("x", F32))
        csv = to_csv_bytes(schema, {"x": vals}, len(vals))
        from repro.relational.physical import TableStorage

        st = TableStorage("t", schema, len(vals), "csv", csv_bytes=csv)
        ctx = ExecContext(catalog={"t": st})
        out = execute(L.scan("t", schema, "csv"), ctx)
        np.testing.assert_allclose(
            np.asarray(out.columns["x"])[: len(vals)], vals, atol=1e-6)


class TestPallasFilterPath:
    """The engine's kernel-accelerated filter must agree with XLA."""

    def test_numeric_predicates_match(self):
        st, cols = _toy(nrows=1500, seed=5)
        plan = (L.scan("t", SCHEMA, "columnar")
                .filter(E.or_(E.and_(E.cmp("v", ">", 300),
                                     E.cmp("k", "<=", 15)),
                              E.cmp("x", "<", 0.1)))
                .project("k", "v"))
        ctx_x = ExecContext(catalog={"t": st})
        ctx_p = ExecContext(catalog={"t": st}, use_pallas_filter=True)
        a = execute(plan, ctx_x).row_multiset()
        b = execute(plan, ctx_p).row_multiset()
        assert a == b and len(a) > 0

    def test_string_predicate_falls_back(self):
        st, cols = _toy(nrows=300, seed=6)
        s0 = bytes(cols["s"][0].tobytes())
        plan = L.scan("t", SCHEMA, "columnar").filter(
            E.cmp("s", "==", s0))
        ctx_p = ExecContext(catalog={"t": st}, use_pallas_filter=True)
        ctx_x = ExecContext(catalog={"t": st})
        assert (execute(plan, ctx_p).row_multiset()
                == execute(plan, ctx_x).row_multiset())
