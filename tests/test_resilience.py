"""Fault-tolerant QueryService (ISSUE 6): deterministic fault
injection, per-query isolation, the degradation ladder, transactional
self-auditing memory pools, and the window soak property.

Covers:
  * FaultInjector determinism (seeded Bernoulli + explicit schedules);
  * MemoryManager audit/quarantine/reconcile and the journaled
    two-phase operations (spill faults degrade to drop, books exact);
  * CacheTransaction rollback on partial multi-entry admission —
    including the partition-grained CE integration path;
  * per-query fault isolation: a failing query resolves its own handle
    to a QueryError while siblings complete; a failed shared CE sends
    its consumers to their unshared residual plans;
  * the degradation ladder (kernel route → fused-XLA → eager) with
    bounded attempts and injectable exponential backoff;
  * window exception safety: every handle resolves no matter where the
    window dies, and the service survives to run the next window;
  * the acceptance soak: 100 windows under faults at every named
    point — all handles resolve, audit stays clean after every window,
    and every successful result is bit-identical to a fault-free run
    (hypothesis property over seeds, plus seeded always-run variants).

The CI fault-injection job re-runs this module over a seed matrix via
the FAULT_SEED environment variable.
"""
import os
import random

import numpy as np
import pytest

from repro.core.cache import CacheManager
from repro.core.faults import (FAULT_POINTS, FaultConfig, FaultInjector,
                               InjectedFault)
from repro.core.memory import MemoryManager
from repro.relational import (I32, MemoryConfig, Partitioning, QueryError,
                              QueryService, Relation, Schema, Session,
                              SessionConfig, expr as E, logical as L,
                              make_storage)
from repro.relational.datagen import generate_columns, synthetic_schema

# the CI fault-injection job sweeps this over a small matrix
FAULT_SEED = int(os.environ.get("FAULT_SEED", "0"))

S = Schema.of(("a", I32), ("b", I32), ("c", I32))
NROWS = 2000


def _mk_session(budget=1 << 24, *, config=None) -> Session:
    rng = np.random.default_rng(9)
    cols = {c: rng.integers(0, 100, NROWS).astype(np.int32)
            for c in ("a", "b", "c")}
    if config is None:
        config = SessionConfig(memory=MemoryConfig(budget_bytes=budget))
    sess = Session.from_config(config)
    st, _ = make_storage("t", S, NROWS, "columnar", cols=cols)
    sess.register(st)
    return sess


def _cfg(budget=1 << 24, **fault_kw) -> SessionConfig:
    return SessionConfig(
        memory=MemoryConfig(budget_bytes=budget)
    ).with_faults(FaultConfig(**fault_kw))


def _queries(sess):
    """Fixed 6-template pool: overlapping predicates so windows form
    CEs; a FIXED pool keeps the jit cache warm across soak windows."""
    t = lambda: sess.table("t")  # noqa: E731
    return [
        t().filter(E.cmp("a", ">", 50)).project("a", "b"),
        t().filter(E.and_(E.cmp("a", ">", 50), E.cmp("b", "<", 40)))
           .project("a", "b"),
        t().filter(E.and_(E.cmp("a", ">", 50), E.cmp("c", ">", 20)))
           .project("a", "c"),
        t().filter(E.cmp("b", "<", 70)).project("b", "c"),
        t().filter(E.and_(E.cmp("b", "<", 70), E.cmp("c", ">", 10)))
           .project("b", "c"),
        t().filter(E.cmp("c", ">", 35)).project("a", "b", "c"),
    ]


def _tables_bit_identical(ta, tb):
    assert ta.nrows == tb.nrows
    assert ta.schema.names == tb.schema.names
    for n in ta.schema.names:
        assert np.array_equal(np.asarray(ta.columns[n])[: ta.nrows],
                              np.asarray(tb.columns[n])[: tb.nrows]), n


# ---------------------------------------------------------------------------
# fault injector
# ---------------------------------------------------------------------------
class TestFaultInjector:
    def _fires(self, inj, point, n):
        out = []
        for i in range(n):
            try:
                inj.check(point)
            except InjectedFault as f:
                out.append((i, f.index))
        return out

    def test_bernoulli_deterministic_per_seed(self):
        cfg = FaultConfig(seed=3, rate=0.3)
        a = self._fires(FaultInjector(cfg), "scan_h2d", 200)
        b = self._fires(FaultInjector(cfg), "scan_h2d", 200)
        assert a == b and len(a) > 0
        # a different seed gives a different sequence
        c = self._fires(FaultInjector(FaultConfig(seed=4, rate=0.3)),
                        "scan_h2d", 200)
        assert a != c

    def test_streams_independent_per_point(self):
        inj = FaultInjector(FaultConfig(seed=3, rate=0.3))
        a = self._fires(inj, "scan_h2d", 100)
        # interleaved checks on another point must not perturb the
        # first point's decision sequence
        inj2 = FaultInjector(FaultConfig(seed=3, rate=0.3))
        b = []
        for i in range(100):
            try:
                inj2.check("kernel_launch")
            except InjectedFault:
                pass
            try:
                inj2.check("scan_h2d")
            except InjectedFault as f:
                b.append((i, f.index))
        assert a == b

    def test_explicit_schedule_fires_exact_indices(self):
        inj = FaultInjector(FaultConfig(
            seed=0, schedule={"ce_admission": (1, 3)}))
        fired = self._fires(inj, "ce_admission", 5)
        assert [f[1] for f in fired] == [1, 3]
        assert inj.invocations("ce_admission") == 5
        assert inj.fired_by_point() == {"ce_admission": 2}

    def test_max_faults_bounds_total(self):
        inj = FaultInjector(FaultConfig(seed=0, rate=1.0, max_faults=2))
        fired = self._fires(inj, "spill_to_host", 10)
        assert len(fired) == 2
        assert inj.suppressed == 8

    def test_disabled_config_builds_no_injector(self):
        assert FaultInjector.from_config(None) is None
        assert FaultInjector.from_config(FaultConfig()) is None
        assert FaultInjector.from_config(FaultConfig(rate=0.1)) is not None

    def test_unknown_point_rejected(self):
        with pytest.raises(AssertionError):
            FaultConfig(rates={"nope": 0.5})


# ---------------------------------------------------------------------------
# self-auditing memory pools
# ---------------------------------------------------------------------------
class TestMemoryAudit:
    def test_clean_after_normal_traffic(self):
        mm = MemoryManager(10_000, host_budget=10_000)
        p = mm.pool("ce")
        a = np.ones(100, np.float32)
        mm.put(p, "k1", a, a.nbytes)
        mm.put(p, "k2", a, a.nbytes)
        assert mm.get(p, "k1") is a
        mm.evict(p, "k2")
        assert mm.audit() == []

    def test_orphaned_buffer_detected_and_never_served(self):
        mm = MemoryManager(10_000)
        p = mm.pool("ce")
        a = np.ones(10, np.float32)
        mm.put(p, "k", a, a.nbytes)
        p.entries["k"].payload = None       # simulate a lost buffer
        assert any("orphaned" in v for v in mm.audit())
        # the serving guard quarantines instead of serving the corpse
        assert mm.get(p, "k") is None
        assert mm.quarantined == 1
        assert mm.audit() == []

    def test_reconcile_repairs_skewed_books(self):
        mm = MemoryManager(10_000)
        p = mm.pool("ce")
        a = np.ones(100, np.float32)
        mm.put(p, "k", a, a.nbytes)
        mm.device_used += 999               # corrupt the manager book
        p.stats.used += 123                 # and the pool book
        assert mm.audit() != []
        rep = mm.reconcile()
        assert rep["corrections"] >= 2
        assert mm.audit() == []
        assert mm.device_used == a.nbytes

    def test_crashed_journal_record_flagged_and_closed(self):
        mm = MemoryManager(10_000)
        rec = mm.journal.begin("put", "ce", "k")
        assert any("never committed" in v for v in mm.audit())
        rep = mm.reconcile()
        assert rep["crashed_ops"] == 1 and mm.audit() == []
        assert rec.committed

    def test_spill_fault_degrades_to_drop_books_exact(self):
        mm = MemoryManager(1000, host_budget=10_000)
        p = mm.pool("ce", spill_fn=lambda x: x, unspill_fn=lambda x: x)
        mm.faults = FaultInjector(FaultConfig(
            seed=0, rates={"spill_to_host": 1.0}))
        a = np.ones(150, np.uint8)
        mm.put(p, "k1", a, 600)
        mm.put(p, "k2", a, 600)   # displaces k1; its spill fails
        assert p.stats.spill_failures >= 1
        assert mm.get(p, "k1") is None          # dropped, not corrupt
        assert mm.get(p, "k2") is a
        assert mm.audit() == []

    def test_spill_succeeds_without_faults(self):
        mm = MemoryManager(1000, host_budget=10_000)
        p = mm.pool("ce", spill_fn=lambda x: x, unspill_fn=lambda x: x)
        a = np.ones(150, np.uint8)
        mm.put(p, "k1", a, 600)
        mm.put(p, "k2", a, 600)
        assert p.stats.spill_failures == 0
        assert mm.get(p, "k1") is a             # spilled then promoted
        assert mm.audit() == []


# ---------------------------------------------------------------------------
# transactional admission
# ---------------------------------------------------------------------------
class TestCacheTransaction:
    def test_rollback_on_exception_releases_budget(self):
        mm = MemoryManager(1 << 20)
        cm = CacheManager(1 << 20, manager=mm, pool="ce")
        with pytest.raises(RuntimeError, match="boom"):
            with cm.transaction() as txn:
                txn.put(b"p0", object(), 1000)
                txn.put(b"p1", object(), 1000)
                assert cm.used_bytes == 2000
                raise RuntimeError("boom")
        assert cm.used_bytes == 0
        assert not cm.contains(b"p0") and not cm.contains(b"p1")
        assert mm.device_used == 0
        assert mm.audit() == []

    def test_commit_keeps_entries(self):
        mm = MemoryManager(1 << 20)
        cm = CacheManager(1 << 20, manager=mm, pool="ce")
        with cm.transaction() as txn:
            txn.put(b"p0", object(), 1000)
        assert cm.contains(b"p0") and cm.used_bytes == 1000
        assert mm.audit() == []

    def test_rollback_does_not_touch_preexisting_entries(self):
        mm = MemoryManager(1 << 20)
        cm = CacheManager(1 << 20, manager=mm, pool="ce")
        cm.put(b"old", object(), 500)
        txn = cm.transaction()
        txn.put(b"new", object(), 1000)
        txn.rollback()
        assert cm.contains(b"old") and not cm.contains(b"new")
        assert cm.used_bytes == 500 and mm.audit() == []


# ---------------------------------------------------------------------------
# per-query fault isolation
# ---------------------------------------------------------------------------
class TestIsolation:
    def test_transient_faults_recover_bit_identical(self):
        ref = _mk_session()
        base = ref.run_batch(_queries(ref)[:3])
        sess = _mk_session(config=_cfg(seed=7, rate=0.25))
        svc = QueryService(sess, max_batch=3)
        handles = [svc.submit(q) for q in _queries(sess)[:3]]
        assert all(h.done for h in handles)
        for h, r0 in zip(handles, base.results):
            if not h.failed:
                _tables_bit_identical(h.result(), r0.table)
        assert sess.memory.audit() == []
        assert sess.fault_injector.n_fired > 0

    def test_one_failing_query_spares_siblings(self):
        ref = _mk_session()
        base = ref.run_batch(_queries(ref)[:3], mqo=False)
        # degrade exhausted after 1 attempt; the schedule kills ONLY
        # the first query's first H2D transfer
        cfg = _cfg(seed=0, schedule={"scan_h2d": (0,)}) \
            .with_resilience(max_attempts=1)
        sess = _mk_session(config=cfg)
        svc = QueryService(sess, max_batch=3, mqo=False)
        handles = [svc.submit(q) for q in _queries(sess)[:3]]
        assert [h.failed for h in handles] == [True, False, False]
        assert isinstance(handles[0].error, QueryError)
        assert handles[0].error.position == 0
        with pytest.raises(InjectedFault):
            handles[0].result()
        for h, r0 in zip(handles[1:], base.results[1:]):
            _tables_bit_identical(h.result(), r0.table)
        rep = handles[0].explain()
        assert rep["status"] == "failed"
        assert "InjectedFault" in rep["error"]
        assert sess.memory.audit() == []

    def test_failed_shared_ce_falls_back_to_residuals(self):
        ref = _mk_session()
        base = ref.run_batch([_queries(ref)[0] for _ in range(3)])
        assert base.mqo.rewritten.ces, "precondition: a CE is shared"
        sess = _mk_session(config=_cfg(
            seed=FAULT_SEED, schedule={"ce_admission": (0,)}))
        batch = sess.run_batch([_queries(sess)[0] for _ in range(3)])
        evs = batch.resilience.get("events", [])
        assert any(e["action"] == "fallback" for e in evs)
        for r, r0 in zip(batch.results, base.results):
            assert r is not None
            _tables_bit_identical(r.table, r0.table)
        assert sess.memory.audit() == []

    def test_poisoned_plan_fails_alone(self):
        ref = _mk_session()
        base = ref.run_batch(_queries(ref)[:2], mqo=False)
        sess = _mk_session()
        svc = QueryService(sess, max_batch=3, mqo=False)
        ghost = Relation(L.scan("ghost", S, "columnar"), sess)
        h_bad = svc.submit(ghost)
        good = [svc.submit(q) for q in _queries(sess)[:2]]
        assert h_bad.failed and not any(h.failed for h in good)
        with pytest.raises(Exception):
            h_bad.result()
        for h, r0 in zip(good, base.results):
            _tables_bit_identical(h.result(), r0.table)

    def test_error_handles_report_into_batch(self):
        cfg = _cfg(seed=0, schedule={"scan_h2d": (0,)}) \
            .with_resilience(max_attempts=1)
        sess = _mk_session(config=cfg)
        batch = sess.run_batch(_queries(sess)[:2], mqo=False)
        assert batch.n_failed == 1
        assert batch.results[0] is None and batch.results[1] is not None
        assert batch.per_query_seconds[0] is None
        assert batch.resilience["n_failed"] == 1


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------
class TestDegradationLadder:
    def test_kernel_fault_degrades_to_eager(self):
        ref = _mk_session()
        base = ref.run_batch([_queries(ref)[0]])
        sess = _mk_session(config=_cfg(
            seed=0, schedule={"kernel_launch": (0,)}))
        batch = sess.run_batch([_queries(sess)[0]])
        evs = batch.resilience.get("events", [])
        assert any(e["action"] == "degrade" and e["level"] == "eager"
                   for e in evs)
        _tables_bit_identical(batch.results[0].table, base.results[0].table)

    def test_transient_fault_retries_in_place(self):
        ref = _mk_session()
        base = ref.run_batch([_queries(ref)[0]])
        sess = _mk_session(config=_cfg(
            seed=0, schedule={"scan_h2d": (0,)}))
        batch = sess.run_batch([_queries(sess)[0]])
        evs = batch.resilience.get("events", [])
        assert any(e["action"] == "retry" for e in evs)
        assert not any(e["action"] == "degrade" for e in evs)
        _tables_bit_identical(batch.results[0].table, base.results[0].table)

    def test_attempts_bounded(self):
        cfg = _cfg(seed=0, rates={"scan_h2d": 1.0}) \
            .with_resilience(max_attempts=3)
        sess = _mk_session(config=cfg)
        batch = sess.run_batch([_queries(sess)[0]], mqo=False)
        assert batch.results[0] is None
        evs = batch.resilience["events"]
        assert max(e["attempt"] for e in evs) == 3

    def test_backoff_exponential_injectable_clock(self):
        sleeps = []
        cfg = _cfg(seed=0, schedule={"scan_h2d": (0, 1)}) \
            .with_resilience(backoff_base_s=0.1, max_attempts=4)
        sess = _mk_session(config=cfg)
        sess._sleep = sleeps.append          # never wall-sleeps
        batch = sess.run_batch([_queries(sess)[0]], mqo=False)
        assert batch.results[0] is not None
        assert sleeps == pytest.approx([0.1, 0.2])

    def test_no_backoff_by_default(self):
        sleeps = []
        sess = _mk_session(config=_cfg(
            seed=0, schedule={"scan_h2d": (0,)}))
        sess._sleep = sleeps.append
        sess.run_batch([_queries(sess)[0]], mqo=False)
        assert sleeps == []


# ---------------------------------------------------------------------------
# batched-window dispatch fault (PR 7)
# ---------------------------------------------------------------------------
class TestBatchedLaunchFault:
    """The window's SHARED batched dispatch is a new failure domain:
    when its fault point fires, the whole window must degrade to
    per-query dispatch (the rung ABOVE the PR 6 ladder) with results
    bit-identical to the fault-free run."""

    def _template(self, sess):
        # three same-SHAPE plans so the window forms one batch group
        t = lambda: sess.table("t")  # noqa: E731
        return [t().filter(E.and_(E.cmp("a", ">", 20 + 10 * i),
                                  E.cmp("a", "<", 95 - 5 * i)))
                .project("a", "b") for i in range(3)]

    def test_batched_launch_degrades_to_per_query(self):
        ref = _mk_session()
        base = ref.run_batch(self._template(ref), mqo=False)
        sess = _mk_session(config=_cfg(
            seed=FAULT_SEED, schedule={"batched_launch": (0,)}))
        batch = sess.run_batch(self._template(sess), mqo=False)
        evs = batch.resilience.get("events", [])
        degr = [e for e in evs if e["action"] == "degrade"
                and e["level"] == "per-query"]
        assert len(degr) == 3           # one event per would-be member
        assert batch.metrics.batched_dispatches == 0
        rep = sess.fault_injector.report()
        assert rep["invocations"]["batched_launch"] >= 1
        assert rep["fired"].get("batched_launch") == 1
        for a, b in zip(batch.results, base.results):
            _tables_bit_identical(a.table, b.table)

    def test_window_after_fault_batches_again(self):
        sess = _mk_session(config=_cfg(
            seed=FAULT_SEED, schedule={"batched_launch": (0,)}))
        first = sess.run_batch(self._template(sess), mqo=False)
        assert first.metrics.batched_dispatches == 0
        second = sess.run_batch(self._template(sess), mqo=False)
        assert second.metrics.batched_dispatches >= 1
        for a, b in zip(first.results, second.results):
            _tables_bit_identical(a.table, b.table)

    def test_soak_rates_cover_batched_launch(self):
        # the acceptance soak's rate map is derived from FAULT_POINTS,
        # so the new point is exercised automatically
        assert ALL_RATES.get("batched_launch") == 0.05


# ---------------------------------------------------------------------------
# pid bitset pool fault (PR 8)
# ---------------------------------------------------------------------------
class TestPidPoolFault:
    """A pid bitset read is an optimization, never a failure domain:
    when the ``pid_pool`` point fires, the scan degrades to STATS-ONLY
    partition pruning (a DegradationEvent, never a QueryError) and the
    results stay bit-identical to the fault-free run."""

    P = Schema.of(("a", I32), ("b", I32), ("c", I32))

    def _mk(self, config=None):
        # b == 777 is CORRELATED with the range-partition key a (only
        # rows with a < 130 carry it), so per-partition min/max stats
        # on b cannot refute the value anywhere — only the recorded
        # presence bitset prunes the other partitions
        rng = np.random.default_rng(13)
        a = rng.integers(0, 1000, 4000).astype(np.int32)
        b = np.where(a < 130, 777,
                     rng.integers(0, 1000, 4000)).astype(np.int32)
        c = rng.integers(0, 100, 4000).astype(np.int32)
        cols = {"a": a, "b": b, "c": c}
        if config is None:
            config = SessionConfig(memory=MemoryConfig(
                budget_bytes=1 << 24))
        sess = Session.from_config(config)
        st, _ = make_storage("p", self.P, 4000, "columnar", cols=cols)
        sess.register(st, columnar_for_stats=cols,
                      partitioning=Partitioning("a", "range", 8))
        return sess

    def _seed_then_probe(self, sess):
        t = lambda: sess.table("p")  # noqa: E731
        seed = t().filter(E.cmp("b", "==", 777)).project("a", "b", "c")
        probe = t().filter(E.and_(E.cmp("b", "==", 777),
                                  E.cmp("c", ">", 10))).project("a", "b")
        s = sess.run_batch([seed], mqo=False)
        p = sess.run_batch([probe], mqo=False)
        return s, p

    def test_poisoned_bitset_read_degrades_to_stats_prune(self):
        ref = self._mk()
        s0, p0 = self._seed_then_probe(ref)
        assert s0.metrics.pid_records >= 1, "seed never recorded a bitset"
        # precondition: history prunes beyond stats on the subsumed probe
        assert p0.metrics.pid_hits >= 1
        assert p0.metrics.pid_pruned_parts > 0

        sess = self._mk(config=_cfg(rates={"pid_pool": 1.0}))
        s1, p1 = self._seed_then_probe(sess)
        # every bitset read failed -> stats-only pruning, never a failure
        assert p1.metrics.pid_pruned_parts == 0
        assert p1.n_failed == 0 and s1.n_failed == 0
        evs = [e for e in p1.resilience.get("events", [])
               if e.get("point") == "pid_pool"]
        assert evs, "degradation never reported"
        assert all(e["action"] == "degrade" for e in evs)
        assert any(e["level"] == "stats-prune" for e in evs)
        _tables_bit_identical(p1.results[0].table, p0.results[0].table)
        _tables_bit_identical(s1.results[0].table, s0.results[0].table)
        assert sess.memory.audit() == []

    def test_fault_free_windows_resume_pid_pruning(self):
        # the pool itself survives a poisoned read: once the injector
        # stops firing, the NEXT probe prunes from history again
        sess = self._mk(config=_cfg(seed=0, schedule={"pid_pool": (1,)}))
        ref = self._mk()
        _, p0 = self._seed_then_probe(ref)
        _, p1 = self._seed_then_probe(sess)       # probe's read faulted
        assert p1.metrics.pid_pruned_parts == 0
        probe = sess.table("p").filter(
            E.and_(E.cmp("b", "==", 777),
                   E.cmp("c", ">", 10))).project("a", "b")
        p2 = sess.run_batch([probe], mqo=False)
        assert p2.metrics.pid_pruned_parts > 0
        _tables_bit_identical(p2.results[0].table, p0.results[0].table)

    def test_soak_rates_cover_pid_pool(self):
        # the acceptance soak derives its rate map from FAULT_POINTS,
        # so the new point is exercised automatically
        assert ALL_RATES.get("pid_pool") == 0.05


# ---------------------------------------------------------------------------
# window exception safety
# ---------------------------------------------------------------------------
class TestWindowSafety:
    def test_window_close_fault_retried(self):
        sess = _mk_session(config=_cfg(
            seed=0, schedule={"window_close": (0,)}))
        batch = sess.run_batch(_queries(sess)[:2])
        assert all(r is not None for r in batch.results)
        assert sess.fault_injector.fired_by_point() == {"window_close": 1}

    def test_window_death_resolves_every_handle(self):
        sess = _mk_session(config=_cfg(
            seed=0, rates={"window_close": 1.0}))
        svc = QueryService(sess, max_batch=3)
        handles = [svc.submit(q) for q in _queries(sess)[:3]]
        assert all(h.done and h.failed for h in handles)
        assert all(isinstance(h.error, QueryError) for h in handles)
        # the service survives: state detached cleanly, a fresh window
        # opens and resolves (failing again under rate=1.0, but never
        # deadlocking or corrupting)
        assert svc.pending == 0
        h = svc.submit(_queries(sess)[0])
        svc.flush()
        assert h.done and h.failed
        assert sess.memory.audit() == []

    def test_run_batch_returns_batch_on_window_death(self):
        sess = _mk_session(config=_cfg(
            seed=0, rates={"window_close": 1.0}))
        batch = sess.run_batch(_queries(sess)[:2])
        assert batch.results == [None, None]
        assert "window_error" in batch.resilience

    def test_isolation_off_propagates_window_error(self):
        cfg = _cfg(seed=0, rates={"window_close": 1.0}) \
            .with_resilience(isolate=False)
        sess = _mk_session(config=cfg)
        svc = QueryService(sess, max_batch=2)
        h = svc.submit(_queries(sess)[0])
        with pytest.raises(InjectedFault):
            svc.submit(_queries(sess)[1])
        # the handle still resolved — no corrupt pending state
        assert h.done and h.failed and svc.pending == 0


# ---------------------------------------------------------------------------
# partition-grained admission rollback (satellite: budget-leak fix)
# ---------------------------------------------------------------------------
class TestPartitionedAdmissionRollback:
    SCHEMA = synthetic_schema(n_int=3, n_dbl=2, n_str=1)
    COLS = generate_columns(SCHEMA, 8000, seed=11)

    def _mk(self, config=None):
        if config is None:
            config = SessionConfig(memory=MemoryConfig(
                budget_bytes=1 << 30))
        sess = Session.from_config(config)
        sess.disk_latency_per_byte = 5e-9   # makes caching worthwhile
        st, _ = make_storage("t", self.SCHEMA, 8000, "csv",
                             cols=self.COLS)
        sess.register(st, columnar_for_stats=self.COLS,
                      partitioning=Partitioning("n1", "range", 8))
        return sess

    def _dash(self, sess):
        t = lambda: sess.table("t")  # noqa: E731
        return [
            t().filter(E.cmp("n1", "<", 400))
               .project("n1", "n2", "n3", "d1"),
            t().filter(E.cmp("n1", "<", 300)).project("n1", "n2", "d2"),
            t().filter(E.cmp("n1", "<", 350)).project("n1", "n3", "d1"),
        ]

    def test_partial_admission_rolls_back_cleanly(self):
        ref = self._mk()
        base = ref.run_batch(self._dash(ref))
        ces = base.mqo.rewritten.ces
        pdetail = [c for c in ces if c.partition_detail is not None]
        assert pdetail, "precondition: a partition-grained CE"
        assert len(next(iter(pdetail)).admitted_partitions) >= 2, \
            "precondition: a multi-entry admission"
        # fail the SECOND partition admission: the first, already
        # admitted, must be rolled back (no leaked pool bytes)
        sess = self._mk(SessionConfig(
            memory=MemoryConfig(budget_bytes=1 << 30)
        ).with_faults(FaultConfig(
            seed=0, schedule={"ce_admission": (1,)})))
        batch = sess.run_batch(self._dash(sess))
        assert sess.fault_injector.n_fired == 1
        assert not any(isinstance(k, tuple) for k in sess._ce_cache.keys())
        assert sess.memory.audit() == []
        for r, r0 in zip(batch.results, base.results):
            assert r is not None
            _tables_bit_identical(r.table, r0.table)


# ---------------------------------------------------------------------------
# the soak property (acceptance criteria)
# ---------------------------------------------------------------------------
ALL_RATES = {p: 0.05 for p in FAULT_POINTS}
ALL_RATES["window_close"] = 0.02


def _run_soak(seed, n_windows, rates=ALL_RATES, budget=1 << 15):
    # the 32 KiB budget is deliberate: the working set (~45 KiB of scan
    # columns + CEs) overflows it, so admissions displace resident CEs
    # and the spill_to_host fault point sits on the natural hot path
    """Drive ``n_windows`` micro-batch windows under seeded faults at
    every named point; assert after EVERY window that all handles are
    resolved, the memory audit is clean, and each successful result is
    bit-identical to a fault-free reference run of the same window."""
    faulty = _mk_session(config=_cfg(budget, seed=seed, rates=rates))
    ref = _mk_session(budget=budget)
    svc = QueryService(faulty, max_batch=64)
    rng = random.Random(seed)
    n_ok = n_failed = 0
    for w in range(n_windows):
        # WITH replacement: identical submissions in one window are how
        # CEs form at this table size, keeping ce_admission on the path
        idxs = rng.choices(range(6), k=rng.randint(1, 3))
        pool_f, pool_r = _queries(faulty), _queries(ref)
        handles = [svc.submit(pool_f[i]) for i in idxs]
        svc.flush()
        assert svc.pending == 0, f"window {w}: corrupt window state"
        base = ref.run_batch([pool_r[i] for i in idxs])
        for h, r0 in zip(handles, base.results):
            assert h.done, f"window {w}: unresolved handle"
            if h.failed:
                n_failed += 1
                assert isinstance(h.error, QueryError)
                assert h.explain()["status"] == "failed"
            else:
                n_ok += 1
                _tables_bit_identical(h.result(), r0.table)
        violations = faulty.memory.audit()
        assert violations == [], f"window {w}: {violations}"
        if w % 7 == 6:
            # memory-pressure pulse: demote every resident device entry
            # (CEs take the spill path, so spill_to_host faults land on
            # real in-flight demotions, deterministically at any seed)
            faulty.memory._make_room(faulty.memory.device_budget)
            violations = faulty.memory.audit()
            assert violations == [], f"window {w} post-pulse: {violations}"
    return n_ok, n_failed, faulty


class TestSoak:
    def test_100_window_soak_with_faults_at_every_point(self):
        n_ok, n_failed, sess = _run_soak(FAULT_SEED, 100)
        inj = sess.fault_injector
        assert inj.n_fired > 0, "soak never injected a fault"
        # every named failure point on the SYNC hot path was actually
        # reached (whether a given point FIRES depends on the seed);
        # async_close lives in the async front's closer task — its soak
        # is tests/test_async_service.py
        for point in FAULT_POINTS:
            if point == "async_close":
                continue
            assert inj.invocations(point) > 0, point
        assert n_ok > 0, "soak never completed a query"
        # PR 9: the metrics registry mirrors the injector and the
        # resolved-handle outcomes one-for-one, so soak telemetry can
        # be asserted from ONE place
        reg = sess.telemetry().registry
        rep = inj.report()
        assert reg.value("fault.fired.total") == rep["n_fired"]
        for point, n in rep["fired"].items():
            assert reg.value(f"fault.fired.{point}") == n, point
        for point in FAULT_POINTS:
            assert reg.value(f"fault.invocations.{point}") == \
                inj.invocations(point), point
        assert reg.value("fault.suppressed") == rep["suppressed"]
        assert reg.value("queries.succeeded") == n_ok
        assert reg.value("queries.failed") == n_failed

    def test_seeded_schedules_always_safe(self):
        # always-run fallback for the hypothesis property below
        for seed in (FAULT_SEED + 1, FAULT_SEED + 17, FAULT_SEED + 23):
            _run_soak(seed, 5, rates={p: 0.15 for p in FAULT_POINTS})

    def test_any_fault_schedule_is_safe_property(self):
        pytest.importorskip("hypothesis",
                            reason="property tests need hypothesis")
        from hypothesis import HealthCheck, given, settings
        from hypothesis import strategies as st

        @given(seed=st.integers(0, 2 ** 16))
        @settings(max_examples=5, deadline=None,
                  suppress_health_check=[HealthCheck.too_slow])
        def prop(seed):
            _run_soak(seed, 3, rates={p: 0.2 for p in FAULT_POINTS})

        prop()
