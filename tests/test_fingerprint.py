"""Fingerprint (Merkle-tree) properties: Definitions 1–3 of the paper."""
import numpy as np
import pytest

from repro.core import fingerprint, all_fingerprints, tree_size
from repro.relational import I32, STR, Schema, expr as E, logical as L

S_EMP = Schema.of(("emp_id", I32), ("age", I32), ("gender", STR(4)),
                  ("dep", I32))
S_DEPT = Schema.of(("dept_id", I32), ("budget", I32))


def scan_emp():
    return L.scan("employees", S_EMP)


def scan_dept():
    return L.scan("departments", S_DEPT)


class TestLooseIdentity:
    def test_filters_with_different_predicates_share_fingerprint(self):
        a = scan_emp().filter(E.cmp("age", ">", 30))
        b = scan_emp().filter(E.cmp("age", "<", 20))
        assert fingerprint(a) == fingerprint(b)

    def test_projects_with_different_columns_share_fingerprint(self):
        a = scan_emp().project("emp_id")
        b = scan_emp().project("age", "dep")
        assert fingerprint(a) == fingerprint(b)

    def test_scan_of_different_tables_differ(self):
        assert fingerprint(scan_emp()) != fingerprint(scan_dept())

    def test_scan_of_different_formats_differ(self):
        a = L.scan("employees", S_EMP, "csv")
        b = L.scan("employees", S_EMP, "columnar")
        assert fingerprint(a) != fingerprint(b)


class TestStrictIdentity:
    def test_different_join_keys_differ(self):
        j1 = scan_emp().join(scan_dept(), "dep", "dept_id")
        j2 = scan_emp().join(scan_dept(), "emp_id", "dept_id")
        assert fingerprint(j1) != fingerprint(j2)

    def test_different_aggregates_differ(self):
        a = scan_emp().groupby("dep").agg(("n", "count", ""))
        b = scan_emp().groupby("dep").agg(("s", "sum", "age"))
        assert fingerprint(a) != fingerprint(b)

    def test_limit_n_matters(self):
        assert fingerprint(scan_emp().limit(5)) != fingerprint(
            scan_emp().limit(6))


class TestIsomorphism:
    def test_join_operand_order_is_isomorphic(self):
        j1 = scan_emp().join(scan_dept(), "dep", "dept_id")
        j2 = scan_dept().join(scan_emp(), "dept_id", "dep")
        assert fingerprint(j1) == fingerprint(j2)

    def test_union_operand_order_is_isomorphic(self):
        a = scan_emp().filter(E.cmp("age", ">", 1)).project("emp_id")
        b = scan_emp().filter(E.cmp("age", "<", 9)).project("emp_id")
        assert fingerprint(a.union(b)) == fingerprint(b.union(a))


class TestStructure:
    def test_different_shapes_differ(self):
        a = scan_emp().filter(E.cmp("age", ">", 30))
        b = scan_emp().filter(E.cmp("age", ">", 30)).project("emp_id")
        c = scan_emp().project("emp_id").filter(E.cmp("emp_id", ">", 30))
        fps = {fingerprint(a), fingerprint(b), fingerprint(c)}
        assert len(fps) == 3

    def test_all_fingerprints_covers_every_subtree(self):
        plan = (scan_emp().filter(E.cmp("age", ">", 30))
                .join(scan_dept(), "dep", "dept_id")
                .project("emp_id", "budget"))
        fps = all_fingerprints(plan)
        assert len(fps) == tree_size(plan)

    def test_deep_plan_no_recursion_error(self):
        node = scan_emp()
        for i in range(2000):
            node = node.filter(E.cmp("age", ">", i % 60))
        assert fingerprint(node)  # must not hit the recursion limit
