"""CacheManager budget/spill accounting (regression tests)."""
from repro.core.cache import CacheManager


def _mk(budget=100):
    # identity spill/unspill: enough to exercise the accounting paths
    return CacheManager(budget, spill_fn=lambda p: p, unspill_fn=lambda p: p)


class TestAccounting:
    def test_put_within_budget(self):
        cm = _mk(100)
        cm.put(b"a", "A", nbytes=60)
        assert cm.stats.used == 60 and cm.stats.spilled_bytes == 0

    def test_overflow_spills(self):
        cm = _mk(100)
        cm.put(b"a", "A", nbytes=60)
        e = cm.put(b"b", "B", nbytes=60)
        assert e.spilled
        assert cm.stats.used == 60 and cm.stats.spilled_bytes == 60

    def test_evict_resident_entry(self):
        cm = _mk(100)
        cm.put(b"a", "A", nbytes=60)
        cm.evict(b"a")
        assert cm.stats.used == 0
        assert not cm.contains(b"a")

    def test_evict_spilled_entry_resets_spilled_bytes(self):
        cm = _mk(100)
        cm.put(b"a", "A", nbytes=60)
        cm.put(b"b", "B", nbytes=60)          # spilled
        cm.evict(b"b")
        assert cm.stats.spilled_bytes == 0
        assert cm.stats.used == 60

    def test_evict_missing_is_noop(self):
        cm = _mk(100)
        cm.put(b"a", "A", nbytes=60)
        cm.evict(b"nope")
        assert cm.stats.used == 60 and cm.stats.spilled_bytes == 0

    def test_clear_resets_both_counters(self):
        cm = _mk(100)
        cm.put(b"a", "A", nbytes=60)
        cm.put(b"b", "B", nbytes=60)          # spilled
        cm.clear()
        assert cm.stats.used == 0
        assert cm.stats.spilled_bytes == 0
        assert not cm.contains(b"a") and not cm.contains(b"b")
        # cache stays usable after clear
        cm.put(b"c", "C", nbytes=60)
        assert cm.stats.used == 60 and cm.stats.spilled_bytes == 0

    def test_get_unspills(self):
        cm = _mk(100)
        cm.put(b"a", "A", nbytes=60)
        cm.put(b"b", "B", nbytes=60)
        assert cm.get(b"b") == "B"
        assert cm.stats.hits == 1

    def test_no_promotion_without_headroom(self):
        cm = _mk(100)
        cm.put(b"a", "A", nbytes=60)
        cm.put(b"b", "B", nbytes=60)          # spilled
        cm.get(b"b")                          # 40 free < 60: stays spilled
        assert cm.entry(b"b").spilled
        assert cm.stats.used == 60 and cm.stats.spilled_bytes == 60
        assert cm.stats.promotions == 0

    def test_hit_promotes_spilled_entry_when_budget_frees(self):
        """Satellite fix (ISSUE 2): a spilled entry used to be
        re-unspilled on EVERY hit and never moved back to device even
        when the budget freed up."""
        cm = _mk(100)
        cm.put(b"a", "A", nbytes=60)
        cm.put(b"b", "B", nbytes=60)          # spilled
        cm.evict(b"a")                        # headroom appears
        assert cm.get(b"b") == "B"
        e = cm.entry(b"b")
        assert not e.spilled                  # promoted to device
        assert cm.stats.used == 60
        assert cm.stats.spilled_bytes == 0
        assert cm.stats.promotions == 1
        # subsequent hits read device-resident payload, no unspill work
        assert cm.get(b"b") == "B"
        assert cm.stats.promotions == 1
