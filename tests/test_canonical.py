"""Canonical plan IR (ISSUE 5): syntactic variants of one query —
shuffled conjuncts, pushed negations, double negations, flipped
literal-on-left compares, stacked filters, redundant projections —
canonicalize to identical expressions, identical strict fingerprints,
and hit the SAME resident covering expression across service windows.

Property tests run twice: a hypothesis version (skipped when the
package is absent) and a seeded always-run variant over the same
generators.
"""
import random

import numpy as np
import pytest

from repro.core.fingerprint import fingerprint, strict_fingerprint
from repro.relational import (FALSE, I32, QueryService, Schema, Session,
                              SessionConfig, c, canonicalize_expr,
                              canonicalize_plan, expr as E, format_plan,
                              logical as L, make_storage)

S = Schema.of(("a", I32), ("b", I32), ("d", I32))
COLS = ("a", "b", "d")


def _mk_session(budget=1 << 24, nrows=2000):
    rng = np.random.default_rng(3)
    cols = {n: rng.integers(0, 100, nrows).astype(np.int32)
            for n in COLS}
    sess = Session.from_config(
        SessionConfig.from_legacy_kwargs(budget_bytes=budget))
    st, _ = make_storage("t", S, nrows, "columnar", cols=cols)
    sess.register(st)
    return sess, cols


# ---------------------------------------------------------------------------
# random expression trees + semantics-preserving syntactic variants
# ---------------------------------------------------------------------------
def random_expr(rng: random.Random, depth: int = 3) -> E.Expr:
    if depth <= 0 or rng.random() < 0.35:
        col = rng.choice(COLS)
        op = rng.choice(E._OPS)
        if rng.random() < 0.15:            # col-col compare
            return E.col_cmp(col, op, rng.choice(COLS))
        return E.cmp(col, op, rng.randint(0, 100))
    kind = rng.random()
    parts = tuple(random_expr(rng, depth - 1)
                  for _ in range(rng.randint(2, 3)))
    if kind < 0.4:
        return E.And(parts)
    if kind < 0.8:
        return E.Or(parts)
    return E.Not(random_expr(rng, depth - 1))


def syntactic_variant(e: E.Expr, rng: random.Random) -> E.Expr:
    """A differently-spelled expression with identical semantics."""
    if rng.random() < 0.25:                 # double negation anywhere
        return E.Not(E.Not(syntactic_variant(e, rng)))
    if isinstance(e, E.Cmp):
        e = E.oriented(e)
        r = rng.random()
        if (r < 0.33 and isinstance(e.col, E.Col)
                and isinstance(e.rhs, E.Lit)):
            # literal-on-left spelling: a > 5  →  5 < a
            return E.Cmp(E.MIRROR[e.op], e.rhs, e.col)
        if r < 0.66:
            # negated complement: a > 5  →  ¬(a <= 5)
            return E.Not(E.Cmp(E.NEGATE[e.op], e.col, e.rhs))
        return e
    if isinstance(e, (E.And, E.Or)):
        parts = [syntactic_variant(p, rng) for p in e.parts]
        rng.shuffle(parts)                  # commutativity
        out = type(e)(tuple(parts))
        if rng.random() < 0.3:              # De Morgan spelling
            dual = E.Or if isinstance(e, E.And) else E.And
            return E.Not(dual(tuple(E.Not(p) for p in parts)))
        return out
    if isinstance(e, E.Not):
        return E.Not(syntactic_variant(e.part, rng))
    return e


def _eval_np(e: E.Expr, cols) -> np.ndarray:
    return np.asarray(E.eval_expr(e, {n: np.asarray(v)
                                      for n, v in cols.items()}))


def check_variant_pair(seed: int, cols) -> None:
    rng = random.Random(seed)
    orig = random_expr(rng)
    var = syntactic_variant(orig, rng)
    canon_o, canon_v = canonicalize_expr(orig), canonicalize_expr(var)
    # one normal form...
    assert canon_o == canon_v, (E.pretty(orig), E.pretty(var))
    # ...that is semantics-preserving
    np.testing.assert_array_equal(_eval_np(orig, cols),
                                  _eval_np(canon_o, cols))
    np.testing.assert_array_equal(_eval_np(var, cols),
                                  _eval_np(canon_o, cols))
    # and plan-level: one strict fingerprint
    scan = L.scan("t", S)
    p1 = canonicalize_plan(scan.filter(orig).project("a"))
    p2 = canonicalize_plan(scan.filter(var).project("a"))
    assert fingerprint(p1) == fingerprint(p2)
    assert strict_fingerprint(p1) == strict_fingerprint(p2)


class TestPropertySeeded:
    """Always-run seeded variant of the hypothesis properties."""

    def test_variants_canonicalize_identically(self):
        rng = np.random.default_rng(0)
        cols = {n: rng.integers(0, 100, 257).astype(np.int32)
                for n in COLS}
        for seed in range(60):
            check_variant_pair(seed, cols)


class TestPropertyHypothesis:
    def test_variants_canonicalize_identically(self):
        hyp = pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        rng = np.random.default_rng(1)
        cols = {n: rng.integers(0, 100, 129).astype(np.int32)
                for n in COLS}

        @settings(max_examples=60, deadline=None)
        @given(st.integers(min_value=0, max_value=10_000))
        def prop(seed):
            check_variant_pair(seed, cols)

        prop()


# ---------------------------------------------------------------------------
# targeted normal-form rules
# ---------------------------------------------------------------------------
class TestExprNormalForm:
    def test_reversed_literal_compare_is_representable(self):
        # satellite: Lit-op-Col used to be unconstructible in practice
        e = E.Cmp("<", E.Lit(5), E.Col("a"))          # 5 < a
        assert canonicalize_expr(e) == E.cmp("a", ">", 5)
        assert E.columns_of(e) == frozenset({"a"})
        assert E.canonical(e) == E.canonical(E.cmp("a", ">", 5))
        assert "a" in E.pretty(e)

    def test_reversed_compare_evaluates(self):
        cols = {"a": np.arange(10, dtype=np.int32)}
        got = _eval_np(E.Cmp("<", E.Lit(5), E.Col("a")), cols)
        np.testing.assert_array_equal(got, np.arange(10) > 5)

    @pytest.mark.parametrize("op", E._OPS)
    def test_every_op_flips_consistently(self, op):
        cols = {"a": np.arange(-3, 9, dtype=np.int32)}
        lhs = E.Cmp(op, E.Lit(4), E.Col("a"))
        rhs = canonicalize_expr(lhs)
        np.testing.assert_array_equal(_eval_np(lhs, cols),
                                      _eval_np(rhs, cols))

    def test_col_col_compare_orientation(self):
        # a < b and b > a must share one canonical form (review fix)
        lhs = E.col_cmp("a", "<", "b")
        rhs = E.col_cmp("b", ">", "a")
        assert canonicalize_expr(lhs) == canonicalize_expr(rhs)
        assert E.canonical(lhs) == E.canonical(rhs)
        cols = {"a": np.arange(8, dtype=np.int32),
                "b": np.full(8, 4, dtype=np.int32)}
        np.testing.assert_array_equal(_eval_np(lhs, cols),
                                      _eval_np(rhs, cols))
        p1 = canonicalize_plan(L.scan("t", S).filter(lhs).project("a"))
        p2 = canonicalize_plan(L.scan("t", S).filter(rhs).project("a"))
        assert strict_fingerprint(p1) == strict_fingerprint(p2)

    def test_empty_disjunction_is_false(self):
        assert E.or_() == FALSE                     # review fix
        cols = {"a": np.arange(4, dtype=np.int32)}
        np.testing.assert_array_equal(_eval_np(E.or_(), cols),
                                      np.zeros(4, bool))

    def test_not_cmp_folds_to_complement(self):
        assert (canonicalize_expr(E.Not(E.cmp("a", ">=", 5)))
                == E.cmp("a", "<", 5))

    def test_double_negation_cancels(self):
        p = E.cmp("a", "==", 1)
        assert canonicalize_expr(E.Not(E.Not(p))) == p

    def test_de_morgan_pushdown(self):
        e = E.Not(E.And((E.cmp("a", ">", 1), E.cmp("b", "<", 2))))
        want = canonicalize_expr(
            E.Or((E.cmp("a", "<=", 1), E.cmp("b", ">=", 2))))
        assert canonicalize_expr(e) == want

    def test_conjunct_sort_and_dedup(self):
        x, y = E.cmp("a", ">", 1), E.cmp("b", "<", 2)
        assert (canonicalize_expr(E.And((y, x, y)))
                == canonicalize_expr(E.And((x, y))))

    def test_constant_folding(self):
        t = E.Cmp("<", E.Lit(1), E.Lit(2))      # true
        f = E.Cmp(">", E.Lit(1), E.Lit(2))      # false
        assert canonicalize_expr(t) == E.TRUE
        assert canonicalize_expr(f) == FALSE
        p = E.cmp("a", ">", 3)
        assert canonicalize_expr(E.And((t, p))) == p
        assert canonicalize_expr(E.And((f, p))) == FALSE
        assert canonicalize_expr(E.Or((t, p))) == E.TRUE
        assert canonicalize_expr(E.Or((f, p))) == p

    @pytest.mark.parametrize("op", E._OPS)
    def test_cross_type_const_fold_closed_under_complement(self, op):
        """review fix: Not(Lit-op-Lit) over incomparable literal types
        must fold to the complement of the un-negated fold, matching
        the un-canonicalized eval path."""
        e = E.Cmp(op, E.Lit(b"a"), E.Lit(5))
        plain = canonicalize_expr(e)
        negated = canonicalize_expr(E.Not(e))
        assert {plain, negated} == {E.TRUE, FALSE}
        cols = {"a": np.arange(4, dtype=np.int32)}
        np.testing.assert_array_equal(_eval_np(e, cols),
                                      _eval_np(plain, cols))
        np.testing.assert_array_equal(_eval_np(E.Not(e), cols),
                                      _eval_np(negated, cols))

    def test_nan_literal_negation_not_folded(self):
        """review fix: ¬(x > NaN) must NOT fold to x <= NaN (IEEE NaN
        satisfies neither side) — the Not survives canonicalization
        and both forms evaluate identically."""
        e = E.Not(E.cmp("a", ">", float("nan")))
        canon = canonicalize_expr(e)
        assert isinstance(canon, E.Not)
        cols = {"a": np.arange(4, dtype=np.float32)}
        np.testing.assert_array_equal(_eval_np(e, cols),
                                      _eval_np(canon, cols))
        # un-negated NaN compares still fold soundly (all-False)
        np.testing.assert_array_equal(
            _eval_np(E.cmp("a", ">", float("nan")), cols),
            np.zeros(4, bool))

    def test_nan_columns_rejected_at_registration(self):
        """The ordered-complement fold (¬(x<=v) → x>v) is only sound
        without NaN; registration must therefore refuse non-finite
        float columns (review fix: made explicit, was accidental)."""
        from repro.relational import F32, Session as S_, make_storage \
            as mk
        import numpy as _np

        sch = Schema.of(("x", F32))
        cols = {"x": _np.array([1.0, _np.nan, 3.0], _np.float32)}
        sess = S_.from_config(
            SessionConfig.from_legacy_kwargs(budget_bytes=1 << 20))
        st, _ = mk("t", sch, 3, "columnar", cols=cols)
        with pytest.raises(ValueError, match="NaN"):
            sess.register(st, columnar_for_stats=cols)

    def test_constant_false_filter_executes(self):
        sess, _ = _mk_session()
        q = sess.table("t").where(E.Cmp(">", E.Lit(1), E.Lit(2)))
        out = sess.run_batch([q], mqo=False).results[0].table
        assert out.nrows == 0


class TestPlanNormalForm:
    def test_stacked_filters_merge(self):
        scan = L.scan("t", S)
        a = canonicalize_plan(
            scan.filter(E.cmp("a", ">", 5)).filter(E.cmp("b", "<", 3)))
        b = canonicalize_plan(
            scan.filter(E.and_(E.cmp("b", "<", 3), E.cmp("a", ">", 5))))
        assert strict_fingerprint(a) == strict_fingerprint(b)

    def test_true_filter_disappears(self):
        scan = L.scan("t", S)
        assert canonicalize_plan(scan.filter(E.TRUE)) == scan

    def test_identity_projection_disappears(self):
        scan = L.scan("t", S)
        assert canonicalize_plan(scan.project(*S.names)) == scan

    def test_project_project_collapses_and_dedups(self):
        scan = L.scan("t", S)
        a = canonicalize_plan(scan.project("a", "b").project("a", "a"))
        assert a == scan.project("a")

    def test_format_plan_renders_tree(self):
        other = L.scan("u", Schema.of(("x", I32)))
        plan = (L.scan("t", S).filter(E.cmp("a", ">", 5))
                .join(other, "a", "x"))
        text = format_plan(plan, show_schema=True)
        assert "Join" in text and "Filter" in text and "Scan t" in text
        assert "⟨" in text


# ---------------------------------------------------------------------------
# cross-window sharing: variants hit the SAME resident CE
# ---------------------------------------------------------------------------
class TestCrossWindowSharing:
    def _builder_query(self, sess):
        return (sess.table("t")
                .where((c.a > 50) & (c.b < 80))
                .select("a", "b"))

    def _variant_query(self, sess):
        # flipped literal, pushed negation, swapped conjuncts
        return (sess.table("t")
                .where(~(c.b >= 80) & (50 < c.a))
                .select("a", "b"))

    def _legacy_query(self, sess):
        return (sess.scan_node("t")
                .filter(E.and_(E.Not(E.cmp("b", ">=", 80)),
                               E.Cmp("<", E.Lit(50), E.Col("a"))))
                .project("a", "b"))

    def test_one_strict_fingerprint_three_spellings(self):
        sess, _ = _mk_session()
        plans = [canonicalize_plan(p) for p in
                 (self._builder_query(sess), self._variant_query(sess),
                  self._legacy_query(sess))]
        fps = {strict_fingerprint(p) for p in plans}
        assert len(fps) == 1

    def test_window_shares_one_ce_across_spellings(self):
        sess, _ = _mk_session()
        svc = QueryService(sess, max_batch=3)
        with pytest.warns(DeprecationWarning):
            handles = [svc.submit(self._builder_query(sess)),
                       svc.submit(self._variant_query(sess)),
                       svc.submit(self._legacy_query(sess))]
        keysets = [{ce["strict_psi"] for ce in h.explain()["ces"]}
                   for h in handles]
        assert keysets[0] and keysets[0] == keysets[1] == keysets[2]
        ta = handles[0].result()
        for h in handles[1:]:
            tb = h.result()
            assert ta.row_multiset() == tb.row_multiset()

    def test_variant_resumes_from_resident_ce_next_window(self):
        sess, _ = _mk_session()
        svc = QueryService(sess, max_batch=2)
        # window 1: two same-spelling queries materialize the CE
        h1 = svc.submit(self._builder_query(sess))
        h2 = svc.submit(self._builder_query(sess))
        assert h1.done and h2.done
        ces1 = {ce["strict_psi"] for ce in h1.explain()["ces"]}
        assert ces1
        # window 2: DIFFERENT spellings arrive; canonicalization maps
        # them onto the same strict key, so the resident CE is hit
        h3 = svc.submit(self._variant_query(sess))
        with pytest.warns(DeprecationWarning):
            h4 = svc.submit(self._legacy_query(sess))
        ex3, ex4 = h3.explain(), h4.explain()
        assert {ce["strict_psi"] for ce in ex3["ces"]} == ces1
        assert ex3["resident_reuse"] and ex4["resident_reuse"]
        assert all(ce["cache_hit"] for ce in ex3["ces"])

    def test_tpcds_builder_vs_handbuilt_share_one_ce(self):
        """ISSUE 5 acceptance: two syntactic variants of a TPC-DS-style
        query — one from the builder, one a hand-built raw tree — get
        equal strict fingerprints and consume ONE shared CE."""
        from repro.relational.tpcds import build_tpcds_session

        sess = build_tpcds_session(scale_rows=4000)
        svc = QueryService(sess, max_batch=2)
        builder = (sess.table("store_sales")
                   .where((c.ss_sales_price > 50.0)
                          & (c.ss_quantity >= 10))
                   .select("ss_item_sk", "ss_sales_price"))
        hand = (sess.scan_node("store_sales")
                .filter(E.and_(
                    E.Not(E.cmp("ss_quantity", "<", 10)),
                    E.Cmp("<", E.Lit(50.0), E.Col("ss_sales_price"))))
                .project("ss_item_sk", "ss_sales_price"))
        assert (strict_fingerprint(canonicalize_plan(builder))
                == strict_fingerprint(canonicalize_plan(hand)))
        h1 = svc.submit(builder)
        with pytest.warns(DeprecationWarning):
            h2 = svc.submit(hand)
        e1, e2 = h1.explain(), h2.explain()
        keys = {ce["strict_psi"] for ce in e1["ces"]}
        assert keys and keys == {ce["strict_psi"] for ce in e2["ces"]}
        assert h1.result().row_multiset() == h2.result().row_multiset()

    def test_hypothesis_variants_share_resident_ce(self):
        """Seeded stream: random variant spellings of one template in
        later windows keep hitting the window-1 CE."""
        sess, _ = _mk_session()
        svc = QueryService(sess, max_batch=2)
        base = E.and_(E.cmp("a", ">", 30), E.cmp("b", "<=", 70))

        def q(pred):
            return sess.table("t").where(pred).select("a", "b")

        h = [svc.submit(q(base)), svc.submit(q(base))]
        want = {ce["strict_psi"] for ce in h[0].explain()["ces"]}
        assert want
        rng = random.Random(7)
        for _ in range(4):
            v1, v2 = (syntactic_variant(base, rng),
                      syntactic_variant(base, rng))
            ha, hb = svc.submit(q(v1)), svc.submit(q(v2))
            for hx in (ha, hb):
                ex = hx.explain()
                assert {ce["strict_psi"] for ce in ex["ces"]} == want
                assert ex["resident_reuse"]


class TestPlanShapeKeys:
    """Satellite (ISSUE 7): the plan-shape compile cache keys slotted
    programs by predicate SHAPE.  Properties: (i) every literal variant
    of one template compiles to ONE program (the shape key), with only
    the hoisted operand values differing; (ii) structurally different
    templates never collide onto one program; (iii) across a
    multi-window recurring stream the trace cache misses only in the
    first window."""

    KINDS = {"a": "i32", "b": "i32", "d": "i32"}

    def _slots(self, pred):
        from repro.kernels.filter_project.ops import compile_predicate_slots
        return compile_predicate_slots(
            canonicalize_expr(pred), COLS, self.KINDS)

    def test_literal_variants_one_shape_key(self):
        rng = random.Random(1234)
        templates = [
            lambda x, y: E.and_(E.cmp("a", ">", x), E.cmp("b", "<", y)),
            lambda x, y: E.or_(E.cmp("a", "==", x),
                               E.and_(E.cmp("b", ">=", y),
                                      E.cmp("d", "!=", x))),
            lambda x, y: E.Not(E.and_(E.cmp("d", "<=", x),
                                      E.cmp("a", "<", y))),
        ]
        for tpl in templates:
            progs, operands = set(), set()
            for _ in range(25):
                x, y = rng.randint(0, 60), rng.randint(61, 100)
                program, ivals, fvals = self._slots(tpl(x, y))
                progs.add(program)
                operands.add((ivals, fvals))
            assert len(progs) == 1, "literal variants must share ONE shape"
            assert len(operands) > 1, "literals must be hoisted, not baked"

    def test_distinct_structures_never_collide(self):
        structures = [
            E.cmp("a", ">", 5),
            E.cmp("a", ">=", 5),                      # different op
            E.cmp("b", ">", 5),                       # different column
            E.and_(E.cmp("a", ">", 5), E.cmp("b", "<", 9)),
            E.or_(E.cmp("a", ">", 5), E.cmp("b", "<", 9)),
            E.and_(E.cmp("a", ">", 5), E.cmp("b", "<", 9),
                   E.cmp("d", "==", 2)),              # extra term
            E.Not(E.cmp("a", "==", 5)),               # != after push-down
            E.In(E.Col("a"), (2, 5, 9)),              # membership opcode
            E.col_cmp("a", "<", "b"),                 # col-col compare
        ]
        progs = [self._slots(s)[0] for s in structures]
        assert len(set(progs)) == len(progs), \
            "structurally different predicates must map to distinct keys"

    def test_trace_cache_hits_across_windows(self):
        for window_batch in (True, False):
            sess, _ = _mk_session(nrows=4000)
            sess.window_batch = window_batch
            for w in range(3):
                qs = [sess.table("t")
                      .where((c.a > 10 + 7 * i + w) & (c.b < 90 - i - w))
                      .select("a", "b") for i in range(4)]
                m = sess.run_batch(qs, mqo=False).metrics
                if w == 0:
                    assert m.trace_misses > 0       # cold window traces
                else:
                    assert m.trace_misses == 0, \
                        (window_batch, w, m.trace_misses)
                    assert m.trace_hits > 0         # hit rate 1.0
