"""Pipeline-fusion correctness: FusedPipeline ≡ the eager executor.

The fusion property (ISSUE 1 acceptance): on ANY Scan→Filter*→Project
chain, over csv and columnar storage, through the Pallas-interpret and
the XLA path, with and without deferred synchronization, the fused
executor's live rows are bit-identical to the seed eager executor's.
Randomization is seeded numpy (hypothesis is optional in this repo).
"""
import numpy as np
import pytest

from conftest import build_session, hr_queries
from repro.relational import (ExecContext, F32, FusedPipeline, I32, STR,
                              Schema, Session, execute, expr as E,
                              fuse_plan, logical as L, make_storage,
                              SessionConfig)
from repro.relational.datagen import generate_columns
from repro.relational.rules import optimize_single
from repro.relational.stats import (RelationalCostModel, StatsRegistry,
                                    build_table_stats)

SCHEMA = Schema.of(("k", I32), ("v", I32), ("x", F32), ("s", STR(8)))


def _toy(nrows=700, seed=0, fmt="columnar"):
    rng = np.random.default_rng(seed)
    cols = {
        "k": rng.integers(0, 20, nrows).astype(np.int32),
        "v": rng.integers(0, 1000, nrows).astype(np.int32),
        "x": rng.random(nrows).astype(np.float32),
        "s": rng.integers(97, 100, (nrows, 8)).astype(np.uint8),
    }
    st, _ = make_storage("t", SCHEMA, nrows, fmt, cols=cols)
    return st, cols


def _cost_model(cols, nrows):
    reg = StatsRegistry()
    reg.register("t", build_table_stats(cols, nrows, SCHEMA))
    return RelationalCostModel(reg)


def _pick_op(rng, ops):
    return str(rng.choice(ops))


def _random_pred(rng, avail) -> E.Expr:
    """Random predicate over the columns still in scope."""
    numeric = [c for c in ("k", "v", "x") if c in avail]

    def term():
        col = str(rng.choice(numeric))
        if col == "k":
            return E.cmp("k", _pick_op(rng, ["<", "<=", ">", ">=", "==",
                                             "!="]), int(rng.integers(0, 20)))
        if col == "v":
            return E.cmp("v", _pick_op(rng, ["<", ">", ">=", "<="]),
                         int(rng.integers(0, 1000)))
        return E.cmp("x", _pick_op(rng, ["<", ">"]),
                     float(np.float32(rng.random())))

    terms = [term() for _ in range(int(rng.integers(1, 4)))]
    if "k" in avail and "v" in avail and rng.integers(0, 3) == 0:
        terms.append(E.col_cmp("k", _pick_op(rng, ["<", ">"]), "v"))
    combine = E.and_ if rng.integers(0, 2) else E.or_
    pred = combine(*terms)
    if rng.integers(0, 4) == 0:
        pred = E.not_(pred)
    return pred


def _random_chain(rng, fmt) -> L.Node:
    plan: L.Node = L.scan("t", SCHEMA, fmt)
    n_ops = int(rng.integers(1, 5))
    saw_filter = False
    for i in range(n_ops):
        avail = set(plan.schema.names)
        can_filter = avail & {"k", "v", "x"}
        if can_filter and (rng.integers(0, 2) or not saw_filter):
            plan = plan.filter(_random_pred(rng, avail))
            saw_filter = True
        else:
            names = list(plan.schema.names)
            keep = sorted(rng.choice(len(names),
                                     size=int(rng.integers(1, len(names) + 1)),
                                     replace=False))
            plan = plan.project(*[names[i] for i in keep])
    # chains that ended up projection-only stay valid test cases: the
    # fusion pass must leave them alone and results must still match
    return plan


def _assert_tables_bit_identical(a, b):
    assert a.schema.names == b.schema.names
    assert a.nrows == b.nrows
    an, bn = a.to_numpy(), b.to_numpy()
    for name in a.schema.names:
        np.testing.assert_array_equal(an[name], bn[name], err_msg=name)


class TestFusePass:
    def test_chain_collapses(self):
        plan = (L.scan("t", SCHEMA, "columnar")
                .filter(E.cmp("v", ">", 10)).filter(E.cmp("k", "<", 5))
                .project("k", "v"))
        fused = fuse_plan(plan)
        assert isinstance(fused, FusedPipeline)
        assert fused.n_filters == 2
        assert fused.cols == ("k", "v")
        assert isinstance(fused.source, L.Scan)

    def test_pure_projection_not_fused(self):
        plan = L.scan("t", SCHEMA, "columnar").project("k")
        assert fuse_plan(plan) is plan

    def test_join_blocks_chain_but_inner_chains_fuse(self):
        s2 = Schema.of(("b", I32), ("q", I32))
        left = L.scan("t", SCHEMA, "columnar").filter(E.cmp("v", ">", 10))
        right = L.scan("r", s2, "columnar").filter(E.cmp("q", "<", 5))
        plan = left.join(right, "k", "b").filter(E.cmp("q", ">", 1))
        fused = fuse_plan(plan)
        assert isinstance(fused, L.Filter)          # above the join: eager
        join = fused.child
        assert isinstance(join, L.Join)
        assert all(isinstance(c, FusedPipeline) for c in join.children)

    def test_filter_above_fused_absorbs(self):
        inner = fuse_plan(L.scan("t", SCHEMA, "columnar")
                          .filter(E.cmp("v", ">", 10)).project("k", "v"))
        outer = fuse_plan(L.Filter(child=inner, pred=E.cmp("k", "<", 5)))
        assert isinstance(outer, FusedPipeline)
        assert outer.n_filters == 2
        assert isinstance(outer.source, L.Scan)

    def test_unknown_column_degrades_to_eager(self):
        # hand-built Filter over a Project that dropped the pred column
        plan = L.Filter(child=L.scan("t", SCHEMA, "columnar").project("k"),
                        pred=E.cmp("v", ">", 10))
        assert fuse_plan(plan) is plan


class TestFusedEqualsEager:
    """The acceptance property: fused output ≡ eager output, bit for bit."""

    @pytest.mark.parametrize("fmt", ["columnar", "csv"])
    @pytest.mark.parametrize("pallas", [False, True])
    def test_randomized_chains(self, fmt, pallas):
        n_cases = 6 if pallas else 12   # interpret mode is slow on CPU
        for case in range(n_cases):
            rng = np.random.default_rng(1000 * pallas + 10 * case
                                        + (fmt == "csv"))
            nrows = int(rng.integers(3, 1200))
            st, cols = _toy(nrows=nrows, seed=case, fmt=fmt)
            plan = _random_chain(rng, fmt)
            eager = execute(plan, ExecContext(
                catalog={"t": st}, fuse=False, defer_sync=False))
            fused = execute(plan, ExecContext(
                catalog={"t": st}, use_pallas_filter=pallas))
            _assert_tables_bit_identical(eager, fused)

    @pytest.mark.parametrize("fmt", ["columnar", "csv"])
    def test_deferred_sync_with_estimates(self, fmt):
        for case in range(6):
            rng = np.random.default_rng(77 + case)
            st, cols = _toy(nrows=900, seed=case, fmt=fmt)
            cm = _cost_model(cols, 900)
            plan = _random_chain(rng, fmt)
            eager = execute(plan, ExecContext(
                catalog={"t": st}, fuse=False, defer_sync=False))
            fused = execute(plan, ExecContext(
                catalog={"t": st}, cost_model=cm, scan_cache={}))
            _assert_tables_bit_identical(eager, fused)

    def test_estimate_overflow_recompacts(self):
        """A wildly wrong (too small) estimate must not lose rows."""
        st, cols = _toy(nrows=800, seed=3)
        # stats built from all-zero columns => selectivity of v>10 ~ 0,
        # while the actual data matches ~99% of rows
        lying = {n: np.zeros_like(a) for n, a in cols.items()}
        cm = _cost_model(lying, 800)
        plan = (L.scan("t", SCHEMA, "columnar")
                .filter(E.cmp("v", ">", 10)).project("k", "v"))
        eager = execute(plan, ExecContext(
            catalog={"t": st}, fuse=False, defer_sync=False))
        fused = execute(plan, ExecContext(catalog={"t": st}, cost_model=cm))
        assert fused.nrows > 700     # the estimate really was wrong
        _assert_tables_bit_identical(eager, fused)

    def test_estimate_overflow_eager_ops(self):
        """Deferred sync on the eager Filter/Join/Aggregate path."""
        st, cols = _toy(nrows=800, seed=4)
        lying = {n: np.zeros_like(a) for n, a in cols.items()}
        cm = _cost_model(lying, 800)
        plan = (L.scan("t", SCHEMA, "columnar")
                .filter(E.cmp("v", ">", 10))
                .groupby("k").agg(("n", "count", ""), ("sv", "sum", "v")))
        eager = execute(plan, ExecContext(
            catalog={"t": st}, fuse=False, defer_sync=False))
        deferred = execute(plan, ExecContext(
            catalog={"t": st}, cost_model=cm))
        assert eager.row_multiset() == deferred.row_multiset()


class TestScanCache:
    def test_hits_after_first_scan(self):
        st, cols = _toy(nrows=500)
        sc = {}
        plan = (L.scan("t", SCHEMA, "columnar")
                .filter(E.cmp("v", ">", 500)).project("k", "v"))
        ctx1 = ExecContext(catalog={"t": st}, scan_cache=sc)
        a = execute(plan, ctx1)
        assert ctx1.metrics.bytes_read_disk > 0
        assert ctx1.metrics.bytes_scan_cache_read == 0
        ctx2 = ExecContext(catalog={"t": st}, scan_cache=sc)
        b = execute(plan, ctx2)
        assert ctx2.metrics.bytes_read_disk == 0
        assert ctx2.metrics.bytes_scan_cache_read > 0
        _assert_tables_bit_identical(a, b)

    def test_csv_caches_raw_bytes_but_reparses(self):
        st, cols = _toy(nrows=300, fmt="csv")
        sc = {}
        plan = L.scan("t", SCHEMA, "csv").filter(E.cmp("v", ">", 500))
        ctx1 = ExecContext(catalog={"t": st}, scan_cache=sc)
        execute(plan, ctx1)
        parsed_first = ctx1.metrics.bytes_parsed
        ctx2 = ExecContext(catalog={"t": st}, scan_cache=sc)
        execute(plan, ctx2)
        assert ctx2.metrics.bytes_read_disk == 0          # raw bytes cached
        assert ctx2.metrics.bytes_parsed == parsed_first  # parse still paid


class TestSessionEndToEnd:
    """Fused Session ≡ seed-eager Session on the paper's running example
    (joins + aggregates + sorts above the fused leaf chains)."""

    @pytest.mark.parametrize("mqo", [False, True])
    def test_hr_queries_match(self, hr_data, mqo):
        eager_sess = build_session(hr_data)
        eager_sess.fuse = eager_sess.defer_sync = \
            eager_sess.use_scan_cache = False
        fused_sess = build_session(hr_data)
        base = eager_sess.run_batch(hr_queries(eager_sess), mqo=mqo)
        opt = fused_sess.run_batch(hr_queries(fused_sess), mqo=mqo)
        for b, o in zip(base.results, opt.results):
            assert b.table.row_multiset() == o.table.row_multiset()

    def test_second_batch_uses_scan_cache(self, hr_data):
        sess = build_session(hr_data)
        sess.run_batch(hr_queries(sess), mqo=False)
        m = sess.run_batch(hr_queries(sess), mqo=False).metrics
        assert m.bytes_read_disk == 0
        assert m.bytes_scan_cache_read > 0

    def test_mqo_divergent_extraction_is_fused(self):
        from repro.core.plan import walk

        rng = np.random.default_rng(11)
        S = Schema.of(("a", I32), ("b", I32), ("c", I32))
        cols = {c: rng.integers(0, 100, 2000).astype(np.int32)
                for c in ("a", "b", "c")}
        sess = Session.from_config(
            SessionConfig.from_legacy_kwargs(budget_bytes=1 << 24))
        st, _ = make_storage("t", S, 2000, "columnar", cols=cols)
        sess.register(st)
        t = sess.table("t")
        q1 = t.filter(E.cmp("a", ">", 80)).project("a", "b")
        q2 = t.filter(E.cmp("a", "<", 20)).project("a", "c")
        res = sess.run_batch([q1, q2], mqo=True)
        if res.mqo.report.n_selected:
            fused_nodes = [n for p in res.mqo.rewritten.plans
                           for n in walk(p)
                           if isinstance(n, FusedPipeline)]
            assert fused_nodes, "divergent CE residuals should be fused"
        # and of course: results match the no-MQO run
        base = sess.run_batch([q1, q2], mqo=False)
        for b, o in zip(base.results, res.results):
            assert b.table.row_multiset() == o.table.row_multiset()


class TestReviewRegressions:
    def test_fractional_threshold_on_int_column_is_exact(self):
        # values around 2^24, where an f32 promotion would collapse
        # neighboring ints; the engine must fold to an exact int compare
        vals = np.array([2**24 - 1, 2**24, 2**24 + 1, 2**24 + 2, 5],
                        np.int32)
        sch = Schema.of(("v", I32))
        st, _ = make_storage("t", sch, len(vals), "columnar",
                             cols={"v": vals})
        for op, thr, expect in [
            (">", 2**24 + 0.5, {2**24 + 1, 2**24 + 2}),
            ("<=", 2**24 + 0.5, {2**24 - 1, 2**24, 5}),
            ("==", 10.5, set()),
            ("!=", 10.5, set(int(v) for v in vals)),
        ]:
            plan = L.scan("t", sch, "columnar").filter(E.cmp("v", op, thr))
            for ctx in (ExecContext(catalog={"t": st}, fuse=False,
                                    defer_sync=False),
                        ExecContext(catalog={"t": st}),
                        ExecContext(catalog={"t": st},
                                    use_pallas_filter=True)):
                got = {r[0] for r in execute(plan, ctx).row_multiset()}
                assert got == expect, (op, thr, got)

    def test_kernel_supports_string_colcol_with_schema(self):
        from repro.kernels.filter_project.ops import kernel_supports

        pred = E.col_cmp("s1", "==", "s2")
        # without dtype info the name-only check cannot reject it...
        assert kernel_supports(pred)
        # ...but with the schema's numeric column set it must
        assert not kernel_supports(pred, numeric_cols=("k", "v"))
        assert kernel_supports(E.col_cmp("k", "<", "v"),
                               numeric_cols=("k", "v"))

    def test_gross_overestimate_shrinks_capacity(self):
        """An est-padded buffer must not outlive the operator: a result
        with ~0 rows keeps a tight capacity even when the estimate said
        20% of the table (else cached CEs are charged padded nbytes)."""
        st, cols = _toy(nrows=100_000, seed=9)
        cm = _cost_model(cols, 100_000)
        # contradiction: est ~ sel(v>500)*sel(v<400)*n >> 0, actual 0
        plan = (L.scan("t", SCHEMA, "columnar")
                .filter(E.and_(E.cmp("v", ">", 500), E.cmp("v", "<", 400)))
                .project("k", "v"))
        out = execute(plan, ExecContext(catalog={"t": st}, cost_model=cm))
        assert out.nrows == 0
        assert out.capacity <= 2    # not the est-sized padded buffer
        eager = execute(plan, ExecContext(
            catalog={"t": st}, fuse=False, defer_sync=False))
        _assert_tables_bit_identical(eager, out)

    def test_register_invalidates_scan_cache(self):
        nrows = 256   # == capacity, so the cache key is identical
        sch = Schema.of(("v", I32))
        v1 = np.arange(nrows, dtype=np.int32)
        v2 = v1 + 10_000
        sess = Session.from_config(
            SessionConfig.from_legacy_kwargs(budget_bytes=1 << 24))
        st1, _ = make_storage("t", sch, nrows, "columnar", cols={"v": v1})
        sess.register(st1, columnar_for_stats={"v": v1})
        q = sess.table("t").filter(E.cmp("v", ">=", 0))
        first = sess.run_batch([q], mqo=False).results[0].table.to_numpy()
        np.testing.assert_array_equal(first["v"], v1)
        st2, _ = make_storage("t", sch, nrows, "columnar", cols={"v": v2})
        sess.register(st2, columnar_for_stats={"v": v2})
        q2 = sess.table("t").filter(E.cmp("v", ">=", 0))
        second = sess.run_batch([q2], mqo=False).results[0].table.to_numpy()
        np.testing.assert_array_equal(second["v"], v2)


class TestUnionDeferred:
    """Satellite (ISSUE 2): Union sizes its output from the sum of the
    input cardinality estimates and compacts every column in one fused
    dispatch — results must stay bit-identical to the seed eager path
    (per-column argsort compaction, exact sizing)."""

    def _union_plan(self, rng) -> L.Node:
        left = (L.scan("t", SCHEMA, "columnar")
                .filter(_random_pred(rng, {"k", "v", "x"}))
                .project("k", "v"))
        right = (L.scan("t", SCHEMA, "columnar")
                 .filter(_random_pred(rng, {"k", "v", "x"}))
                 .project("k", "v"))
        plan = left.union(right)
        if rng.integers(0, 2):
            third = (L.scan("t", SCHEMA, "columnar")
                     .filter(_random_pred(rng, {"k", "v", "x"}))
                     .project("k", "v"))
            plan = plan.union(third)
        return plan

    def test_randomized_unions_match_eager(self):
        for case in range(8):
            rng = np.random.default_rng(500 + case)
            nrows = int(rng.integers(3, 1200))
            st, cols = _toy(nrows=nrows, seed=case)
            cm = _cost_model(cols, nrows)
            plan = self._union_plan(rng)
            eager = execute(plan, ExecContext(
                catalog={"t": st}, fuse=False, defer_sync=False))
            fused = execute(plan, ExecContext(
                catalog={"t": st}, cost_model=cm))
            _assert_tables_bit_identical(eager, fused)

    def test_empty_sides(self):
        st, cols = _toy(nrows=200, seed=1)
        cm = _cost_model(cols, 200)
        empty = (L.scan("t", SCHEMA, "columnar")
                 .filter(E.and_(E.cmp("v", ">", 2000)))   # matches nothing
                 .project("k", "v"))
        full = (L.scan("t", SCHEMA, "columnar")
                .filter(E.cmp("v", ">=", 0)).project("k", "v"))
        for plan in (empty.union(full), full.union(empty),
                     empty.union(empty)):
            eager = execute(plan, ExecContext(
                catalog={"t": st}, fuse=False, defer_sync=False))
            fused = execute(plan, ExecContext(
                catalog={"t": st}, cost_model=cm))
            _assert_tables_bit_identical(eager, fused)


class TestLocalOptimizerChains:
    """optimize_single output (the MQO input shape) also fuses cleanly."""

    def test_optimized_plan_fuses_and_matches(self):
        st, cols = _toy(nrows=600, seed=8)
        plan = (L.scan("t", SCHEMA, "columnar")
                .project("k", "v", "x")
                .filter(E.and_(E.cmp("v", ">", 100), E.cmp("x", "<", 0.9)))
                .project("k", "v"))
        opt = optimize_single(plan)
        eager = execute(opt, ExecContext(
            catalog={"t": st}, fuse=False, defer_sync=False))
        fused = execute(opt, ExecContext(catalog={"t": st}))
        _assert_tables_bit_identical(eager, fused)


class TestInListCoverage:
    """Satellite (ISSUE 7): ``In``-list membership runs through the
    postfix programs — every kernel route must match the eager/XLA
    oracle bit for bit, including fractional and out-of-range list
    values against integer columns."""

    def _contexts(self, st, pallas):
        return (
            ExecContext(catalog={"t": st}),                     # slotted XLA
            ExecContext(catalog={"t": st}, shape_cache=False),  # literal jit
            ExecContext(catalog={"t": st}, use_pallas_filter=pallas),
        )

    @pytest.mark.parametrize("fmt", ["columnar", "csv"])
    @pytest.mark.parametrize("pallas", [False, True])
    def test_randomized_in_lists(self, fmt, pallas):
        for case in range(4 if pallas else 8):
            rng = np.random.default_rng(4000 + 10 * case + (fmt == "csv"))
            nrows = int(rng.integers(3, 900))
            st, cols = _toy(nrows=nrows, seed=case, fmt=fmt)
            vals = tuple(int(v) for v in
                         rng.integers(0, 20, int(rng.integers(1, 6))))
            pred: E.Expr = E.In(E.Col("k"), vals)
            in_only = not rng.integers(0, 2)
            if not in_only:
                pred = E.and_(pred, _random_pred(rng, {"k", "v", "x"}))
            plan = L.scan("t", SCHEMA, fmt).filter(pred).project("k", "v")
            eager = execute(plan, ExecContext(
                catalog={"t": st}, fuse=False, defer_sync=False))
            if in_only:      # numpy oracle for the membership itself
                keep = np.isin(cols["k"], np.asarray(vals, np.int32))
                assert eager.nrows == int(keep.sum())
            for ctx in self._contexts(st, pallas):
                _assert_tables_bit_identical(eager, execute(plan, ctx))

    @pytest.mark.parametrize("pallas", [False, True])
    def test_in_list_edge_values(self, pallas):
        # fractional values never equal an int column; out-of-range
        # values never equal; duplicates are harmless
        st, cols = _toy(nrows=400, seed=5)
        vals = (3, 3, 7.0, 7.5, 2**40, -2**40, 11)
        plan = (L.scan("t", SCHEMA, "columnar")
                .filter(E.In(E.Col("k"), vals)).project("k", "v"))
        expect = np.isin(cols["k"], np.asarray([3, 7, 11], np.int32))
        eager = execute(plan, ExecContext(
            catalog={"t": st}, fuse=False, defer_sync=False))
        assert eager.nrows == int(expect.sum())
        for ctx in self._contexts(st, pallas):
            _assert_tables_bit_identical(eager, execute(plan, ctx))


class TestI64Coverage:
    """Satellite (ISSUE 7): int64 columns (columnar-only, x64 mode)
    through every filter route — values beyond 2^32 must compare
    exactly (an f32/i32 downcast would collapse them)."""

    def _i64_case(self, nrows, seed):
        from repro.relational import I64
        rng = np.random.default_rng(seed)
        sch = Schema.of(("big", I64), ("v", I32))
        cols = {
            "big": rng.integers(1, 1 << 40, nrows).astype(np.int64),
            "v": rng.integers(0, 1000, nrows).astype(np.int32),
        }
        st, _ = make_storage("t", sch, nrows, "columnar", cols=cols)
        return sch, st, cols

    @pytest.mark.parametrize("pallas", [False, True])
    def test_i64_filter_matches_oracle(self, pallas):
        from jax.experimental import enable_x64
        with enable_x64():
            for case in range(4):
                sch, st, cols = self._i64_case(600, 6000 + case)
                thr = int(np.median(cols["big"]))
                pred = E.and_(E.cmp("big", ">", thr),
                              E.cmp("v", "<", 700))
                plan = (L.scan("t", sch, "columnar")
                        .filter(pred).project("big", "v"))
                expect = (cols["big"] > thr) & (cols["v"] < 700)
                eager = execute(plan, ExecContext(
                    catalog={"t": st}, fuse=False, defer_sync=False))
                assert eager.nrows == int(expect.sum())
                np.testing.assert_array_equal(
                    np.sort(eager.to_numpy()["big"]),
                    np.sort(cols["big"][expect]))
                for ctx in (ExecContext(catalog={"t": st}),
                            ExecContext(catalog={"t": st},
                                        shape_cache=False),
                            ExecContext(catalog={"t": st},
                                        use_pallas_filter=pallas)):
                    _assert_tables_bit_identical(eager, execute(plan, ctx))

    def test_i64_in_list_exact_beyond_2_53(self):
        from jax.experimental import enable_x64
        # neighbors beyond 2^53 are indistinguishable even in f64 — the
        # membership compare must stay integer-exact
        from repro.relational import I64
        base = (1 << 53) + 2
        vals = np.array([base - 1, base, base + 1, 5], np.int64)
        sch = Schema.of(("big", I64))
        with enable_x64():
            st, _ = make_storage("t", sch, len(vals), "columnar",
                                 cols={"big": vals})
            plan = (L.scan("t", sch, "columnar")
                    .filter(E.In(E.Col("big"), (int(base),))))
            for ctx in (ExecContext(catalog={"t": st}),
                        ExecContext(catalog={"t": st}, fuse=False,
                                    defer_sync=False)):
                out = execute(plan, ctx)
                assert out.nrows == 1
                assert int(out.to_numpy()["big"][0]) == base


class TestWindowBatchIdentity:
    """Tentpole acceptance (ISSUE 7): a window executed as batched
    shared dispatches is BIT-identical to per-query dispatch — over
    both storage formats, both kernel routes, and mixed windows where
    only a subset of the plans share a template."""

    def _sessions(self, pallas):
        out = []
        for window_batch in (True, False):
            sess = Session.from_config(SessionConfig().with_execution(
                window_batch=window_batch, use_pallas_filter=pallas))
            for name, seed in (("t", 21), ("r", 22)):
                rng = np.random.default_rng(seed)
                nrows = 800 if name == "t" else 500
                cols = {
                    "k": rng.integers(0, 20, nrows).astype(np.int32),
                    "v": rng.integers(0, 1000, nrows).astype(np.int32),
                    "x": rng.random(nrows).astype(np.float32),
                    "s": rng.integers(97, 100, (nrows, 8)).astype(np.uint8),
                }
                st, _ = make_storage(name, SCHEMA, nrows, self.fmt,
                                     cols=cols)
                sess.register(st, columnar_for_stats=cols)
            out.append(sess)
        return out

    def _mixed_window(self, sess, w):
        """4 same-template plans over t (batchable), one different
        shape over t, one over r — the batch group must contain exactly
        the template members and leave the rest per-query."""
        t = lambda: sess.table("t")
        qs = [t().filter(E.and_(E.cmp("v", ">", 100 + 37 * i + 11 * w),
                                E.cmp("v", "<", 950 - 13 * i)))
              .project("k", "v") for i in range(4)]
        qs.append(t().filter(E.cmp("x", "<", 0.5 + 0.01 * w))
                  .project("k", "x"))
        qs.append(sess.table("r").filter(E.cmp("k", "==", 3 + w))
                  .project("k", "v"))
        return qs

    @pytest.mark.parametrize("fmt", ["columnar", "csv"])
    @pytest.mark.parametrize("pallas", [False, True])
    def test_mixed_window_bit_identical(self, fmt, pallas):
        self.fmt = fmt
        batched, perq = self._sessions(pallas)
        for w in range(3):
            rb = batched.run_batch(self._mixed_window(batched, w),
                                   mqo=False)
            rp = perq.run_batch(self._mixed_window(perq, w), mqo=False)
            assert rb.metrics.batched_dispatches >= 1
            assert rb.metrics.batched_queries == 4
            for a, b in zip(rb.results, rp.results):
                _assert_tables_bit_identical(a.table, b.table)

    @pytest.mark.parametrize("fmt", ["columnar", "csv"])
    def test_all_singletons_stay_per_query(self, fmt):
        self.fmt = fmt
        batched, perq = self._sessions(False)
        t = lambda s: s.table("t")
        mk = lambda s: [t(s).filter(E.cmp("v", ">", 500)).project("k"),
                        t(s).filter(E.cmp("x", "<", 0.4)).project("x"),
                        s.table("r").filter(E.cmp("k", "<", 9))
                        .project("k", "v")]
        rb = batched.run_batch(mk(batched), mqo=False)
        rp = perq.run_batch(mk(perq), mqo=False)
        assert rb.metrics.batched_dispatches == 0   # no shared template
        for a, b in zip(rb.results, rp.results):
            _assert_tables_bit_identical(a.table, b.table)
