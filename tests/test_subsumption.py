"""Semantic subsumption + pid bitset pool (ISSUE 8).

Covers:
  * the ONE shared integer-threshold fold (``expr.fold_int_cmp``): a
    single case table pinned against every call site — the direct fold,
    interval normalization, and partition pruning — plus semantic
    ground truth via ``eval_expr`` (the three sites must never drift);
  * ``normalize_intervals`` unit semantics (range-merge, inclusive
    integer bounds, contradiction → FALSE, identity preservation);
  * ``subsumes`` / ``subsumption_residual`` unit semantics;
  * ``PidPool`` unit behavior (record / intersect / implies-closure /
    layout mismatch / invalidation / bytes accounting);
  * hypothesis properties:
      - the interval-normalized predicate selects the SAME rows as the
        raw spelling on random data,
      - pid-bitset-pruned execution is bit-identical to unpruned over
        both partition schemes x both storage formats,
      - a subsumption-resumed query returns exactly the rows of a
        from-scratch run;
  * service integration: ``explain()`` reports ``subsumption_hit`` /
    ``pid_pruned_parts``; the ``mqo.subsumption`` and
    ``execution.pid_cache`` knobs disable each channel independently.
"""
import numpy as np
import pytest

from repro.core.memory import MemoryManager, PidPool
from repro.relational import (ExecutionConfig, I32, F32, MemoryConfig,
                              MqoConfig, Partitioning, QueryService, Schema,
                              Session, SessionConfig, expr as E,
                              make_storage)
from repro.relational.canonical import (FALSE, canonicalize_expr, is_false,
                                        is_true, normalize_intervals,
                                        subsumes, subsumption_residual)
from repro.relational.datagen import generate_columns, synthetic_schema
from repro.relational.partition import partition_table, prune_parts

INT_SCHEMA = Schema.of(("a", I32), ("b", I32), ("f", F32))

I32_MIN, I32_MAX = -(1 << 31), (1 << 31) - 1


def _canon(pred):
    return E.canonical(canonicalize_expr(pred))


def _norm(pred, schema=INT_SCHEMA):
    return normalize_intervals(canonicalize_expr(pred), schema)


# ---------------------------------------------------------------------------
# the shared integer-threshold fold: ONE case table, every call site
# ---------------------------------------------------------------------------
# (op, fractional threshold, expected fold_int_cmp result).  All three
# consumers — eval_expr's exact compare lowering, partition pruning's
# per-partition maybe-check, and canonical interval normalization —
# route through expr.fold_int_cmp; this table pins them together.
FOLD_CASES = [
    (">", 10.5, ("cmp", ">", 10)),      # a > 10.5  ⟺  a > 10
    (">=", 10.5, ("cmp", ">=", 11)),    # a >= 10.5 ⟺  a >= 11
    ("<", 10.5, ("cmp", "<", 11)),      # a < 10.5  ⟺  a < 11
    ("<=", 10.5, ("cmp", "<=", 10)),    # a <= 10.5 ⟺  a <= 10
    (">", -0.5, ("cmp", ">", -1)),
    ("<", -0.5, ("cmp", "<", 0)),
    ("==", 10.5, ("all", False)),       # an int never equals a fraction
    ("!=", 10.5, ("all", True)),
    # thresholds beyond the i32 range saturate to a constant
    ("<", -3000000000.5, ("all", False)),
    (">", -3000000000.5, ("all", True)),
    ("<=", 3000000000.5, ("all", True)),
    (">=", 3000000000.5, ("all", False)),
]


def _inclusive(op, b):
    """The inclusive integer spelling interval normalization emits."""
    if op == ">":
        return (">=", b + 1)
    if op == "<":
        return ("<=", b - 1)
    return (op, b)


class TestSharedFoldCaseTable:
    @pytest.mark.parametrize("op,v,expect", FOLD_CASES)
    def test_direct_fold(self, op, v, expect):
        assert E.fold_int_cmp(op, v, bits=32) == expect

    @pytest.mark.parametrize("op,v,expect", FOLD_CASES)
    def test_fold_is_semantically_exact(self, op, v, expect):
        # ground truth: the folded compare selects the same int32 values
        a = np.array([-(1 << 31), -12, -1, 0, 1, 10, 11, 12,
                      (1 << 31) - 1], dtype=np.int64)
        npop = {"<": np.less, "<=": np.less_equal, ">": np.greater,
                ">=": np.greater_equal, "==": np.equal,
                "!=": np.not_equal}
        raw = npop[op](a.astype(np.float64), v)
        if expect[0] == "all":
            assert bool(raw.all()) == expect[1]
            assert bool(raw.any()) == expect[1]
        else:
            _, op2, b = expect
            assert np.array_equal(raw, npop[op2](a, b))

    @pytest.mark.parametrize("op,v,expect", FOLD_CASES)
    def test_normalize_intervals_site(self, op, v, expect):
        norm = _norm(E.cmp("a", op, v))
        if expect == ("all", True):
            assert is_true(norm)
        elif expect == ("all", False):
            assert is_false(norm)
        else:
            _, op2, b = expect
            op3, b3 = _inclusive(op2, b)
            assert E.canonical(norm) == _canon(E.cmp("a", op3, b3))

    @pytest.mark.parametrize("op,v,expect",
                             [c for c in FOLD_CASES if c[2][0] == "cmp"])
    def test_prune_parts_site(self, op, v, expect):
        # pruning the fractional spelling == pruning the folded spelling
        rng = np.random.default_rng(3)
        cols = {"n1": rng.integers(-40, 60, 4000).astype(np.int32)}
        _, _, info = partition_table(Partitioning("n1", "range", 8),
                                     4000, cols)
        _, op2, b = expect
        raw = set(prune_parts(E.cmp("n1", op, v), info))
        folded = set(prune_parts(E.cmp("n1", op2, b), info))
        assert raw == folded


# ---------------------------------------------------------------------------
# interval normal form (unit)
# ---------------------------------------------------------------------------
class TestNormalizeIntervals:
    def test_range_merge_keeps_tightest(self):
        p = _norm(E.and_(E.cmp("a", ">", 5), E.cmp("a", ">", 3)))
        assert E.canonical(p) == _canon(E.cmp("a", ">=", 6))

    def test_strict_int_bounds_become_inclusive(self):
        assert E.canonical(_norm(E.cmp("a", ">", 5))) == \
            _canon(E.cmp("a", ">=", 6))
        assert E.canonical(_norm(E.cmp("a", "<", 5))) == \
            _canon(E.cmp("a", "<=", 4))

    def test_contradiction_collapses_to_false(self):
        assert is_false(_norm(E.and_(E.cmp("a", ">", 5),
                                     E.cmp("a", "<", 3))))
        # adjacent strict bounds over ints: nothing between 5 and 6
        assert is_false(_norm(E.and_(E.cmp("a", ">", 5),
                                     E.cmp("a", "<", 6))))
        assert is_false(_norm(E.and_(E.cmp("a", "==", 2),
                                     E.cmp("a", "==", 3))))

    def test_degenerate_interval_becomes_eq(self):
        p = _norm(E.and_(E.cmp("a", ">=", 5), E.cmp("a", "<=", 5)))
        assert E.canonical(p) == _canon(E.cmp("a", "==", 5))

    def test_eq_absorbs_consistent_bounds(self):
        p = _norm(E.and_(E.cmp("a", "==", 7), E.cmp("a", ">", 2)))
        assert E.canonical(p) == _canon(E.cmp("a", "==", 7))

    def test_neq_outside_interval_is_dropped(self):
        p = _norm(E.and_(E.cmp("a", ">=", 5), E.cmp("a", "!=", 3)))
        assert E.canonical(p) == _canon(E.cmp("a", ">=", 5))

    def test_float_bounds_stay_strict(self):
        p = _norm(E.and_(E.cmp("f", ">", 0.5), E.cmp("f", ">", 0.25)))
        assert E.canonical(p) == _canon(E.cmp("f", ">", 0.5))

    def test_untouched_pred_preserves_identity(self):
        p = canonicalize_expr(E.and_(E.cmp("a", ">=", 5),
                                     E.cmp("b", "<=", 9)))
        assert normalize_intervals(p, INT_SCHEMA) is p

    def test_other_columns_kept_verbatim(self):
        p = _norm(E.and_(E.cmp("a", ">", 5), E.cmp("a", ">", 3),
                         E.cmp("b", "<", 9)))
        assert E.canonical(p) == _canon(E.and_(E.cmp("a", ">=", 6),
                                               E.cmp("b", "<=", 8)))


# ---------------------------------------------------------------------------
# subsumption (unit)
# ---------------------------------------------------------------------------
class TestSubsumption:
    S = INT_SCHEMA

    def test_conjunct_superset_subsumed(self):
        p = E.cmp("a", ">", 5)
        q = E.and_(E.cmp("a", ">", 5), E.cmp("b", "<", 3))
        assert subsumes(p, q, self.S)
        resid = subsumption_residual(p, q, self.S)
        # the residual comes back interval-normalized: b < 3 ⟺ b <= 2
        assert E.canonical(resid) == E.canonical(_norm(E.cmp("b", "<", 3)))
        # not symmetric: q has rows p lacks? no — p has rows q lacks
        assert not subsumes(q, p, self.S)

    def test_interval_containment_subsumed(self):
        p, q = E.cmp("a", ">=", 5), E.cmp("a", ">", 7)
        assert subsumes(p, q, self.S)
        resid = subsumption_residual(p, q, self.S)
        assert E.canonical(resid) == _canon(E.cmp("a", ">=", 8))
        assert not subsumes(q, p, self.S)

    def test_equal_preds_residual_true(self):
        p = E.and_(E.cmp("a", ">", 5), E.cmp("b", "<", 3))
        q = E.and_(E.cmp("b", "<", 3), E.cmp("a", ">", 5))
        assert is_true(subsumption_residual(p, q, self.S))

    def test_fractional_thresholds_fold_before_deciding(self):
        assert subsumes(E.cmp("a", ">", 4.5), E.cmp("a", ">=", 6), self.S)
        assert not subsumes(E.cmp("a", ">", 4.5), E.cmp("a", ">=", 4),
                            self.S)

    def test_contradictory_query_residual_false(self):
        q = E.and_(E.cmp("a", ">", 5), E.cmp("a", "<", 3))
        resid = subsumption_residual(E.cmp("b", ">", 0), q, self.S)
        assert resid is not None and is_false(resid)

    def test_eq_inside_interval_subsumed(self):
        p = E.and_(E.cmp("a", ">=", 5), E.cmp("a", "<=", 10))
        q = E.cmp("a", "==", 7)
        assert subsumes(p, q, self.S)
        assert E.canonical(subsumption_residual(p, q, self.S)) == \
            _canon(E.cmp("a", "==", 7))

    def test_in_membership_subsumed(self):
        p = E.isin("a", [1, 2, 3])
        q = E.isin("a", [1, 2])
        assert subsumes(p, q, self.S)
        assert not subsumes(q, p, self.S)

    def test_non_numeric_atoms_need_exact_match(self):
        # column-column compares are only implied by an exact canonical
        # match of the same atom
        p = E.col_cmp("a", "<", "b")
        q = E.and_(E.col_cmp("a", "<", "b"), E.cmp("a", ">", 5))
        assert subsumes(p, q, self.S)
        assert not subsumes(E.col_cmp("a", "<", "b"),
                            E.cmp("a", ">", 5), self.S)

    def test_disjoint_columns_not_subsumed(self):
        assert not subsumes(E.cmp("a", ">", 5), E.cmp("b", ">", 5), self.S)

    def test_or_pred_needs_exact_match(self):
        p = E.or_(E.cmp("a", ">", 5), E.cmp("b", ">", 5))
        q = E.and_(E.or_(E.cmp("a", ">", 5), E.cmp("b", ">", 5)),
                   E.cmp("a", "<", 100))
        assert subsumes(p, q, self.S)
        # a bare disjunct does NOT imply the disjunction's atom-set
        # conservatively? it does semantically, but the decision is
        # conservative — must simply never claim an unsound direction
        assert not subsumes(q, p, self.S)


# ---------------------------------------------------------------------------
# PidPool (unit)
# ---------------------------------------------------------------------------
class TestPidPool:
    def _pool(self, budget=1 << 16):
        return PidPool(MemoryManager(budget, host_budget=budget))

    def test_record_then_exact_intersect(self):
        pool = self._pool()
        pred = E.cmp("a", ">", 5)
        key = E.canonical(pred)
        pool.record("t", key, pred, 8, present=(1, 3))
        live, hits = pool.intersect("t", key, pred, 8,
                                    live=range(8))
        assert hits == 1 and live == (1, 3)
        assert pool.contains("t", key)

    def test_implies_closure_prunes_stronger_query(self):
        pool = self._pool()
        weak = E.cmp("a", ">", 5)
        pool.record("t", E.canonical(weak), weak, 8, present=(2, 5))
        strong = E.and_(E.cmp("a", ">", 5), E.cmp("b", "<", 3))
        live, hits = pool.intersect(
            "t", E.canonical(strong), strong, 8, live=range(8),
            implies=lambda p, q: subsumes(p, q, INT_SCHEMA))
        assert hits == 1 and live == (2, 5)
        # without the implies closure a different key finds nothing
        live2, hits2 = pool.intersect(
            "t", E.canonical(strong), strong, 8, live=range(8))
        assert hits2 == 0 and live2 == tuple(range(8))

    def test_layout_mismatch_skipped(self):
        pool = self._pool()
        pred = E.cmp("a", ">", 5)
        key = E.canonical(pred)
        pool.record("t", key, pred, 8, present=(1,))
        live, hits = pool.intersect("t", key, pred, 16, live=range(16))
        assert hits == 0 and live == tuple(range(16))

    def test_other_table_never_consulted(self):
        pool = self._pool()
        pred = E.cmp("a", ">", 5)
        key = E.canonical(pred)
        pool.record("t", key, pred, 8, present=(1,))
        live, hits = pool.intersect("u", key, pred, 8, live=range(8))
        assert hits == 0 and live == tuple(range(8))

    def test_invalidate_table_drops_only_its_keys(self):
        pool = self._pool()
        pa, pb = E.cmp("a", ">", 5), E.cmp("b", "<", 3)
        pool.record("t", E.canonical(pa), pa, 8, present=(1,))
        pool.record("u", E.canonical(pb), pb, 8, present=(2,))
        pool.invalidate_table("t")
        assert not pool.contains("t", E.canonical(pa))
        assert pool.contains("u", E.canonical(pb))

    def test_bitset_bytes_accounting(self):
        pool = self._pool()
        pred = E.cmp("a", ">", 5)
        pool.record("t", E.canonical(pred), pred, 8, present=(0,))
        assert pool.used_bytes == 1          # 8 partitions = 1 byte
        pred2 = E.cmp("b", ">", 5)
        pool.record("t", E.canonical(pred2), pred2, 1024, present=(9,))
        assert pool.used_bytes == 1 + 128    # 1024 partitions = 128 B


# ---------------------------------------------------------------------------
# properties: seeded always-run sweeps + hypothesis variants when available
# ---------------------------------------------------------------------------
_OPS = ("<", "<=", ">", ">=", "==", "!=")


def _rand_atom(rng):
    """One random compare atom over the INT_SCHEMA columns: integer,
    fractional-on-integer, and float thresholds all reachable."""
    name = ("a", "b", "f")[rng.integers(0, 3)]
    op = _OPS[rng.integers(0, len(_OPS))]
    if name == "f":
        thr = round(float(rng.uniform(-1.5, 1.5)), 3)
    elif rng.integers(0, 2):
        thr = int(rng.integers(-5, 105))
    else:
        thr = round(float(rng.uniform(-5, 105)), 2)
    return E.cmp(name, op, thr)


def _prop_cols(seed):
    rng = np.random.default_rng(seed)
    return {
        "a": rng.integers(-10, 110, 512).astype(np.int32),
        "b": rng.integers(-10, 110, 512).astype(np.int32),
        "f": rng.uniform(-2, 2, 512).astype(np.float32),
    }


def _check_normal_form_rows(atoms):
    cols = _prop_cols(5)
    pred = E.and_(*atoms) if len(atoms) > 1 else atoms[0]
    norm = normalize_intervals(canonicalize_expr(pred), INT_SCHEMA)
    m_raw = np.asarray(E.eval_expr(pred, cols))
    m_norm = np.asarray(E.eval_expr(norm, cols))
    assert np.array_equal(m_raw, m_norm), E.pretty(pred)


def _check_residual_reconstructs(atoms, extra):
    """Whenever p subsumes q = p ∧ extra, rows(p) ∧ residual == rows(q).
    Returns True when the (conservative) decision actually fired."""
    cols = _prop_cols(7)
    p = E.and_(*atoms) if len(atoms) > 1 else atoms[0]
    q = E.and_(p, extra)
    resid = subsumption_residual(p, q, INT_SCHEMA)
    if resid is None:
        return False       # declining is always allowed, never wrong
    m_p = np.asarray(E.eval_expr(p, cols))
    m_q = np.asarray(E.eval_expr(q, cols))
    m_r = np.asarray(E.eval_expr(resid, cols))
    assert np.array_equal(m_p & m_r, m_q), E.pretty(q)
    return True


class TestNormalizationProperty:
    def test_interval_normal_form_selects_same_rows_seeded(self):
        rng = np.random.default_rng(23)
        for _ in range(150):
            n = int(rng.integers(1, 5))
            _check_normal_form_rows([_rand_atom(rng) for _ in range(n)])

    def test_residual_reconstructs_query_seeded(self):
        rng = np.random.default_rng(29)
        fired = 0
        for _ in range(150):
            n = int(rng.integers(1, 4))
            atoms = [_rand_atom(rng) for _ in range(n)]
            fired += _check_residual_reconstructs(atoms, _rand_atom(rng))
        assert fired > 50, "subsumption almost never decided"

    def test_normal_form_rows_property(self):
        pytest.importorskip("hypothesis",
                            reason="property tests need hypothesis")
        from hypothesis import HealthCheck, given, settings
        from hypothesis import strategies as st

        @given(seed=st.integers(0, 2 ** 16),
               n=st.integers(1, 4))
        @settings(max_examples=40, deadline=None,
                  suppress_health_check=[HealthCheck.too_slow])
        def prop(seed, n):
            rng = np.random.default_rng(seed)
            atoms = [_rand_atom(rng) for _ in range(n)]
            _check_normal_form_rows(atoms)
            _check_residual_reconstructs(atoms, _rand_atom(rng))

        prop()


SCHEMA = synthetic_schema(n_int=3, n_dbl=2, n_str=1)
NROWS = 4000
COLS = generate_columns(SCHEMA, NROWS, seed=11)


def _session(fmt="columnar", scheme="range", prune=True, pid=True,
             partitioned=True, subsumption=True):
    sess = Session.from_config(SessionConfig(
        execution=ExecutionConfig(prune=prune, pid_cache=pid),
        memory=MemoryConfig(budget_bytes=1 << 26),
        mqo=MqoConfig(subsumption=subsumption)))
    st, _ = make_storage("t", SCHEMA, NROWS, fmt, cols=COLS)
    sess.register(st, columnar_for_stats=COLS,
                  partitioning=(Partitioning("n1", scheme, 8)
                                if partitioned else None))
    return sess


def _check_pid_pruned_equals_unpruned(fmt, scheme, t, u):
    pruned = _session(fmt=fmt, scheme=scheme)
    plain = _session(fmt=fmt, scheme=scheme, prune=False, pid=False)
    qs = lambda s: [                         # noqa: E731
        s.table("t").filter(E.cmp("n1", "<", t)).project("n1", "n2"),
        s.table("t").filter(E.and_(E.cmp("n1", "<", t),
                                   E.cmp("n2", "<", u)))
         .project("n1", "n2"),
    ]
    # two passes: the first RECORDS presence bitsets, the second
    # INTERSECTS them (exact key for query 1, implies closure for the
    # strictly-stronger query 2)
    for _ in range(2):
        a = pruned.run_batch(qs(pruned), mqo=False)
        b = plain.run_batch(qs(plain), mqo=False)
        for ra, rb in zip(a.results, b.results):
            assert ra.table.row_multiset() == rb.table.row_multiset()
    assert pruned._pid_pool is not None
    assert pruned._pid_pool.used_bytes > 0


class TestPidPruningProperty:
    @pytest.mark.parametrize("fmt", ["columnar", "csv"])
    @pytest.mark.parametrize("scheme", ["range", "hash"])
    def test_bitset_pruned_equals_unpruned_seeded(self, fmt, scheme):
        rng = np.random.default_rng(31)
        for _ in range(3):
            t = int(rng.integers(50, 900))
            u = int(rng.integers(50, 900))
            _check_pid_pruned_equals_unpruned(fmt, scheme, t, u)

    def test_bitset_pruned_equals_unpruned_property(self):
        pytest.importorskip("hypothesis",
                            reason="property tests need hypothesis")
        from hypothesis import HealthCheck, given, settings
        from hypothesis import strategies as st

        @given(t=st.integers(50, 900), u=st.integers(50, 900),
               fmt=st.sampled_from(["columnar", "csv"]),
               scheme=st.sampled_from(["range", "hash"]))
        @settings(max_examples=8, deadline=None,
                  suppress_health_check=[HealthCheck.too_slow])
        def prop(t, u, fmt, scheme):
            _check_pid_pruned_equals_unpruned(fmt, scheme, t, u)

        prop()


def _check_resumed_equals_from_scratch(w, s, u, *, expect_hit=False):
    warm = _session(partitioned=False)
    cold = _session(partitioned=False)
    warm.disk_latency_per_byte = 5e-9        # make caching worthwhile
    seed = [warm.table("t").filter(E.cmp("n1", "<", w))
                .project("n1", "n2", "d1") for _ in range(3)]
    probe = lambda sess: sess.table("t").filter(  # noqa: E731
        E.and_(E.cmp("n1", "<", s), E.cmp("n2", ">=", u))
    ).project("n1", "n2")
    seeded = warm.run_batch(seed)
    assert seeded.mqo.rewritten.ces, "precondition: a CE formed"
    got = warm.run_batch([probe(warm)])
    want = cold.run_batch([probe(cold)], mqo=False)
    if expect_hit:
        assert got.mqo.report.n_subsumed == 1
    assert got.results[0].table.row_multiset() == \
        want.results[0].table.row_multiset()


class TestSubsumptionResumeProperty:
    def test_resumed_equals_from_scratch_seeded(self):
        rng = np.random.default_rng(37)
        for _ in range(3):
            _check_resumed_equals_from_scratch(
                int(rng.integers(400, 800)), int(rng.integers(100, 390)),
                int(rng.integers(100, 900)), expect_hit=True)

    def test_resumed_equals_from_scratch_property(self):
        pytest.importorskip("hypothesis",
                            reason="property tests need hypothesis")
        from hypothesis import HealthCheck, given, settings
        from hypothesis import strategies as st

        @given(w=st.integers(400, 800), s=st.integers(100, 390),
               u=st.integers(100, 900))
        @settings(max_examples=5, deadline=None,
                  suppress_health_check=[HealthCheck.too_slow])
        def prop(w, s, u):
            _check_resumed_equals_from_scratch(w, s, u)

        prop()


# ---------------------------------------------------------------------------
# service integration: explain fields + config knobs
# ---------------------------------------------------------------------------
class TestServiceIntegration:
    def _seed_and_probe(self, sess):
        svc = QueryService(sess, max_batch=4)
        seeds = [svc.submit(sess.table("t").filter(E.cmp("n1", "<", 500))
                            .project("n1", "n2", "d1")) for _ in range(3)]
        svc.flush()
        assert all(not h.failed for h in seeds)
        probe = svc.submit(sess.table("t").filter(
            E.and_(E.cmp("n1", "<", 300), E.cmp("n2", ">=", 400))
        ).project("n1", "n2"))
        svc.flush()
        return probe

    def test_explain_reports_subsumption_hit(self):
        sess = _session(partitioned=False)
        sess.disk_latency_per_byte = 5e-9
        h = self._seed_and_probe(sess)
        e = h.explain()
        assert e["subsumption_hit"] is True
        assert not e.get("resident_reuse")
        sub = e["subsumption"]
        assert len(sub["strict_psi"]) == 12
        assert "cmp" in sub["residual"]
        assert isinstance(e["pid_pruned_parts"], int)

    def test_subsumption_knob_disables_channel(self):
        sess = _session(partitioned=False, subsumption=False)
        sess.disk_latency_per_byte = 5e-9
        h = self._seed_and_probe(sess)
        e = h.explain()
        assert e["subsumption_hit"] is False
        assert "subsumption" not in e

    def test_pid_cache_knob_disables_pool(self):
        sess = _session(pid=False)
        assert sess._pid_pool is None
        h = self._seed_and_probe(sess)
        assert not h.failed
        assert h.explain()["pid_pruned_parts"] == 0

    def test_reregister_invalidates_pid_bitsets(self):
        sess = _session()
        q = lambda: sess.table("t").filter(       # noqa: E731
            E.cmp("n1", "<", 300)).project("n1")
        sess.run_batch([q()], mqo=False)
        assert sess._pid_pool.used_bytes > 0
        st, _ = make_storage("t", SCHEMA, NROWS, "columnar", cols=COLS)
        sess.register(st, columnar_for_stats=COLS,
                      partitioning=Partitioning("n1", "range", 8))
        assert sess._pid_pool.used_bytes == 0

    def test_pid_pool_is_tiny_next_to_ce_pool(self):
        sess = _session()
        sess.disk_latency_per_byte = 5e-9
        self._seed_and_probe(sess)
        ce_bytes = sess._ce_cache.used_bytes
        assert ce_bytes > 0
        assert sess._pid_pool.used_bytes <= max(1, ce_bytes // 100)
