"""Rewrite-phase details: extraction plans, cache-plan chaining, caching
semantics (first consumer pays — paper §6.3 footnote 5)."""
import numpy as np
import pytest

from repro.core.fingerprint import fingerprint
from repro.core.plan import walk
from repro.relational import (ExecContext, I32, Schema, Session,
                              expr as E, logical as L, make_storage,
                              SessionConfig)

S = Schema.of(("a", I32), ("b", I32), ("c", I32))


@pytest.fixture()
def sess():
    rng = np.random.default_rng(9)
    cols = {c: rng.integers(0, 100, 2000).astype(np.int32)
            for c in ("a", "b", "c")}
    s = Session.from_config(
        SessionConfig.from_legacy_kwargs(budget_bytes=1 << 24))
    st, _ = make_storage("t", S, 2000, "columnar", cols=cols)
    s.register(st)
    return s


class TestExtraction:
    def test_identity_extraction_for_equal_members(self, sess):
        t = sess.table("t")
        q = lambda: t.filter(E.cmp("a", ">", 50)).project("a", "b")
        res = sess.run_batch([q(), q()], mqo=True)
        # equal members: rewritten plans are bare CachedScans (possibly
        # under a project for column order) with NO re-filter
        for plan in res.mqo.rewritten.plans:
            assert not any(isinstance(n, L.Filter) for n in walk(plan))

    def test_divergent_extraction_refilters(self, sess):
        t = sess.table("t")
        q1 = t.filter(E.cmp("a", ">", 80)).project("a", "b")
        q2 = t.filter(E.cmp("a", "<", 20)).project("a", "c")
        res = sess.run_batch([q1, q2], mqo=True)
        if res.mqo.report.n_selected:
            for plan in res.mqo.rewritten.plans:
                kinds = [type(n) for n in walk(plan)]
                if L.CachedScan in kinds:
                    assert L.Filter in kinds  # member predicate re-applied

    def test_first_consumer_pays_materialization(self, sess):
        t = sess.table("t")
        q = lambda: t.filter(E.cmp("a", ">", 50)).project("a")
        res = sess.run_batch([q(), q(), q()], mqo=True)
        rep = res.cache_report
        # one admission (first query), hits for the others
        assert rep["admissions"] >= 1
        assert rep["hits"] >= 2

    def test_extraction_columns_preserved(self, sess):
        """Augmented covering projects keep member predicate columns."""
        t = sess.table("t")
        q1 = t.filter(E.cmp("a", ">", 60)).project("b")
        q2 = t.filter(E.cmp("a", "<", 40)).project("c")
        res = sess.run_batch([q1, q2], mqo=True)
        base = sess.run_batch([q1, q2], mqo=False)
        for b, o in zip(base.results, res.results):
            assert b.table.row_multiset() == o.table.row_multiset()
            assert b.table.schema.names == o.table.schema.names


class TestBudgetBehavior:
    def test_zero_budget_rewrites_nothing(self, sess):
        t = sess.table("t")
        q = lambda: t.filter(E.cmp("a", ">", 50))
        res = sess.run_batch([q(), q()], mqo=True, budget_bytes=0)
        assert res.mqo.report.n_selected == 0
        for plan in res.mqo.rewritten.plans:
            assert not any(isinstance(n, L.CachedScan)
                           for n in walk(plan))

    def test_tiny_budget_prefers_small_high_value_ces(self, sess):
        t = sess.table("t")
        # one narrow shared SE (small weight) + one wide one (big weight)
        narrow = lambda thr: (t.filter(E.cmp("a", ">", thr))
                              .project("a"))
        wide = lambda thr: t.filter(E.cmp("b", ">", thr))
        qs = [narrow(90), narrow(95), wide(10), wide(5)]
        res = sess.run_batch(qs, mqo=True, budget_bytes=4096)
        assert res.mqo.report.selected_weight <= 4096

    def test_spill_on_underestimate(self):
        """Cardinality underestimates spill instead of crashing
        (paper §6.3 footnote 6-ii)."""
        from repro.core.cache import CacheManager

        mgr = CacheManager(budget_bytes=100,
                           spill_fn=lambda x: ("host", x),
                           unspill_fn=lambda x: x[1])
        mgr.put(b"x" * 16, payload="A" * 10, nbytes=90, est_bytes=50)
        mgr.put(b"y" * 16, payload="B" * 10, nbytes=90, est_bytes=50)
        assert mgr.stats.spilled_bytes == 90       # second one spilled
        assert mgr.get(b"y" * 16) == "B" * 10      # still readable
