"""Unified memory hierarchy: budget invariants, spill tiers, policies,
cross-batch CE retention (ISSUE 2).

The load-bearing properties:

  * ``device_used <= device_budget`` (and host analog) after ANY
    put/get/evict sequence, in every pool — hypothesis-tested;
  * batch results are bit-identical under a pathologically tiny budget
    (everything evicted/dropped) and an unlimited one, for every
    eviction policy;
  * a warm repeat of a batch re-prices resident CEs as zero-weight
    knapsack items and re-materializes nothing;
  * re-registering a table invalidates its scan-pool entries and any
    retained CE content.
"""
import numpy as np
import pytest

from repro.core.memory import MemoryManager
from repro.relational import (I32, Schema, Session, expr as E,
                              make_storage,
                              SessionConfig)


def _mk_manager(device=100, host=None, policy="lru"):
    return MemoryManager(device, host_budget=host, policy=policy)


class TestTiers:
    def test_pools_share_one_device_budget(self):
        m = _mk_manager(100)
        a = m.pool("a")
        b = m.pool("b")
        a.put("x", 1, nbytes=60)
        b.put("y", 2, nbytes=60)          # evicts a's entry (lru)
        assert m.device_used == 60
        assert b.contains("y") and not a.contains("x")

    def test_two_tier_spill_then_drop(self):
        m = _mk_manager(100, host=100)
        p = m.pool("p", spill_fn=lambda x: ("host", x),
                   unspill_fn=lambda x: x[1])
        p.put("a", "A", nbytes=80)
        p.put("b", "B", nbytes=80)        # a -> host
        assert p.entry("a").spilled and m.host_used == 80
        p.put("c", "C", nbytes=80)        # b -> host would exceed: a drops
        assert m.device_used == 80 and m.host_used == 80
        assert not p.contains("a")        # dropped off the host tier
        assert p.get("b") == "B"          # unspilled from host

    def test_evict_to_drop_without_spill_fn(self):
        m = _mk_manager(100)
        p = m.pool("p")                   # no spill path: evict == drop
        p.put("a", "A", nbytes=60)
        p.put("b", "B", nbytes=60)
        assert not p.contains("a") and p.contains("b")
        assert m.device_used == 60 and m.host_used == 0
        assert p.stats.evictions == 1

    def test_promotion_on_hit_with_headroom(self):
        m = _mk_manager(100)
        p = m.pool("p", spill_fn=lambda x: x, unspill_fn=lambda x: x,
                   policy="admission")
        p.put("a", "A", nbytes=60)
        p.put("b", "B", nbytes=60)        # incoming spills (admission)
        assert p.entry("b").spilled
        p.evict("a")                      # budget frees up
        assert p.get("b") == "B"          # hit promotes back to device
        assert not p.entry("b").spilled
        assert p.stats.promotions == 1
        assert m.device_used == 60 and m.host_used == 0

    def test_oversized_entry_goes_straight_to_spill_path(self):
        m = _mk_manager(100, host=1000)
        p = m.pool("p", spill_fn=lambda x: x, unspill_fn=lambda x: x)
        e = p.put("big", "B", nbytes=500)
        assert e.spilled and m.device_used == 0 and m.host_used == 500

    def test_can_never_fit_entry_does_not_flush_residents(self):
        """An entry bigger than a whole tier is dropped without
        evicting anything from that tier."""
        m = _mk_manager(100, host=200)
        p = m.pool("p", spill_fn=lambda x: x, unspill_fn=lambda x: x)
        p.put("a", "A", nbytes=60)
        p.put("b", "B", nbytes=60)            # spills to host
        e = p.put("huge", "H", nbytes=500)    # > device AND > host
        assert e.tier == "dropped"
        assert p.contains("a") and p.contains("b")   # residents intact
        assert m.device_used == 60 and m.host_used == 60


class TestPolicies:
    def test_lru_evicts_least_recently_used(self):
        m = _mk_manager(100, policy="lru")
        p = m.pool("p")
        p.put("a", "A", nbytes=40)
        p.put("b", "B", nbytes=40)
        assert p.get("a") == "A"          # refresh a
        p.put("c", "C", nbytes=40)        # b is now the lru victim
        assert p.contains("a") and p.contains("c") and not p.contains("b")

    def test_benefit_evicts_lowest_benefit_per_byte(self):
        m = _mk_manager(100, policy="benefit")
        p = m.pool("p")
        p.put("cheap", "X", nbytes=40, benefit=1.0)
        p.put("dear", "Y", nbytes=40, benefit=100.0)
        p.put("new", "Z", nbytes=40, benefit=10.0)
        assert not p.contains("cheap")
        assert p.contains("dear") and p.contains("new")

    def test_admission_pool_protects_residents(self):
        m = _mk_manager(100, policy="admission")
        p = m.pool("p", spill_fn=lambda x: x, unspill_fn=lambda x: x)
        p.put("a", "A", nbytes=60)
        e = p.put("b", "B", nbytes=60)
        assert p.contains("a") and not p.entry("a").spilled
        assert e.spilled                  # the incoming entry spilled

    def test_admission_put_may_displace_evictable_pools(self):
        m = _mk_manager(100, policy="lru")
        scan = m.pool("scan")
        ce = m.pool("ce", policy="admission")
        scan.put("col", "S", nbytes=80)
        ce.put("psi", "C", nbytes=80)     # scan column yields
        assert ce.contains("psi") and not ce.entry("psi").spilled
        assert not scan.contains("col")


class TestMaintenance:
    def test_invalidate_by_predicate(self):
        m = _mk_manager(1000)
        p = m.pool("scan")
        p.put(("t1", "a"), 1, nbytes=10)
        p.put(("t1", "b"), 2, nbytes=10)
        p.put(("t2", "a"), 3, nbytes=10)
        assert p.invalidate(lambda k: k[0] == "t1") == 2
        assert not p.contains(("t1", "a")) and p.contains(("t2", "a"))
        assert m.device_used == 10

    def test_reput_same_key_replaces_accounting(self):
        m = _mk_manager(100)
        p = m.pool("p")
        p.put("a", "A", nbytes=60)
        p.put("a", "A2", nbytes=30)
        assert m.device_used == 30 and p.get("a") == "A2"

    def test_report_shape(self):
        m = _mk_manager(100)
        m.pool("p").put(b"\x12" * 16, "A", nbytes=10)
        rep = m.report()
        assert rep["device_used"] == 10
        assert rep["pools"]["p"]["entries"][0]["nbytes"] == 10


# ---------------------------------------------------------------------------
# hypothesis: budget invariants under arbitrary op sequences
# ---------------------------------------------------------------------------
class TestBudgetInvariants:
    KEYS = list(range(8))

    def _check(self, m: MemoryManager):
        dev = host = 0
        for p in m.pools.values():
            pd = sum(e.nbytes for e in p.entries.values()
                     if e.tier == "device")
            ph = sum(e.nbytes for e in p.entries.values()
                     if e.tier == "host")
            assert p.stats.used == pd
            assert p.stats.spilled_bytes == ph
            dev += pd
            host += ph
        assert m.device_used == dev
        assert m.host_used == host
        assert m.device_used <= m.device_budget
        if m.host_budget is not None:
            assert m.host_used <= m.host_budget

    def _run_ops(self, ops, device, host, policies):
        m = MemoryManager(device, host_budget=host)
        pools = [
            m.pool("p0", policy=policies[0]),
            m.pool("p1", spill_fn=lambda x: x, unspill_fn=lambda x: x,
                   policy=policies[1]),
        ]
        for op, pool_i, key, nbytes, benefit in ops:
            p = pools[pool_i]
            if op == "put":
                p.put(key, f"v{key}", nbytes=nbytes, benefit=benefit)
            elif op == "get":
                p.get(key)
            elif op == "evict":
                p.evict(key)
            else:
                p.clear()
            self._check(m)

    def test_property_used_le_budget(self):
        hyp = pytest.importorskip(
            "hypothesis", reason="property tests need hypothesis")
        from hypothesis import given, settings, strategies as st

        op = st.tuples(
            st.sampled_from(["put", "put", "put", "get", "evict", "clear"]),
            st.integers(0, 1),
            st.sampled_from(self.KEYS),
            st.integers(0, 130),
            st.floats(0.0, 10.0, allow_nan=False),
        )

        @settings(max_examples=120, deadline=None)
        @given(ops=st.lists(op, min_size=1, max_size=40),
               device=st.integers(0, 150),
               host=st.one_of(st.none(), st.integers(0, 150)),
               policies=st.tuples(
                   st.sampled_from(["lru", "benefit", "admission"]),
                   st.sampled_from(["lru", "benefit", "admission"])))
        def run(ops, device, host, policies):
            self._run_ops(ops, device, host, policies)

        run()

    def test_smoke_sequences_without_hypothesis(self):
        """Deterministic fallback so the invariant is exercised even
        when hypothesis is absent (it is optional in this repo)."""
        rng = np.random.default_rng(0)
        for case in range(50):
            ops = []
            for _ in range(30):
                ops.append((
                    ["put", "put", "put", "get", "evict", "clear"][
                        int(rng.integers(0, 6))],
                    int(rng.integers(0, 2)),
                    int(rng.integers(0, 8)),
                    int(rng.integers(0, 130)),
                    float(rng.random() * 10),
                ))
            self._run_ops(
                ops, int(rng.integers(0, 150)),
                None if rng.integers(0, 2) else int(rng.integers(0, 150)),
                (["lru", "benefit", "admission"][int(rng.integers(0, 3))],
                 ["lru", "benefit", "admission"][int(rng.integers(0, 3))]))


# ---------------------------------------------------------------------------
# end-to-end: budgets never change results; batches warm up across runs
# ---------------------------------------------------------------------------
S = Schema.of(("a", I32), ("b", I32), ("c", I32))


def _session(budget, policy="lru", nrows=4000, fmt="columnar",
             seed=7, **kw) -> Session:
    rng = np.random.default_rng(seed)
    cols = {c: rng.integers(0, 100, nrows).astype(np.int32)
            for c in ("a", "b", "c")}
    sess = Session.from_config(SessionConfig.from_legacy_kwargs(
        budget_bytes=budget, policy=policy, **kw))
    st, _ = make_storage("t", S, nrows, fmt, cols=cols)
    sess.register(st, columnar_for_stats=cols)
    return sess


def _shared_batch(sess: Session):
    t = sess.table("t")
    q = lambda: t.filter(E.cmp("a", ">", 40)).project("a", "b")
    r = lambda: t.filter(E.cmp("b", "<", 70)).project("b", "c")
    return [q(), q(), r(), r(), q()]


class TestBudgetsNeverChangeResults:
    @pytest.mark.parametrize("policy", ["lru", "benefit"])
    def test_tiny_budget_bit_identical_to_unlimited(self, policy):
        """Everything evicted/dropped vs nothing evicted: same rows."""
        tiny = _session(budget=128, policy=policy)
        big = _session(budget=1 << 30, policy=policy)
        for _ in range(2):              # second pass hits retained state
            rt = tiny.run_batch(_shared_batch(tiny), mqo=True)
            rb = big.run_batch(_shared_batch(big), mqo=True)
            for a, b in zip(rt.results, rb.results):
                assert a.table.row_multiset() == b.table.row_multiset()
        assert tiny.memory.device_used <= 128

    @pytest.mark.parametrize("policy", ["lru", "benefit"])
    def test_thrashing_scan_pool_budget(self, policy):
        """A budget big enough to cache SOME scan columns but not all:
        eviction churns, results must still match the eager path."""
        sess = _session(budget=16 * 4000 + 64, policy=policy)
        eager = _session(budget=1 << 30)
        eager.fuse = eager.defer_sync = eager.use_scan_cache = False
        got = sess.run_batch(_shared_batch(sess), mqo=False)
        want = eager.run_batch(_shared_batch(eager), mqo=False)
        for a, b in zip(got.results, want.results):
            assert a.table.row_multiset() == b.table.row_multiset()
        assert sess.memory.device_used <= sess.memory.device_budget


class TestCrossBatchRetention:
    def test_warm_repeat_reprices_and_skips_rematerialization(self):
        sess = _session(budget=1 << 26, fmt="csv", nrows=20_000)
        cold = sess.run_batch(_shared_batch(sess), mqo=True)
        assert cold.mqo.report.n_selected >= 1
        adm_cold = cold.cache_report["admissions"]
        warm = sess.run_batch(_shared_batch(sess), mqo=True)
        assert warm.mqo.report.n_resident >= 1
        assert warm.mqo.report.selected_weight == 0   # all already paid
        assert warm.cache_report["admissions"] == adm_cold  # no re-puts
        base = sess.run_batch(_shared_batch(sess), mqo=False)
        for b, o in zip(base.results, warm.results):
            assert b.table.row_multiset() == o.table.row_multiset()

    def test_retention_off_restores_per_batch_behavior(self):
        sess = _session(budget=1 << 26, fmt="csv", nrows=20_000,
                        retain_across_batches=False)
        sess.run_batch(_shared_batch(sess), mqo=True)
        warm = sess.run_batch(_shared_batch(sess), mqo=True)
        assert warm.mqo.report.n_resident == 0

    def test_same_psi_different_predicates_not_reused(self):
        """Loose ψ collision across batches: the strict content check
        must refuse the zero-weight repricing and the stale bytes."""
        sess = _session(budget=1 << 26, fmt="csv", nrows=20_000)
        t = sess.table("t")
        b1 = lambda: t.filter(E.cmp("a", ">", 80)).project("a", "b")
        b2 = lambda: t.filter(E.cmp("a", "<", 15)).project("a", "b")
        sess.run_batch([b1(), b1(), b1()], mqo=True)
        res = sess.run_batch([b2(), b2(), b2()], mqo=True)
        assert res.mqo.report.n_resident == 0
        base = sess.run_batch([b2(), b2(), b2()], mqo=False)
        for b, o in zip(base.results, res.results):
            assert b.table.row_multiset() == o.table.row_multiset()


class TestReregisterInvalidation:
    def test_reregister_drops_scan_pool_entries(self):
        sess = _session(budget=1 << 26)
        sess.run_batch(_shared_batch(sess), mqo=False)
        assert any(k[0] == "t" for k in sess._scan_pool.keys())
        rng = np.random.default_rng(8)
        cols = {c: rng.integers(100, 200, 4000).astype(np.int32)
                for c in ("a", "b", "c")}
        st, _ = make_storage("t", S, 4000, "columnar", cols=cols)
        sess.register(st, columnar_for_stats=cols)
        assert not any(k[0] == "t" for k in sess._scan_pool.keys())

    def test_reregister_drops_retained_ce_content(self):
        sess = _session(budget=1 << 26, fmt="csv", nrows=20_000)
        cold = sess.run_batch(_shared_batch(sess), mqo=True)
        assert cold.mqo.report.n_selected >= 1
        assert sess._ce_cache.resident_psis()
        rng = np.random.default_rng(9)
        new_cols = {c: rng.integers(0, 100, 20_000).astype(np.int32)
                    for c in ("a", "b", "c")}
        st, _ = make_storage("t", S, 20_000, "csv", cols=new_cols)
        sess.register(st, columnar_for_stats=new_cols)
        assert not sess._ce_cache.resident_psis()
        # and the next batch over the NEW data is correct
        opt = sess.run_batch(_shared_batch(sess), mqo=True)
        base = sess.run_batch(_shared_batch(sess), mqo=False)
        for b, o in zip(base.results, opt.results):
            assert b.table.row_multiset() == o.table.row_multiset()
