"""Pure numpy/python reference interpreter for logical plans.

The tests' ground truth: executes the same logical plans as the JAX
engine with plain row-wise semantics.  Returns sorted row multisets.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.relational import expr as E, logical as L


def _pad_bytes(v, width: int) -> bytes:
    raw = v if isinstance(v, bytes) else str(v).encode()
    return raw[:width] + b"\x00" * max(0, width - len(raw))


def eval_pred(e: E.Expr, row: dict, schema) -> bool:
    if isinstance(e, E.TrueExpr):
        return True
    if isinstance(e, E.Cmp):
        lhs = row[e.col.name]
        rhs = (row[e.rhs.name] if isinstance(e.rhs, E.Col)
               else e.rhs.value)
        if isinstance(lhs, bytes):
            rhs = _pad_bytes(rhs, len(lhs))
            return lhs == rhs if e.op == "==" else lhs != rhs
        import operator as op

        return {"<": op.lt, "<=": op.le, ">": op.gt, ">=": op.ge,
                "==": op.eq, "!=": op.ne}[e.op](lhs, rhs)
    if isinstance(e, E.And):
        return all(eval_pred(p, row, schema) for p in e.parts)
    if isinstance(e, E.Or):
        return any(eval_pred(p, row, schema) for p in e.parts)
    if isinstance(e, E.Not):
        return not eval_pred(e.part, row, schema)
    raise TypeError(type(e))


def _rows_of(columns: Dict[str, np.ndarray], nrows: int, schema) -> List[dict]:
    out = []
    for i in range(nrows):
        row = {}
        for name, t in schema.fields:
            v = columns[name][i]
            if t.kind == "str":
                row[name] = bytes(np.asarray(v).tobytes())
            elif t.kind == "f32":
                row[name] = float(v)
            else:
                row[name] = int(v)
        out.append(row)
    return out


def execute_oracle(node: L.Node, catalog: Dict[str, tuple]) -> List[dict]:
    """catalog: table name -> (schema, nrows, typed numpy columns)."""
    if isinstance(node, (L.Scan,)):
        schema, nrows, cols = catalog[node.table]
        return _rows_of(cols, nrows, schema)
    if isinstance(node, L.Filter):
        rows = execute_oracle(node.child, catalog)
        return [r for r in rows
                if eval_pred(node.pred, r, node.child.schema)]
    if isinstance(node, L.Project):
        rows = execute_oracle(node.child, catalog)
        return [{c: r[c] for c in node.cols} for r in rows]
    if isinstance(node, L.Join):
        lrows = execute_oracle(node.left, catalog)
        rrows = execute_oracle(node.right, catalog)
        (lc, rc), = node.on
        if lrows and lc not in lrows[0]:
            lc, rc = rc, lc
        index: Dict[object, List[dict]] = {}
        for r in rrows:
            index.setdefault(r[rc], []).append(r)
        out = []
        for l in lrows:
            for r in index.get(l[lc], ()):  # inner equi-join
                out.append({**l, **r})
        return out
    if isinstance(node, L.Aggregate):
        rows = execute_oracle(node.child, catalog)
        groups: Dict[tuple, List[dict]] = {}
        for r in rows:
            groups.setdefault(tuple(r[g] for g in node.group_by),
                              []).append(r)
        out = []
        for key, members in groups.items():
            row = dict(zip(node.group_by, key))
            for out_name, fn, c in node.aggs:
                vals = [m[c] for m in members] if c else []
                if fn == "count":
                    row[out_name] = len(members)
                elif fn == "sum":
                    row[out_name] = sum(vals)
                elif fn == "min":
                    row[out_name] = min(vals)
                elif fn == "max":
                    row[out_name] = max(vals)
                elif fn == "mean":
                    row[out_name] = float(sum(vals)) / len(vals)
            out.append(row)
        return out
    if isinstance(node, L.Sort):
        rows = execute_oracle(node.child, catalog)
        return sorted(rows, key=lambda r: r[node.by], reverse=node.desc)
    if isinstance(node, L.Limit):
        return execute_oracle(node.child, catalog)[: node.n]
    if isinstance(node, L.Union):
        return (execute_oracle(node.left, catalog)
                + execute_oracle(node.right, catalog))
    raise TypeError(type(node))


def multiset(rows: List[dict], schema) -> List[tuple]:
    out = []
    for r in rows:
        t = []
        for name, ct in schema.fields:
            v = r[name]
            if ct.kind == "f32":
                v = round(float(v), 4)
            t.append(v)
        out.append(tuple(t))
    out.sort()
    return out
